"""Quickstart: build a reduced model with the paper's memory plan, train a
few steps, then serve it — all on CPU in under a minute.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import numpy as np

from repro.configs import (ARCHS, MemoryPlan, MeshPlan, RunConfig,
                           TrainConfig)
from repro.configs.base import ShapeConfig
from repro.data.pipeline import SyntheticLM
from repro.models.model import build_model
from repro.serve.engine import Engine, Request
from repro.train.fault import FaultHandler
from repro.train.loop import train


def main():
    cfg = ARCHS["smollm-135m"].reduced()     # tiny same-family twin
    tc = TrainConfig(total_steps=30, warmup_steps=5, learning_rate=1e-2,
                     checkpoint_every=15, log_every=10,
                     checkpoint_dir=tempfile.mkdtemp())
    run = RunConfig(
        model=cfg,
        shape=ShapeConfig("quickstart", 64, 4, "train"),
        mesh=MeshPlan((1,), ("data",)),
        # the paper's technique as a first-class config:
        memory=MemoryPlan(policy="mcdla", placement="bw_aware"),
        train=tc)
    model = build_model(run)

    print("== train ==")
    data = SyntheticLM(cfg, batch=4, seq=64, seed=0)
    state, metrics = train(model, tc, iter(data),
                           fault_handler=FaultHandler(install_signals=False))
    print(f"final loss: {float(metrics['loss']):.3f}")

    print("== serve ==")
    eng = Engine(model, state["params"], batch=2, max_len=64)
    eng.submit(Request(uid=0, prompt=np.arange(8, dtype=np.int32),
                       max_new_tokens=8))
    for r in eng.run():
        print(f"request {r.uid} -> {r.out_tokens}")


if __name__ == "__main__":
    main()
