"""Reproduce the paper's evaluation (Figs 2/9/11/12/13/14, Table IV) with
the calibrated simulator and print the headline comparison.

    PYTHONPATH=src python examples/paper_figures.py
"""
from repro.sim.simulator import harmonic_mean, speedup_table
from repro.sim.topology import ALL_SYSTEMS
from repro.sim.workloads import WORKLOADS


def main():
    dags = {k: f() for k, f in WORKLOADS.items()}
    hm = {}
    for mode in ("dp", "mp"):
        tab = speedup_table(dags, ALL_SYSTEMS, mode)
        print(f"\n=== {mode} speedups over DC-DLA ===")
        names = [s.name for s in ALL_SYSTEMS]
        print(f"{'workload':12s} " + " ".join(f"{n:>10s}" for n in names))
        for w in dags:
            print(f"{w:12s} " + " ".join(f"{tab[w][n]:10.2f}"
                                         for n in names))
        for n in names:
            hm[(mode, n)] = harmonic_mean([tab[w][n] for w in dags])
        print("hmean        " + " ".join(f"{hm[(mode, n)]:10.2f}"
                                         for n in names))
    overall = harmonic_mean([hm[("dp", "MC-DLA(B)")],
                             hm[("mp", "MC-DLA(B)")]])
    print(f"\nMC-DLA(B) overall speedup: {overall:.2f}x   "
          f"(paper: 2.8x; dp {hm[('dp', 'MC-DLA(B)')]:.2f} vs paper 3.5, "
          f"mp {hm[('mp', 'MC-DLA(B)')]:.2f} vs paper 2.1)")


if __name__ == "__main__":
    main()
