"""Batched serving over pooled KV caches (deliverable b, serving scenario).

    PYTHONPATH=src python examples/serve_batch.py
"""
import sys

from repro.launch import serve as launch_serve


def main():
    sys.argv = ["serve", "--arch", "h2o-danube-1.8b", "--smoke",
                "--batch", "4", "--requests", "8", "--new-tokens", "12",
                "--max-len", "96"]
    launch_serve.main()


if __name__ == "__main__":
    main()
