"""End-to-end driver (deliverable b): train the FULL smollm-135m (135M
params) for a few hundred steps on synthetic data.

    PYTHONPATH=src python examples/train_smollm.py --steps 300

This is the same launcher production uses (launch/train.py); on a TPU pod
drop --cpu-batch to run the assigned train_4k shape against the 16x16 mesh.
"""
import argparse

from repro.launch import train as launch_train
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()
    sys.argv = ["train", "--arch", "smollm-135m", "--steps", str(args.steps),
                "--batch", str(args.batch), "--seq", str(args.seq),
                "--lr", "3e-3", "--policy", "mcdla"]
    launch_train.main()


if __name__ == "__main__":
    main()
