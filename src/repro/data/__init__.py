"""Host-side data pipeline."""
