"""Data pipeline: deterministic synthetic streams + file-backed tokens,
checkpointable state, host-side prefetch.

The CPU role the paper worries about (§V-A: "getting the training datasets
ready to be fed into the accelerators") lives here: batches are produced on
host threads and double-buffered ahead of the device step, so the input
pipeline overlaps the accelerator compute — and, under MC-DLA, the host
PCIe link carries *only* this input traffic because memory virtualization
traffic moved to the device-side pool.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import frontends


class SyntheticLM:
    """Deterministic, stateless-by-step synthetic LM stream.

    Batch t is a pure function of (seed, t): resuming at step t after a
    restart reproduces the identical stream with no replay buffer.
    """

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
                 dtype=jnp.bfloat16):
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self.seed, self.dtype = seed, dtype
        self.step = 0

    # -- checkpointable state -------------------------------------------
    def get_state(self) -> Dict[str, int]:
        return {"step": self.step, "seed": self.seed}

    def set_state(self, state: Dict[str, int]) -> None:
        self.step = int(state["step"])
        self.seed = int(state["seed"])

    # --------------------------------------------------------------
    def batch_at(self, t: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, t]))
        B, S, V = self.batch, self.seq, cfg.vocab_size
        # markov-ish stream so the loss is learnable (not pure noise)
        base = rng.integers(0, V, size=(B, 1), dtype=np.int32)
        drift = rng.integers(0, 17, size=(B, S), dtype=np.int32)
        toks = (base + np.cumsum(drift, axis=1)) % V
        tokens = toks.astype(np.int32)
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = -1                       # no target for last pos
        if cfg.mrope_sections:
            pos = np.broadcast_to(np.arange(S, dtype=np.int32),
                                  (3, B, S)).copy()
        else:
            pos = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S)).copy()
        d: Dict[str, np.ndarray] = {"tokens": tokens, "labels": labels,
                                    "positions": pos}
        if cfg.frontend == "audio_stub":
            d["frames"] = rng.standard_normal(
                (B, cfg.frontend_tokens, frontends.AUDIO_FRAME_DIM),
                dtype=np.float32)
        if cfg.frontend == "vision_stub":
            d["patches"] = rng.standard_normal(
                (B, cfg.frontend_tokens, frontends.VISION_PATCH_DIM),
                dtype=np.float32)
            d["labels"][:, :cfg.frontend_tokens] = -1   # no CE on patches
        return d

    def __iter__(self) -> Iterator[Tuple[int, Dict[str, np.ndarray]]]:
        while True:
            t = self.step
            self.step += 1
            yield t, self.batch_at(t)


class MemmapTokens:
    """File-backed token stream (binary int32 file), windowed batches."""

    def __init__(self, path: str, cfg: ModelConfig, batch: int, seq: int,
                 seed: int = 0):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.cfg, self.batch, self.seq, self.seed = cfg, batch, seq, seed
        self.step = 0
        self.n_windows = max(1, (len(self.tokens) - 1) // seq)

    def get_state(self):
        return {"step": self.step, "seed": self.seed}

    def set_state(self, state):
        self.step = int(state["step"])
        self.seed = int(state["seed"])

    def batch_at(self, t: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, t]))
        idx = rng.integers(0, self.n_windows, size=(self.batch,))
        S = self.seq
        toks = np.stack([self.tokens[i * S:(i + 1) * S] for i in idx])
        labels = np.stack([self.tokens[i * S + 1:(i + 1) * S + 1] for i in idx])
        pos = np.broadcast_to(np.arange(S, dtype=np.int32),
                              (self.batch, S)).copy()
        return {"tokens": toks.astype(np.int32),
                "labels": labels.astype(np.int32), "positions": pos}

    def __iter__(self):
        while True:
            t = self.step
            self.step += 1
            yield t, self.batch_at(t)


class Prefetcher:
    """Host-thread double buffering around any (step, batch) iterator."""

    def __init__(self, source, depth: int = 2, shardings=None):
        self.source = source
        self.shardings = shardings
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._work, daemon=True)
        self.thread.start()

    def _work(self):
        for item in self.source:
            if self._stop.is_set():
                break
            t, batch = item
            if self.shardings is not None:
                batch = {k: jax.device_put(v, self.shardings.get(k))
                         for k, v in batch.items()}
            while not self._stop.is_set():
                try:
                    self.q.put((t, batch), timeout=0.25)
                    break
                except queue.Full:
                    continue

    def __iter__(self):
        while not self._stop.is_set():
            yield self.q.get()

    def get_state(self):
        return self.source.get_state()

    def set_state(self, s):
        return self.source.set_state(s)

    def close(self):
        self._stop.set()
