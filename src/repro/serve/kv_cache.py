"""Pooled KV-cache utilities: capacity accounting + shardings.

The cache layout itself is built by models/transformer.init_caches /
cache_specs (sequence dim striped over the 'model' axis = the paper's
pooled memory applied to inference).  This module answers the sizing
questions: does a cache fit one chip?  the pool?  what does pooling buy?
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import MeshPlan, ModelConfig
from repro import hw


@dataclasses.dataclass(frozen=True)
class CacheFootprint:
    total_bytes: float           # global cache bytes
    per_device_unpooled: float   # if each chip held its batch shard fully
    per_device_pooled: float     # with the sequence dim striped over 'model'

    def fits(self, chip: hw.Chip = hw.TPU_V5E) -> bool:
        return self.per_device_pooled <= chip.hbm_bytes


def kv_cache_footprint(cfg: ModelConfig, plan: MeshPlan, batch: int,
                       seq: int, dtype_bytes: int = 2) -> CacheFootprint:
    if cfg.is_ssm:
        conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        per_layer = batch * ((cfg.ssm_conv_width - 1) * conv_dim +
                             cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state)
        total = cfg.num_layers * per_layer * dtype_bytes
    else:
        K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        n_sites = cfg.num_layers
        if cfg.is_hybrid:
            n_sites = cfg.num_layers // cfg.hybrid_attn_every  # shared sites
            conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
            ssm_bytes = cfg.num_layers * batch * (
                (cfg.ssm_conv_width - 1) * conv_dim +
                cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state) * dtype_bytes
        else:
            ssm_bytes = 0.0
        total = n_sites * 2 * batch * seq * K * hd * dtype_bytes + \
            (ssm_bytes if cfg.is_hybrid else 0)
    dp = plan.axis_size("data") * plan.axis_size("pod")
    tp = plan.axis_size("model")
    b_shard = dp if batch % dp == 0 else 1
    s_shard = tp if seq % tp == 0 else 1
    return CacheFootprint(
        total_bytes=total,
        per_device_unpooled=total / b_shard,
        per_device_pooled=total / (b_shard * s_shard),
    )
