"""Pooled KV-cache utilities: capacity accounting + shardings.

The cache layout itself is built by models/transformer.init_caches /
cache_specs (sequence dim striped over the 'model' axis = the paper's
pooled memory applied to inference).  This module answers the sizing
questions: does a cache fit one chip?  the pool?  what does pooling buy?
Sizing is queried per-tier: :func:`cache_tier_report` prices the cache
against the serving runtime's :class:`~repro.core.tiers.MemoryTier`
capacity contract (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import MeshPlan, ModelConfig
from repro import hw


@dataclasses.dataclass(frozen=True)
class CacheFootprint:
    total_bytes: float           # global cache bytes
    per_device_unpooled: float   # if each chip held its batch shard fully
    per_device_pooled: float     # with the sequence dim striped over 'model'

    def fits(self, chip: hw.Chip = hw.TPU_V5E) -> bool:
        return self.per_device_pooled <= chip.hbm_bytes


def kv_cache_footprint(cfg: ModelConfig, plan: MeshPlan, batch: int,
                       seq: int, dtype_bytes: int = 2) -> CacheFootprint:
    if cfg.is_ssm:
        conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        per_layer = batch * ((cfg.ssm_conv_width - 1) * conv_dim +
                             cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state)
        total = cfg.num_layers * per_layer * dtype_bytes
    else:
        K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        n_sites = cfg.num_layers
        if cfg.is_hybrid:
            n_sites = cfg.num_layers // cfg.hybrid_attn_every  # shared sites
            conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
            ssm_bytes = cfg.num_layers * batch * (
                (cfg.ssm_conv_width - 1) * conv_dim +
                cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state) * dtype_bytes
        else:
            ssm_bytes = 0.0
        total = n_sites * 2 * batch * seq * K * hd * dtype_bytes + \
            (ssm_bytes if cfg.is_hybrid else 0)
    dp = plan.axis_size("data") * plan.axis_size("pod")
    tp = plan.axis_size("model")
    b_shard = dp if batch % dp == 0 else 1
    s_shard = tp if seq % tp == 0 else 1
    return CacheFootprint(
        total_bytes=total,
        per_device_unpooled=total / b_shard,
        per_device_pooled=total / (b_shard * s_shard),
    )


# ---------------------------------------------------------------------------
def cache_tier_report(cfg: ModelConfig, runtime, batch: int, seq: int,
                      dtype_bytes: int = 2,
                      chip: hw.Chip = None) -> Dict[str, Any]:
    """Price a serving cache against the runtime's memory tier.

    ``runtime``: a :class:`repro.core.runtime.MemoryRuntime`.  The cache
    layout itself (models/transformer.cache_specs) always stripes the
    sequence dim over the mesh — pooled HBM applied to inference — so the
    cache occupies ``per_device_pooled`` bytes of local HBM regardless of
    the training policy; ``fits`` is that number against chip HBM.  The
    tier contract supplies the context around it: what one device could
    address through the backing store (``capacity_bytes``) and what a
    decode step's cache read costs against the tier bandwidth.
    """
    from repro.core.pool import PoolAccountant

    chip = chip if chip is not None else runtime.chip
    fp = kv_cache_footprint(cfg, runtime.plan, batch, seq, dtype_bytes)
    acct = PoolAccountant(runtime.plan, runtime.memory)
    tier = runtime.tier
    per_dev = fp.per_device_pooled
    # one decode step touches the whole cache shard once (attention reads)
    bw = tier.bandwidth(runtime.plan, chip)
    return {
        "tier": tier.describe(),
        "total_bytes": fp.total_bytes,
        "per_device_bytes": per_dev,
        "capacity_bytes": tier.capacity(acct),
        "fits": per_dev <= chip.hbm_bytes,
        "pooling_gain": (fp.per_device_unpooled / per_dev) if per_dev else 1.0,
        "decode_read_s": per_dev / bw if bw > 0 else 0.0,
    }


# ---------------------------------------------------------------------------
#: auto-sizing defaults (KVCacheManager): bounded so a CPU smoke twin stays
#: cheap; production callers raise them or pass sizes explicitly
DEFAULT_MAX_LEN = 512
DEFAULT_MAX_BATCH = 8
DEFAULT_HBM_FRAC = 0.5          # fraction of addressable bytes given to KV


def derive_cache_shape(cfg: ModelConfig, runtime, batch: int = None,
                       max_len: int = None, *,
                       page_size: int = None,
                       hbm_frac: float = DEFAULT_HBM_FRAC,
                       max_batch: int = DEFAULT_MAX_BATCH,
                       default_max_len: int = DEFAULT_MAX_LEN,
                       dtype_bytes: int = 2,
                       chip: hw.Chip = None) -> Dict[str, Any]:
    """Auto-size the decode batch / cache length from the tier report.

    Fills in whichever of ``batch`` / ``max_len`` the caller left
    unspecified — ``None`` and ``0`` both mean "solve for it", so an
    explicit 0 can no longer leak through the ``max_len`` halving loop as
    a phantom one-slot cache while the returned batch stays 0.  The
    serving tier's ``capacity_bytes`` (clamped to chip HBM — resident
    slots still occupy device memory) funds ``hbm_frac`` worth of cache;
    ``max_len`` halves from ``default_max_len`` until one slot fits, then
    ``batch`` packs as many slots as the budget holds (capped so the jit'd
    decode batch stays bounded).

    With ``page_size`` the cache is sized in **pages** instead of slots:
    ``max_len`` is rounded to a multiple of the page size (explicit values
    round up — the caller asked to fit that many rows; derived values
    round down into the budget, floored at ONE page: a sub-page cache is
    unusable, so a starvation budget combined with a large ``page_size``
    can exceed the budget — visible as ``fits=False`` in the report) and
    the report gains ``page_size``, ``pages_per_slot`` and ``num_pages``
    (= batch x pages_per_slot, the page-pool population before
    overcommit).

    Returns ``{"batch", "max_len", "report"}`` with the
    :func:`cache_tier_report` priced at the final shape.
    """
    chip = chip if chip is not None else runtime.chip
    batch = batch or None           # explicit 0 == None == solve for it
    max_len = max_len or None
    if page_size is not None and page_size < 1:
        raise ValueError(f"page_size must be >= 1: {page_size}")
    from repro.core.pool import PoolAccountant
    acct = PoolAccountant(runtime.plan, runtime.memory)
    capacity = runtime.tier.capacity(acct)
    budget = hbm_frac * min(capacity, chip.hbm_bytes)

    def slot_bytes(n_slots: int, L: int) -> float:
        return kv_cache_footprint(cfg, runtime.plan, n_slots, L,
                                  dtype_bytes).total_bytes

    def round_pages(L: int, up: bool) -> int:
        if page_size is None:
            return L
        if up:
            return page_size * -(-L // page_size)
        return max(page_size, page_size * (L // page_size))

    if max_len is None:
        L = default_max_len
        while L > 16 and slot_bytes(batch or 1, L) > budget:
            L //= 2
        max_len = round_pages(L, up=False)
    else:
        max_len = round_pages(max_len, up=True)
    if batch is None:
        one = max(slot_bytes(1, max_len), 1.0)
        batch = int(max(1, min(max_batch, budget // one)))
    report = cache_tier_report(cfg, runtime, batch, max_len, dtype_bytes,
                               chip)
    if page_size is not None:
        pages_per_slot = max_len // page_size
        report.update(page_size=page_size, pages_per_slot=pages_per_slot,
                      num_pages=batch * pages_per_slot)
    return {"batch": batch, "max_len": max_len, "report": report}
