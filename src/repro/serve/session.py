"""Session: the streaming result API of the serving stack (DESIGN.md §6).

A :class:`Session` is the engine-side lifecycle object for one submitted
:class:`~repro.serve.engine.Request`: it carries the generated-token
stream, the scheduler state (queued / running / paused / finished), the
cache residency (slot index, cached length) and the finish reason —
replacing the old pattern of mutating ``Request.out_tokens`` from inside
``Engine.step``.

Streaming: every generated token flows through :meth:`emit`, which appends
to the stream and invokes the optional ``on_token`` callback — the hook a
serving frontend uses to push tokens to a client mid-decode.  The legacy
``Request.out_tokens`` list is kept as an *alias* of the session stream
(same list object), so pre-Session callers keep working unchanged.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, List, Optional


class SessionState(enum.Enum):
    QUEUED = "queued"          # submitted, no cache slot yet
    RUNNING = "running"        # resident in a decode slot
    PAUSED = "paused"          # preempted: KV spilled to the secondary tier
    FINISHED = "finished"      # retired (see finish_reason)
    CANCELLED = "cancelled"


#: finish reasons
FINISH_EOS = "eos"                  # sampled the request's eos_id
FINISH_LENGTH = "length"            # hit max_new_tokens
FINISH_CACHE_FULL = "cache_full"    # cache slot exhausted (max_len rows)
FINISH_REJECTED = "rejected"        # prompt does not fit a cache slot
FINISH_QUOTA = "quota"              # exceeds the tenant quota outright
FINISH_CANCELLED = "cancelled"


@dataclasses.dataclass
class Session:
    """Lifecycle + token stream of one request inside the engine."""

    request: "Request"                 # noqa: F821 — serve.engine.Request
    seq: int                           # admission ticket (FCFS order)
    state: SessionState = SessionState.QUEUED
    tokens: List[int] = dataclasses.field(default_factory=list)
    finish_reason: Optional[str] = None
    on_token: Optional[Callable[["Session", int], None]] = None
    # cache residency (owned by KVCacheManager)
    slot: Optional[int] = None
    length: int = 0                    # tokens currently cached (slot/spill)
    steps_since_admit: int = 0         # preemption quantum bookkeeping
    preemptions: int = 0               # times this session was paused
    emitted: int = 0                   # high-water mark of on_token notifies

    def __post_init__(self):
        # alias the legacy output list: one list, two names
        if self.request.out_tokens:
            self.tokens = self.request.out_tokens
        else:
            self.request.out_tokens = self.tokens

    # ------------------------------------------------------------------
    @property
    def uid(self) -> int:
        return self.request.uid

    @property
    def priority(self) -> int:
        return getattr(self.request, "priority", 0)

    @property
    def tenant(self) -> str:
        return getattr(self.request, "tenant", "default")

    @property
    def deadline(self) -> float:
        """EDF rank: the request's deadline in engine steps (inf: none)."""
        d = getattr(self.request, "deadline", None)
        return float("inf") if d is None else float(d)

    @property
    def remaining(self) -> int:
        """SRPT rank: decode tokens still owed (never negative)."""
        return max(0, self.request.max_new_tokens - len(self.tokens))

    @property
    def done(self) -> bool:
        return self.state in (SessionState.FINISHED, SessionState.CANCELLED)

    @property
    def resident(self) -> bool:
        return self.slot is not None

    # ------------------------------------------------------------------
    def emit(self, token: int) -> None:
        """Append one generated token to the stream (and notify).

        ``on_token`` fires only for stream positions not yet notified:
        when a failed handoff rewinds ``tokens`` and the session is
        replayed, re-generated positions are appended silently instead
        of streaming the same token to the client twice.
        """
        self.tokens.append(token)
        self.steps_since_admit += 1
        if self.on_token is not None and len(self.tokens) > self.emitted:
            self.emitted = len(self.tokens)
            self.on_token(self, token)

    def rewind(self) -> None:
        """Reset to freshly-queued for a requeue/replay.

        Clears the token stream in place (preserving the
        ``Request.out_tokens`` alias) and drops the cache residency; the
        ``emitted`` high-water mark deliberately survives so a replayed
        session never streams the same position to the client twice."""
        del self.tokens[:]
        self.length = 0
        self.slot = None
        self.state = SessionState.QUEUED

    def finish(self, reason: str) -> None:
        self.state = (SessionState.CANCELLED if reason == FINISH_CANCELLED
                      else SessionState.FINISHED)
        self.finish_reason = reason

    def cancel(self) -> None:
        self.finish(FINISH_CANCELLED)

    def result(self) -> List[int]:
        """The generated tokens so far (complete once ``done``)."""
        return list(self.tokens)

    def __repr__(self) -> str:
        return (f"Session(uid={self.uid}, state={self.state.value}, "
                f"slot={self.slot}, len={self.length}, "
                f"tokens={len(self.tokens)})")
