"""Engine: thin facade over the Scheduler / KVCacheManager / Session APIs.

The paper's technique applied to inference (DESIGN.md §6): the KV cache is
sharded over the mesh's pooled HBM (sequence dim over 'model'), so a
524k-token cache that exceeds one chip's memory serves from the pool with
the decode attention executed *distributed* — no cache migration, the
compute goes to the data.

The serving stack is three composable APIs; the engine only wires them to
the model's prefill/decode compute and the sampler:

* :class:`~repro.serve.scheduler.Scheduler` — admission, continuous
  batching, preemption (pluggable: fcfs / priority / fair).
* :class:`~repro.serve.cache_manager.KVCacheManager` — slot allocation,
  tier-report auto-sizing of ``batch``/``max_len``, cold-slot spill to a
  secondary memory tier and fetch-back on resume.
* :class:`~repro.serve.session.Session` — the streaming result API
  (token stream + lifecycle + finish reason) returned by :meth:`submit`.

Back-compat: the legacy ``Engine(model, params, batch, max_len)``
constructor still works (sizes are simply explicit instead of derived),
and ``Request.out_tokens`` stays populated — it aliases the session's
token stream.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.serve.cache_manager import KVCacheManager
from repro.serve.scheduler import Scheduler, build_scheduler
from repro.serve.session import (FINISH_CACHE_FULL, FINISH_EOS,
                                 FINISH_LENGTH, FINISH_REJECTED, Session,
                                 SessionState)

log = logging.getLogger(__name__)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (S_prompt,) int32
    max_new_tokens: int = 16
    eos_id: int = -1                   # -1: never
    priority: int = 0                  # PriorityScheduler rank (higher first)
    out_tokens: Optional[List[int]] = None

    def __post_init__(self):
        if self.out_tokens is None:
            self.out_tokens = []


class Engine:
    """Facade: scheduler + cache manager + sampler behind one object.

    ``batch`` / ``max_len`` may be omitted — the cache manager then sizes
    them from the serving tier's ``cache_tier_report`` (how much cache the
    tier lets one device address).  The legacy positional signature
    ``Engine(model, params, batch, max_len)`` is unchanged.
    """

    def __init__(self, model: Model, params,
                 batch: Optional[int] = None,
                 max_len: Optional[int] = None,
                 temperature: float = 0.0, seed: int = 0,
                 scheduler: Union[str, Scheduler] = "fcfs",
                 spill: Union[str, Any, None] = "spill",
                 **cache_kwargs):
        self.model = model
        self.params = params
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)

        self.scheduler: Scheduler = (build_scheduler(scheduler)
                                     if isinstance(scheduler, str)
                                     else scheduler)
        self.cache = KVCacheManager(model, batch, max_len, spill=spill,
                                    **cache_kwargs)
        self.batch, self.max_len = self.cache.batch, self.cache.max_len
        self.kv_report = self.cache.report
        if not self.kv_report["fits"]:
            log.warning("kv cache exceeds per-device HBM: %.2f GB/device "
                        "(tier %s could address %.2f GB) — expect OOM at "
                        "this batch/max_len",
                        self.kv_report["per_device_bytes"] / 1e9,
                        self.kv_report["tier"],
                        self.kv_report["capacity_bytes"] / 1e9)

        self.sessions: List[Session] = []      # every submission, in order
        self.finished: List[Request] = []      # legacy result list
        self._seq = 0
        self._decode = jax.jit(model.decode_step)

        def prefill_one(params, caches, tokens, positions, slot):
            """Prefill one sequence into slot ``slot`` of the batched cache."""
            ctx = model.ctx("prefill")
            from repro.models import transformer as tfm
            one_cache = tfm.slot_cache(caches, slot)
            h, new_cache = tfm.forward_serve(
                params, ctx, tokens, positions, one_cache,
                cache_index=jnp.zeros((), jnp.int32))
            logits = tfm.unembed(params, ctx, h[:, -1:, :])[:, 0, :]
            caches = tfm.merge_slot_cache(caches, new_cache, slot)
            return logits[0], caches

        self._prefill = jax.jit(prefill_one)

    # ------------------------------------------------------------------
    def submit(self, req: Request, on_token=None) -> Session:
        """Queue a request; returns its :class:`Session` (token stream)."""
        sess = Session(request=req, seq=self._seq, on_token=on_token)
        self._seq += 1
        self.sessions.append(sess)
        self.scheduler.submit(sess)
        return sess

    @property
    def pending(self) -> List[Request]:
        """Legacy view: requests waiting for a slot (queued or paused)."""
        return [s.request for s in self.scheduler.waiting()]

    def _sample(self, logits: jax.Array) -> int:
        if self.temperature <= 0:
            return int(jnp.argmax(logits))
        self.key, sub = jax.random.split(self.key)
        return int(jax.random.categorical(sub, logits / self.temperature))

    def _retire(self, sess: Session, reason: str) -> None:
        sess.finish(reason)
        self.cache.release(sess)
        self.scheduler.on_retire(sess)
        self.finished.append(sess.request)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine step: sweep cancellations, preempt, admit, then one
        decode step for every resident session.  Returns the number of
        resident sessions."""
        self._sweep_cancelled()
        self._preempt()
        self._admit()

        slots = self.cache.slots
        active = [i for i, s in enumerate(slots) if s is not None]
        if not active:
            return 0

        # batched decode: every resident session advances by one token;
        # idle slots decode a dummy token at index 0 (masked out).  Mixed
        # cache lengths decode per unique-length group: the shared
        # cache_index must match each slot's write position.
        tok = np.zeros((self.batch, 1), np.int32)
        for i in active:
            tok[i, 0] = slots[i].tokens[-1]
        groups: Dict[int, List[int]] = {}
        for i in active:
            groups.setdefault(slots[i].length, []).append(i)
        for length, idxs in sorted(groups.items()):
            pos = self._positions(1, length, self.batch)
            logits, new_caches = self._decode(
                self.params, jnp.asarray(tok), pos, self.cache.caches,
                jnp.int32(length))
            # merge: only the slots of this length group take the new cache
            # (other slots' caches must not see the dummy write at `length`)
            mask = np.zeros((self.batch,), bool)
            mask[idxs] = True
            m = jnp.asarray(mask)

            def merge(old, new):
                # cache leaves are (n_groups, B, ...): batch is dim 1
                mm = m.reshape((1, self.batch) + (1,) * (old.ndim - 2))
                return jnp.where(mm, new.astype(old.dtype), old)

            self.cache.caches = jax.tree.map(merge, self.cache.caches,
                                             new_caches)
            for i in idxs:
                sess = slots[i]
                nxt = self._sample(logits[i])
                sess.emit(nxt)
                sess.length += 1
                if sess.done:
                    # cancelled from the on_token callback mid-stream
                    self.cache.release(sess)
                    self.scheduler.on_retire(sess)
                elif nxt == sess.request.eos_id:
                    self._retire(sess, FINISH_EOS)
                elif len(sess.tokens) >= sess.request.max_new_tokens:
                    self._retire(sess, FINISH_LENGTH)
                elif sess.length >= self.max_len:
                    # the NEXT decode would write past the last cache row;
                    # this row itself is used (was an off-by-one retire)
                    self._retire(sess, FINISH_CACHE_FULL)
        return len(active)

    # ------------------------------------------------------------------
    def _sweep_cancelled(self) -> None:
        """Honour out-of-band Session.cancel(): free the slot of a
        cancelled resident session and drop the parked cache (returning
        its SpillTier budget) of one cancelled while paused.  Queued
        cancellations are dropped lazily by the scheduler's next_ready."""
        for sess in self.cache.running():
            if sess.done:
                self.cache.release(sess)
                self.scheduler.on_retire(sess)
        self.cache.sweep_cancelled()

    def _preempt(self) -> None:
        """Pause running sessions when the scheduler ranks waiting work
        above them (their KV spills to the secondary tier)."""
        if self.cache.spill_runtime is None:
            return
        want = len(self.scheduler.waiting())
        freed = self.cache.num_free()
        while freed < want:
            victim = self.scheduler.preempt_victim(self.cache.running())
            if victim is None:
                break
            self.cache.pause(victim)
            self.scheduler.requeue(victim)
            freed += 1

    def _admit(self) -> None:
        """Fill free slots in scheduler order: a popped session that was
        paused resumes via a spill-tier fetch, a fresh one prefills."""
        while True:
            slot = self.cache.free_slot()
            if slot is None:
                return
            sess = self.scheduler.next_ready()
            if sess is None:
                return
            if sess.state is SessionState.PAUSED:
                self.cache.resume(sess, slot)
                continue
            prompt = np.asarray(sess.request.prompt)
            if not self.cache.fits_prompt(len(prompt)):
                log.warning("req %d: prompt of %d tokens does not fit a "
                            "%d-row cache slot — rejected",
                            sess.uid, len(prompt), self.max_len)
                self._retire(sess, FINISH_REJECTED)
                continue
            toks = jnp.asarray(prompt, jnp.int32)[None, :]
            S = toks.shape[1]
            pos = self._positions(S, 0, 1)
            logits, self.cache.caches = self._prefill(
                self.params, self.cache.caches, toks, pos, slot)
            self.cache.bind(slot, sess, S)
            nxt = self._sample(logits)
            sess.emit(nxt)
            if nxt == sess.request.eos_id:
                self._retire(sess, FINISH_EOS)
            elif len(sess.tokens) >= sess.request.max_new_tokens:
                self._retire(sess, FINISH_LENGTH)

    # ------------------------------------------------------------------
    def _positions(self, S: int, offset: int, batch: int):
        if self.model.cfg.mrope_sections:
            return jnp.broadcast_to(
                jnp.arange(offset, offset + S, dtype=jnp.int32)[None, None],
                (3, batch, S))
        return jnp.broadcast_to(
            jnp.arange(offset, offset + S, dtype=jnp.int32)[None],
            (batch, S))

    def run(self, max_steps: int = 10_000) -> List[Request]:
        for _ in range(max_steps):
            if self.step() == 0 and not self.scheduler.has_waiting():
                break
        return self.finished

    # ------------------------------------------------------------------
    @property
    def caches(self):
        """Legacy alias of the manager-owned cache tree."""
        return self.cache.caches

    def traffic_report(self) -> Dict[str, Any]:
        """Spill-tier byte accounting (cold-slot kv_stash / kv_fetch)."""
        return self.cache.traffic_report()

    def describe(self) -> str:
        return (f"engine[{self.cache.describe()} "
                f"sched={self.scheduler.describe()}]")
