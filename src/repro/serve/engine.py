"""Batched serving engine: continuous prefill/decode over pooled KV caches.

The paper's technique applied to inference (DESIGN.md §6): the KV cache is
sharded over the mesh's pooled HBM (sequence dim over 'model'), so a
524k-token cache that exceeds one chip's memory serves from the pool with
the decode attention executed *distributed* (flash-decode: partial softmax
per shard + psum) — no cache migration, the compute goes to the data.

The engine itself is a straightforward batched scheduler: fixed decode
batch slots, prompt prefill into a free slot, greedy/temperature sampling,
EOS / max-token retirement.  Designed to be driven step-by-step (tests) or
via ``run()``.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.serve.kv_cache import cache_tier_report

log = logging.getLogger(__name__)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (S_prompt,) int32
    max_new_tokens: int = 16
    eos_id: int = -1                   # -1: never
    out_tokens: Optional[List[int]] = None

    def __post_init__(self):
        if self.out_tokens is None:
            self.out_tokens = []


@dataclasses.dataclass
class SlotState:
    req: Optional[Request] = None
    length: int = 0                    # tokens currently in this slot's cache


class Engine:
    """Fixed-slot batched engine.  batch = number of concurrent sequences;
    max_len = cache capacity per sequence."""

    def __init__(self, model: Model, params, batch: int, max_len: int,
                 temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.batch, self.max_len = batch, max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        # pooled-KV sizing is queried per-tier (DESIGN.md §6): the serving
        # runtime's tier decides what one device can address for the cache
        self.kv_report = cache_tier_report(model.cfg, model.runtime,
                                           batch, max_len)
        from repro.core.runtime import fmt_bytes
        log.info("kv cache [%s]: %s total, %s/device, fits=%s",
                 self.kv_report["tier"],
                 fmt_bytes(self.kv_report["total_bytes"]),
                 fmt_bytes(self.kv_report["per_device_bytes"]),
                 self.kv_report["fits"])
        if not self.kv_report["fits"]:
            log.warning("kv cache exceeds per-device HBM: %.2f GB/device "
                        "(tier %s could address %.2f GB) — expect OOM at "
                        "this batch/max_len",
                        self.kv_report["per_device_bytes"] / 1e9,
                        self.kv_report["tier"],
                        self.kv_report["capacity_bytes"] / 1e9)
        self.caches = model.init_cache(batch, max_len)
        self.slots = [SlotState() for _ in range(batch)]
        self.pending: List[Request] = []
        self.finished: List[Request] = []
        self._decode = jax.jit(model.decode_step)
        cfg = model.cfg

        def prefill_one(params, caches, tokens, positions, slot):
            """Prefill one sequence into slot `slot` of the batched cache."""
            ctx = model.ctx("prefill")
            from repro.models import transformer as tfm
            one_cache = jax.tree.map(
                lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1),
                caches)
            h, new_cache = tfm.forward_serve(
                params, ctx, tokens, positions, one_cache,
                cache_index=jnp.zeros((), jnp.int32))
            logits = tfm.unembed(params, ctx, h[:, -1:, :])[:, 0, :]
            caches = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_slice_in_dim(
                    c, n.astype(c.dtype), slot, axis=1),
                caches, new_cache)
            return logits[0], caches

        self._prefill = jax.jit(prefill_one)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s.req is None:
                return i
        return None

    def _sample(self, logits: jax.Array) -> int:
        if self.temperature <= 0:
            return int(jnp.argmax(logits))
        self.key, sub = jax.random.split(self.key)
        return int(jax.random.categorical(sub, logits / self.temperature))

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine step: admit pending prompts, then one decode step for
        every active slot.  Returns number of active slots."""
        # admit
        while self.pending:
            slot = self._free_slot()
            if slot is None:
                break
            req = self.pending.pop(0)
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            S = toks.shape[1]
            pos = self._positions(S, 0, 1)
            logits, self.caches = self._prefill(
                self.params, self.caches, toks, pos, slot)
            nxt = self._sample(logits)
            req.out_tokens.append(nxt)
            self.slots[slot] = SlotState(req=req, length=S)

        active = [i for i, s in enumerate(self.slots) if s.req is not None]
        if not active:
            return 0

        # batched decode: every active slot advances by one token; idle
        # slots decode a dummy token at index 0 (masked out).
        tok = np.zeros((self.batch, 1), np.int32)
        for i in active:
            tok[i, 0] = self.slots[i].req.out_tokens[-1]
        # single shared index is the max length (cache updates per-slot use
        # the same index; slots admitted together share it). For mixed
        # lengths we decode per unique length group.
        groups: Dict[int, List[int]] = {}
        for i in active:
            groups.setdefault(self.slots[i].length, []).append(i)
        for length, idxs in groups.items():
            pos = self._positions(1, length, self.batch)
            logits, new_caches = self._decode(
                self.params, jnp.asarray(tok), pos, self.caches,
                jnp.int32(length))
            # merge: only the slots of this length group take the new cache
            # (other slots' caches must not see the dummy write at `length`)
            mask = np.zeros((self.batch,), bool)
            mask[idxs] = True
            m = jnp.asarray(mask)

            def merge(old, new):
                # cache leaves are (n_groups, B, ...): batch is dim 1
                mm = m.reshape((1, self.batch) + (1,) * (old.ndim - 2))
                return jnp.where(mm, new.astype(old.dtype), old)

            self.caches = jax.tree.map(merge, self.caches, new_caches)
            for i in idxs:
                s = self.slots[i]
                nxt = self._sample(logits[i])
                s.req.out_tokens.append(nxt)
                s.length += 1
                done = (len(s.req.out_tokens) >= s.req.max_new_tokens
                        or nxt == s.req.eos_id
                        or s.length + 1 >= self.max_len)
                if done:
                    self.finished.append(s.req)
                    self.slots[i] = SlotState()
        return len(active)

    def _positions(self, S: int, offset: int, batch: int):
        if self.model.cfg.mrope_sections:
            return jnp.broadcast_to(
                jnp.arange(offset, offset + S, dtype=jnp.int32)[None, None],
                (3, batch, S))
        return jnp.broadcast_to(
            jnp.arange(offset, offset + S, dtype=jnp.int32)[None],
            (batch, S))

    def run(self, max_steps: int = 10_000) -> List[Request]:
        for _ in range(max_steps):
            if self.step() == 0 and not self.pending:
                break
        return self.finished
