"""Engine: thin facade over the Scheduler / KVCacheManager / Session APIs.

The paper's technique applied to inference (DESIGN.md §6): the KV cache is
sharded over the mesh's pooled HBM (sequence dim over 'model'), so a
524k-token cache that exceeds one chip's memory serves from the pool with
the decode attention executed *distributed* — no cache migration, the
compute goes to the data.

The serving stack is three composable APIs; the engine only wires them to
the model's prefill/decode compute and the sampler:

* :class:`~repro.serve.scheduler.Scheduler` — admission, continuous
  batching, preemption (pluggable: fcfs / priority / fair / srpt /
  deadline).
* :class:`~repro.serve.cache_manager.KVCacheManager` — slot allocation,
  tier-report auto-sizing of ``batch``/``max_len``, cold-KV spill to a
  secondary memory tier.  ``page_size`` switches the storage model to the
  :class:`~repro.serve.cache_manager.PagedKVCacheManager`: sessions hold
  fixed-size pages of a shared pool, preemption marks them cold in place,
  and spill happens lazily per page through a per-tenant codec.
* :class:`~repro.serve.session.Session` — the streaming result API
  (token stream + lifecycle + finish reason) returned by :meth:`submit`.

Multi-tenant admission (``quota=``) is enforced here, at the facade: a
session is charged its worst-case page reservation against its tenant's
:class:`~repro.serve.quota.TenantQuota` before it may take a slot —
transiently over-budget tenants are deferred (other tenants admit past
them), impossible requests are rejected with finish reason ``"quota"``.

Back-compat: the legacy ``Engine(model, params, batch, max_len)``
constructor still works (sizes are simply explicit instead of derived),
and ``Request.out_tokens`` stays populated — it aliases the session's
token stream.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.model import Model
from repro.serve.cache_manager import KVCacheManager, PagedKVCacheManager
from repro.serve.paging import PageError
from repro.serve.quota import QuotaManager, TenantQuota
from repro.serve.scheduler import Scheduler, build_scheduler
from repro.serve.session import (FINISH_CACHE_FULL, FINISH_EOS,
                                 FINISH_LENGTH, FINISH_QUOTA,
                                 FINISH_REJECTED, Session, SessionState)

log = logging.getLogger(__name__)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (S_prompt,) int32
    max_new_tokens: int = 16
    eos_id: int = -1                   # -1: never
    priority: int = 0                  # PriorityScheduler rank (higher first)
    tenant: str = "default"            # quota / codec bucket
    deadline: Optional[float] = None   # DeadlineScheduler: absolute step
    out_tokens: Optional[List[int]] = None

    def __post_init__(self):
        if self.out_tokens is None:
            self.out_tokens = []


def _masked_merge(mask: jax.Array):
    """Leaf merge taking ``new`` on masked batch rows (cache batch dim 1)."""

    def merge(old, new):
        mm = mask.reshape((1, mask.shape[0]) + (1,) * (old.ndim - 2))
        return jnp.where(mm, new.astype(old.dtype), old)

    return merge


class Engine:
    """Facade: scheduler + cache manager + quotas + sampler in one object.

    ``batch`` / ``max_len`` may be omitted — the cache manager then sizes
    them from the serving tier's ``cache_tier_report`` (how much cache the
    tier lets one device address).  The legacy positional signature
    ``Engine(model, params, batch, max_len)`` is unchanged.
    """

    def __init__(self, model: Model, params,
                 batch: Optional[int] = None,
                 max_len: Optional[int] = None,
                 temperature: float = 0.0, seed: int = 0,
                 scheduler: Union[str, Scheduler] = "fcfs",
                 spill: Union[str, Any, None] = "spill",
                 page_size: Optional[int] = None,
                 pages: Optional[int] = None,
                 codec_kernel: bool = False,
                 quota: Union[QuotaManager, TenantQuota,
                              Dict[str, TenantQuota], None] = None,
                 **cache_kwargs):
        self.model = model
        self.params = params
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)

        self.scheduler: Scheduler = (build_scheduler(scheduler)
                                     if isinstance(scheduler, str)
                                     else scheduler)
        if quota is None or isinstance(quota, QuotaManager):
            self.quota: Optional[QuotaManager] = quota
        elif isinstance(quota, TenantQuota):
            self.quota = QuotaManager(default_quota=quota)
        else:
            self.quota = QuotaManager(dict(quota))

        if page_size:
            codec_for = self.quota.codec_for if self.quota else None
            self.cache: KVCacheManager = PagedKVCacheManager(
                model, batch, max_len, spill=spill, page_size=page_size,
                pages=pages, codec_for=codec_for,
                codec_kernel=codec_kernel, **cache_kwargs)
        else:
            self.cache = KVCacheManager(model, batch, max_len, spill=spill,
                                        **cache_kwargs)
        self.batch, self.max_len = self.cache.batch, self.cache.max_len
        self.kv_report = self.cache.report
        if not self.kv_report["fits"]:
            log.warning("kv cache exceeds per-device HBM: %.2f GB/device "
                        "(tier %s could address %.2f GB) — expect OOM at "
                        "this batch/max_len",
                        self.kv_report["per_device_bytes"] / 1e9,
                        self.kv_report["tier"],
                        self.kv_report["capacity_bytes"] / 1e9)

        self.sessions: List[Session] = []      # every submission, in order
        self.finished: List[Request] = []      # legacy result list
        self._seq = 0
        self._by_uid: Dict[int, Session] = {}
        self._quota_charged: Dict[int, tuple] = {}
        self._build_compute()

    # ------------------------------------------------------------------
    def _build_compute(self) -> None:
        """jit the decode/prefill paths against the manager's storage."""
        model = self.model
        self._decode = jax.jit(model.decode_step)

        def prefill_one(params, caches, tokens, positions, slot):
            """Prefill one sequence into slot ``slot`` of the batched cache."""
            ctx = model.ctx("prefill")
            one_cache = tfm.slot_cache(caches, slot)
            h, new_cache = tfm.forward_serve(
                params, ctx, tokens, positions, one_cache,
                cache_index=jnp.zeros((), jnp.int32))
            logits = tfm.unembed(params, ctx, h[:, -1:, :])[:, 0, :]
            caches = tfm.merge_slot_cache(caches, new_cache, slot)
            return logits[0], caches

        self._prefill = jax.jit(prefill_one)
        if not self.cache.paged:
            return

        # paged twins: gather the contiguous view from the page pool, run
        # the same compute, scatter written pages back (non-group slots
        # route to the scratch page — the masked-dummy-write semantics)
        scratch = self.cache.scratch_id

        page = self.cache.page_size

        def decode_paged(params, pool, slot_tree, page_map, tok, pos, idx,
                         mask):
            view = tfm.gather_pages(pool, slot_tree, page_map)
            logits, new = model.decode_step(params, tok, pos, view, idx)
            # one row written per slot -> write back only its page
            wp = idx // page
            target = jnp.where(mask, jnp.take(page_map, wp, axis=1), scratch)
            pool = tfm.scatter_one_page(pool, new, target, wp * page, page)
            _, new_slot = tfm.split_paged(new)
            slot_tree = jax.tree.map(_masked_merge(mask), slot_tree,
                                     new_slot)
            return logits, pool, slot_tree

        def prefill_paged(params, pool, slot_tree, page_map, tokens,
                          positions, slot, mask):
            ctx = model.ctx("prefill")
            view = tfm.gather_pages(pool, slot_tree, page_map)
            one = tfm.slot_cache(view, slot)
            h, new_one = tfm.forward_serve(
                params, ctx, tokens, positions, one,
                cache_index=jnp.zeros((), jnp.int32))
            logits = tfm.unembed(params, ctx, h[:, -1:, :])[:, 0, :]
            view = tfm.merge_slot_cache(view, new_one, slot)
            eff = jnp.where(mask[:, None], page_map, scratch)
            pool = tfm.scatter_pages(pool, view, eff)
            _, new_slot = tfm.split_paged(view)
            slot_tree = jax.tree.map(_masked_merge(mask), slot_tree,
                                     new_slot)
            return logits[0], pool, slot_tree

        # donate the pool/slot storage: the scatter then updates the page
        # frames in place instead of copying the whole pool every step
        self._decode_paged = jax.jit(decode_paged, donate_argnums=(1, 2))
        self._prefill_paged = jax.jit(prefill_paged, donate_argnums=(1, 2))

    # ------------------------------------------------------------------
    def submit(self, req: Request, on_token=None) -> Session:
        """Queue a request; returns its :class:`Session` (token stream)."""
        sess = Session(request=req, seq=self._seq, on_token=on_token)
        self._seq += 1
        self.sessions.append(sess)
        self._by_uid[sess.uid] = sess
        self.scheduler.submit(sess)
        return sess

    @property
    def pending(self) -> List[Request]:
        """Legacy view: requests waiting for a slot (queued or paused)."""
        return [s.request for s in self.scheduler.waiting()]

    def _sample(self, logits: jax.Array) -> int:
        if self.temperature <= 0:
            return int(jnp.argmax(logits))
        self.key, sub = jax.random.split(self.key)
        return int(jax.random.categorical(sub, logits / self.temperature))

    def _retire(self, sess: Session, reason: str) -> None:
        sess.finish(reason)
        self.cache.release(sess)
        self.scheduler.on_retire(sess)
        self._release_quota(sess)
        self.finished.append(sess.request)

    def _release_quota(self, sess: Session) -> None:
        charge = self._quota_charged.pop(sess.uid, None)
        if charge is not None and self.quota is not None:
            self.quota.release(*charge)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine step: advance the scheduler clock, sweep
        cancellations, preempt, admit, back the next decode row with
        pages, then one decode step for every resident session.  Returns
        the number of resident sessions."""
        self.scheduler.on_step()
        self._sweep_cancelled()
        self._preempt()
        self._admit()
        self._grow_pages()

        slots = self.cache.slots
        active = [i for i, s in enumerate(slots) if s is not None]
        if not active:
            return 0

        # batched decode: every resident session advances by one token;
        # idle slots decode a dummy token at index 0 (masked out).  Mixed
        # cache lengths decode per unique-length group: the shared
        # cache_index must match each slot's write position.
        tok = np.zeros((self.batch, 1), np.int32)
        for i in active:
            tok[i, 0] = slots[i].tokens[-1]
        groups: Dict[int, List[int]] = {}
        for i in active:
            groups.setdefault(slots[i].length, []).append(i)
        for length, idxs in sorted(groups.items()):
            pos = self._positions(1, length, self.batch)
            mask = np.zeros((self.batch,), bool)
            mask[idxs] = True
            m = jnp.asarray(mask)
            if self.cache.paged:
                pm = jnp.asarray(self.cache.page_map())
                logits, self.cache.pool, self.cache.slot_tree = \
                    self._decode_paged(
                        self.params, self.cache.pool, self.cache.slot_tree,
                        pm, jnp.asarray(tok), pos, jnp.int32(length), m)
            else:
                logits, new_caches = self._decode(
                    self.params, jnp.asarray(tok), pos, self.cache.caches,
                    jnp.int32(length))
                # merge: only this length group takes the new cache (other
                # slots' caches must not see the dummy write at `length`)
                self.cache.caches = jax.tree.map(
                    _masked_merge(m), self.cache.caches, new_caches)
            for i in idxs:
                sess = slots[i]
                nxt = self._sample(logits[i])
                sess.emit(nxt)
                sess.length += 1
                if sess.done:
                    # cancelled from the on_token callback mid-stream
                    self.cache.release(sess)
                    self.scheduler.on_retire(sess)
                    self._release_quota(sess)
                elif nxt == sess.request.eos_id:
                    self._retire(sess, FINISH_EOS)
                elif len(sess.tokens) >= sess.request.max_new_tokens:
                    self._retire(sess, FINISH_LENGTH)
                elif sess.length >= self.max_len:
                    # the NEXT decode would write past the last cache row;
                    # this row itself is used (was an off-by-one retire)
                    self._retire(sess, FINISH_CACHE_FULL)
        return len(active)

    # ------------------------------------------------------------------
    def _sweep_cancelled(self) -> None:
        """Honour out-of-band Session.cancel(): free the slot of a
        cancelled resident session, drop the parked cache / pages
        (returning their SpillTier budget) of one cancelled while paused
        or queued, and return the tenant-quota charge.  Queued
        cancellations are dropped lazily by the scheduler's next_ready."""
        for sess in self.cache.running():
            if sess.done:
                self.cache.release(sess)
                self.scheduler.on_retire(sess)
        self.cache.sweep_cancelled()
        for uid in list(self._quota_charged):
            sess = self._by_uid.get(uid)
            if sess is not None and sess.done:
                self._release_quota(sess)

    def _preempt(self) -> None:
        """Pause running sessions when the scheduler ranks waiting work
        above them (their KV goes cold: pages lazily, slots eagerly)."""
        if not self.cache.can_preempt:
            return
        want = len(self.scheduler.waiting())
        freed = self.cache.num_free()
        while freed < want:
            victim = self.scheduler.preempt_victim(self.cache.running())
            if victim is None:
                break
            self.cache.pause(victim)
            self.scheduler.requeue(victim)
            freed += 1

    def _admit(self) -> None:
        """Fill free slots in scheduler order.

        A popped paused session resumes (copy-free for pages never
        evicted); a fresh one is quota-checked, page-backed and prefilled.
        Quota-blocked sessions are *deferred* — later arrivals (other
        tenants) admit past them — unless their demand could never fit the
        tenant's quota, which rejects with finish reason ``"quota"``.
        Pool-pressure failures (every page hot) stop admission for this
        step."""
        deferred: List[Session] = []
        while True:
            slot = self.cache.free_slot()
            if slot is None:
                break
            sess = self.scheduler.next_ready()
            if sess is None:
                break
            if sess.state is SessionState.PAUSED:
                try:
                    self.cache.resume(sess, slot)
                except PageError:
                    deferred.append(sess)
                    break               # pool too hot; retry next step
                continue
            prompt = np.asarray(sess.request.prompt)
            if not self.cache.fits_prompt(len(prompt)):
                log.warning("req %d: prompt of %d tokens does not fit a "
                            "%d-row cache slot — rejected",
                            sess.uid, len(prompt), self.max_len)
                self._retire(sess, FINISH_REJECTED)
                continue
            pages_needed = self.cache.session_pages(
                len(prompt), sess.request.max_new_tokens)
            if self.quota is not None:
                if not self.quota.admissible(sess.tenant, pages_needed):
                    log.warning("req %d: demand (%d pages) can never fit "
                                "tenant %r quota — rejected",
                                sess.uid, pages_needed, sess.tenant)
                    self._retire(sess, FINISH_QUOTA)
                    continue
                if not self.quota.can_admit(sess.tenant, pages_needed):
                    deferred.append(sess)
                    continue
            try:
                self.cache.prepare_slot(slot, sess, max(1, len(prompt)))
            except PageError:
                self.cache.abort_prepare(sess)
                deferred.append(sess)
                break                   # pool too hot; retry next step
            if self.quota is not None:
                self.quota.admit(sess.tenant, pages_needed)
                self._quota_charged[sess.uid] = (sess.tenant, pages_needed)
            toks = jnp.asarray(prompt, jnp.int32)[None, :]
            S = toks.shape[1]
            pos = self._positions(S, 0, 1)
            if self.cache.paged:
                hot = np.zeros((self.batch,), bool)
                hot[slot] = True
                pm = jnp.asarray(self.cache.page_map_for(slot, sess))
                logits, self.cache.pool, self.cache.slot_tree = \
                    self._prefill_paged(
                        self.params, self.cache.pool, self.cache.slot_tree,
                        pm, toks, pos, slot, jnp.asarray(hot))
            else:
                logits, self.cache.caches = self._prefill(
                    self.params, self.cache.caches, toks, pos, slot)
            self.cache.bind(slot, sess, S)
            nxt = self._sample(logits)
            sess.emit(nxt)
            if nxt == sess.request.eos_id:
                self._retire(sess, FINISH_EOS)
            elif len(sess.tokens) >= sess.request.max_new_tokens:
                self._retire(sess, FINISH_LENGTH)
        for sess in reversed(deferred):
            self.scheduler.requeue(sess)

    def _grow_pages(self) -> None:
        """Back every resident session's next decode row with a page.

        Under pool overcommit the allocation may find every page hot; the
        engine then pauses the longest other running session (making its
        pages evictable) and retries — at the limit a session alone in
        the pool retires ``cache_full``."""
        if not self.cache.paged:
            return
        for sess in list(self.cache.running()):
            if sess.slot is None or sess.done:
                continue    # paused by an earlier iteration's pressure
                            # relief: allocating to it now would pin a hot
                            # page to a non-resident owner
            while True:
                try:
                    self.cache.ensure_rows(sess, sess.length + 1)
                    break
                except PageError:
                    if not self._relieve_pressure(sess):
                        self._retire(sess, FINISH_CACHE_FULL)
                        break

    def _relieve_pressure(self, needy: Session) -> bool:
        others = [s for s in self.cache.running() if s is not needy]
        if not others or not self.cache.can_preempt:
            return False
        victim = max(others, key=lambda s: (s.length, s.seq))
        self.cache.pause(victim)
        self.scheduler.requeue(victim)
        return True

    # ------------------------------------------------------------------
    def _positions(self, S: int, offset: int, batch: int):
        if self.model.cfg.mrope_sections:
            return jnp.broadcast_to(
                jnp.arange(offset, offset + S, dtype=jnp.int32)[None, None],
                (3, batch, S))
        return jnp.broadcast_to(
            jnp.arange(offset, offset + S, dtype=jnp.int32)[None],
            (batch, S))

    def run(self, max_steps: int = 10_000) -> List[Request]:
        for _ in range(max_steps):
            if self.step() == 0 and not self.scheduler.has_waiting():
                break
        return self.finished

    # ------------------------------------------------------------------
    @property
    def caches(self):
        """Legacy alias of the manager-owned cache tree."""
        return self.cache.caches

    def traffic_report(self) -> Dict[str, Any]:
        """Spill-tier byte accounting (cold-KV kv_stash / kv_fetch) plus,
        in paged mode, the page-level transfer counters."""
        return self.cache.traffic_report()

    def quota_report(self) -> Dict[str, Any]:
        """Per-tenant session/page usage (empty without quotas)."""
        return self.quota.usage() if self.quota is not None else {}

    def describe(self) -> str:
        quota = f" {self.quota.describe()}" if self.quota else ""
        return (f"engine[{self.cache.describe()} "
                f"sched={self.scheduler.describe()}{quota}]")
