"""Engine: thin facade over the Scheduler / KVCacheManager / Session APIs.

The paper's technique applied to inference (DESIGN.md §6): the KV cache is
sharded over the mesh's pooled HBM (sequence dim over 'model'), so a
524k-token cache that exceeds one chip's memory serves from the pool with
the decode attention executed *distributed* — no cache migration, the
compute goes to the data.

The serving stack is three composable APIs; the engine only wires them to
the model's prefill/decode compute and the sampler:

* :class:`~repro.serve.scheduler.Scheduler` — admission, continuous
  batching, preemption (pluggable: fcfs / priority / fair / srpt /
  deadline).
* :class:`~repro.serve.cache_manager.KVCacheManager` — slot allocation,
  tier-report auto-sizing of ``batch``/``max_len``, cold-KV spill to a
  secondary memory tier.  ``page_size`` switches the storage model to the
  :class:`~repro.serve.cache_manager.PagedKVCacheManager`: sessions hold
  fixed-size pages of a shared pool, preemption marks them cold in place,
  and spill happens lazily per page through a per-tenant codec.
* :class:`~repro.serve.session.Session` — the streaming result API
  (token stream + lifecycle + finish reason) returned by :meth:`submit`.

Multi-tenant admission (``quota=``) is enforced here, at the facade: a
session is charged its worst-case page reservation against its tenant's
:class:`~repro.serve.quota.TenantQuota` before it may take a slot —
transiently over-budget tenants are deferred (other tenants admit past
them), impossible requests are rejected with finish reason ``"quota"``.

**Roles** (``role=``, serve/disagg.py): the facade serves three ways.
``"both"`` — the default colocated engine, prefill and decode in one
lifecycle.  ``"prefill"`` — admission + prompt prefill + first token
only; each freshly prefilled session's KV is chopped into page-shaped
chunks and published to the ``transfer`` queue instead of decoding
(admission pauses while the queue is at capacity).  ``"decode"`` — no
fresh submissions; sessions arrive as page handoffs adopted from the
``transfer`` queue (backpressure requeues them, pages parked in the
transfer tier) and then decode exactly as colocated.  The token stream
is bit-identical across ``both`` / prefill→decode for greedy sampling —
the cross-role trace-equivalence suite pins that.

Back-compat: the legacy ``Engine(model, params, batch, max_len)``
constructor still works (sizes are simply explicit instead of derived),
and ``Request.out_tokens`` stays populated — it aliases the session's
token stream.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.model import Model
from repro.serve.cache_manager import KVCacheManager, PagedKVCacheManager
from repro.serve.paging import PageError, pages_for
from repro.serve.quota import QuotaManager, TenantQuota
from repro.serve.scheduler import Scheduler, build_scheduler
from repro.serve.session import (FINISH_CACHE_FULL, FINISH_EOS,
                                 FINISH_LENGTH, FINISH_QUOTA,
                                 FINISH_REJECTED, Session, SessionState)

log = logging.getLogger(__name__)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (S_prompt,) int32
    max_new_tokens: int = 16
    eos_id: int = -1                   # -1: never
    priority: int = 0                  # PriorityScheduler rank (higher first)
    tenant: str = "default"            # quota / codec bucket
    deadline: Optional[float] = None   # DeadlineScheduler: absolute step
    out_tokens: Optional[List[int]] = None

    def __post_init__(self):
        if self.out_tokens is None:
            self.out_tokens = []


def _masked_merge(mask: jax.Array):
    """Leaf merge taking ``new`` on masked batch rows (cache batch dim 1)."""

    def merge(old, new):
        mm = mask.reshape((1, mask.shape[0]) + (1,) * (old.ndim - 2))
        return jnp.where(mm, new.astype(old.dtype), old)

    return merge


class Engine:
    """Facade: scheduler + cache manager + quotas + sampler in one object.

    ``batch`` / ``max_len`` may be omitted — the cache manager then sizes
    them from the serving tier's ``cache_tier_report`` (how much cache the
    tier lets one device address).  The legacy positional signature
    ``Engine(model, params, batch, max_len)`` is unchanged.
    """

    def __init__(self, model: Model, params,
                 batch: Optional[int] = None,
                 max_len: Optional[int] = None,
                 temperature: float = 0.0, seed: int = 0,
                 scheduler: Union[str, Scheduler] = "fcfs",
                 spill: Union[str, Any, None] = "spill",
                 page_size: Optional[int] = None,
                 pages: Optional[int] = None,
                 codec_kernel: bool = False,
                 decode_kernel: bool = False,
                 quota: Union[QuotaManager, TenantQuota,
                              Dict[str, TenantQuota], None] = None,
                 role: str = "both",
                 transfer: Optional[Any] = None,
                 prefix_share: bool = False,
                 **cache_kwargs):
        if role not in ("both", "prefill", "decode"):
            raise ValueError(f"role must be both/prefill/decode: {role!r}")
        if role != "both":
            if transfer is None:
                raise ValueError(f"role={role!r} needs a TransferQueue "
                                 "(serve/disagg.py) to ship KV through")
            if not page_size:
                raise ValueError(f"role={role!r} ships page-shaped KV: "
                                 "pass page_size")
        self.model = model
        self.params = params
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.role = role
        self.transfer = transfer
        self._page_size = int(page_size) if page_size else None

        self.scheduler: Scheduler = (build_scheduler(scheduler)
                                     if isinstance(scheduler, str)
                                     else scheduler)
        if quota is None or isinstance(quota, QuotaManager):
            self.quota: Optional[QuotaManager] = quota
        elif isinstance(quota, TenantQuota):
            self.quota = QuotaManager(default_quota=quota)
        else:
            self.quota = QuotaManager(dict(quota))

        if decode_kernel and not (page_size and role != "prefill"):
            raise ValueError("decode_kernel needs paged KV: pass page_size "
                             "(and a decode-capable role)")
        if page_size and role != "prefill":
            codec_for = self.quota.codec_for if self.quota else None
            self.cache: KVCacheManager = PagedKVCacheManager(
                model, batch, max_len, spill=spill, page_size=page_size,
                pages=pages, codec_for=codec_for,
                codec_kernel=codec_kernel, decode_kernel=decode_kernel,
                prefix_share=prefix_share,
                **cache_kwargs)
        else:
            # the prefill role computes in plain contiguous slots (no pool
            # indirection on its hot path); page_size only shapes the
            # slot_pages chunking of the published handoff
            self.cache = KVCacheManager(model, batch, max_len, spill=spill,
                                        **cache_kwargs)
        if role == "prefill" and self.cache.max_len % self._page_size:
            raise ValueError(
                f"page_size {self._page_size} must divide max_len "
                f"{self.cache.max_len} (handoff pages tile the slot)")
        self.batch, self.max_len = self.cache.batch, self.cache.max_len
        self.kv_report = self.cache.report
        if not self.kv_report["fits"]:
            log.warning("kv cache exceeds per-device HBM: %.2f GB/device "
                        "(tier %s could address %.2f GB) — expect OOM at "
                        "this batch/max_len",
                        self.kv_report["per_device_bytes"] / 1e9,
                        self.kv_report["tier"],
                        self.kv_report["capacity_bytes"] / 1e9)

        self.sessions: List[Session] = []      # every submission, in order
        self.finished: List[Request] = []      # legacy result list
        self._seq = 0
        self._by_uid: Dict[int, Session] = {}
        self._build_compute()

    # ------------------------------------------------------------------
    def _build_compute(self) -> None:
        """jit the decode/prefill paths against the manager's storage."""
        model = self.model
        self._decode = jax.jit(model.decode_step)

        def fresh_slot(caches):
            """Zeroed single-slot cache for a FRESH admission's prefill.

            A reused slot still holds its previous occupant's state.  KV
            rows are write-before-read (masked by cache_index) so stale
            rows are harmless, but recurrent SSM/conv state is READ at
            the start of the scan — prefilling from the stale slot leaks
            the last session's state into the new one's stream."""
            return jax.tree.map(
                lambda c: jnp.zeros((c.shape[0], 1) + c.shape[2:], c.dtype),
                caches)

        def prefill_one(params, caches, tokens, positions, slot):
            """Prefill one sequence into slot ``slot`` of the batched cache."""
            ctx = model.ctx("prefill")
            h, new_cache = tfm.forward_serve(
                params, ctx, tokens, positions, fresh_slot(caches),
                cache_index=jnp.zeros((), jnp.int32))
            logits = tfm.unembed(params, ctx, h[:, -1:, :])[:, 0, :]
            caches = tfm.merge_slot_cache(caches, new_cache, slot)
            return logits[0], caches

        self._prefill = jax.jit(prefill_one)
        if not self.cache.paged:
            return

        # paged twins: gather the contiguous view from the page pool, run
        # the same compute, scatter written pages back (non-group slots
        # route to the scratch page — the masked-dummy-write semantics)
        scratch = self.cache.scratch_id

        page = self.cache.page_size

        def decode_paged(params, pool, slot_tree, page_map, tok, pos, idx,
                         mask):
            view = tfm.gather_pages(pool, slot_tree, page_map)
            logits, new = model.decode_step(params, tok, pos, view, idx)
            # one row written per slot -> write back only its page
            wp = idx // page
            target = jnp.where(mask, jnp.take(page_map, wp, axis=1), scratch)
            pool = tfm.scatter_one_page(pool, new, target, wp * page, page)
            _, new_slot = tfm.split_paged(new)
            slot_tree = jax.tree.map(_masked_merge(mask), slot_tree,
                                     new_slot)
            return logits, pool, slot_tree

        def prefill_paged(params, pool, slot_tree, page_map, tokens,
                          positions, slot, mask):
            ctx = model.ctx("prefill")
            view = tfm.gather_pages(pool, slot_tree, page_map)
            # fresh_slot, not slot_cache: see prefill_one — a fresh
            # admission must never read the slot's previous recurrent state
            h, new_one = tfm.forward_serve(
                params, ctx, tokens, positions, fresh_slot(view),
                cache_index=jnp.zeros((), jnp.int32))
            logits = tfm.unembed(params, ctx, h[:, -1:, :])[:, 0, :]
            view = tfm.merge_slot_cache(view, new_one, slot)
            eff = jnp.where(mask[:, None], page_map, scratch)
            pool = tfm.scatter_pages(pool, view, eff)
            _, new_slot = tfm.split_paged(view)
            slot_tree = jax.tree.map(_masked_merge(mask), slot_tree,
                                     new_slot)
            return logits[0], pool, slot_tree

        def prefill_paged_shared(params, pool, slot_tree, page_map, tokens,
                                 positions, slot, mask, cache_index,
                                 write_from):
            """Suffix prefill for a prefix-sharing admission: rows below
            ``cache_index`` were grafted from shared (or forked) pages and
            are NOT recomputed — the tokens here are the prompt's tail,
            written at ``cache_index`` and attending over the gathered
            cache rows.  The scatter routes page columns below
            ``write_from`` (the read-only shared pages) to scratch:
            writers never touch a shared frame."""
            ctx = model.ctx("prefill")
            view = tfm.gather_pages(pool, slot_tree, page_map)
            # slot_cache, not fresh_slot: the grafted prefix rows must be
            # readable; rows past the suffix stay masked by position (the
            # prefix gate in the cache manager guarantees there is no
            # recurrent slot state to leak)
            one = tfm.slot_cache(view, slot)
            h, new_one = tfm.forward_serve(
                params, ctx, tokens, positions, one,
                cache_index=cache_index, prefix_attend=True)
            logits = tfm.unembed(params, ctx, h[:, -1:, :])[:, 0, :]
            view = tfm.merge_slot_cache(view, new_one, slot)
            cols = jnp.arange(page_map.shape[1], dtype=jnp.int32)
            writable = mask[:, None] & (cols[None, :] >= write_from)
            eff = jnp.where(writable, page_map, scratch)
            pool = tfm.scatter_pages(pool, view, eff)
            _, new_slot = tfm.split_paged(view)
            slot_tree = jax.tree.map(_masked_merge(mask), slot_tree,
                                     new_slot)
            return logits[0], pool, slot_tree

        def decode_paged_kernel(params, pool, cpool, cscale, slot_tree,
                                page_map, tok, pos, idx, row_off, write_pid,
                                mask):
            """In-place decode: no gather_pages — the pool leaves ride
            into the forward as the cache and the paged-attention kernel
            dereferences the block table itself, touching only the pages
            each session holds.  The compressed side pool (int8 payload +
            per-frame scales) rides along read-only as kq/vq/ks/vs and is
            dequanted inside the K/V load."""
            merged = {}
            for g in pool:
                d = dict(pool[g])
                d["kq"], d["vq"] = cpool[g]["k"], cpool[g]["v"]
                d["ks"], d["vs"] = cscale[g]["k"], cscale[g]["v"]
                d.update(slot_tree.get(g, {}))
                merged[g] = d
            for g in slot_tree:
                if g not in merged:
                    merged[g] = dict(slot_tree[g])
            ctx = model.ctx("decode")
            h, new = tfm.forward_serve(
                params, ctx, tok, pos, merged, cache_index=idx,
                paged=dict(page_map=page_map, write_pid=write_pid,
                           row_off=row_off))
            logits = tfm.unembed(params, ctx, h[:, 0:1, :])[:, 0, :]
            new_pool = {g: {k: new[g][k] for k in tfm.PAGED_KEYS}
                        for g in pool}
            new_slot = jax.tree.map(
                _masked_merge(mask), slot_tree,
                {g: {k: new[g][k] for k in slot_tree[g]}
                 for g in slot_tree})
            return logits, new_pool, new_slot

        # donate the pool/slot storage: the scatter then updates the page
        # frames in place instead of copying the whole pool every step
        self._decode_paged = jax.jit(decode_paged, donate_argnums=(1, 2))
        self._decode_paged_kernel = jax.jit(decode_paged_kernel,
                                            donate_argnums=(1, 4))
        self._prefill_paged = jax.jit(prefill_paged, donate_argnums=(1, 2))
        self._prefill_paged_shared = jax.jit(prefill_paged_shared,
                                             donate_argnums=(1, 2))

    # ------------------------------------------------------------------
    def submit(self, req: Optional[Request] = None, on_token=None,
               session: Optional[Session] = None) -> Session:
        """Queue a request; returns its :class:`Session` (token stream).

        Pass ``session=`` to hand over an existing session instead of
        minting one — the router does this so a session keeps its
        cluster-wide ``seq`` (and token stream identity) across placement,
        drain redistribution, and engine-loss requeue."""
        if self.role == "decode":
            raise RuntimeError(
                "a decode-role engine adopts sessions from the transfer "
                "queue; submit prompts to the prefill engine (or the "
                "DisaggPair facade)")
        if session is None:
            sess = Session(request=req, seq=self._seq, on_token=on_token)
        else:
            sess = session
            if on_token is not None:
                sess.on_token = on_token
        self._seq = max(self._seq, sess.seq) + 1
        if self._by_uid.get(sess.uid) is not sess:
            self.sessions.append(sess)
            self._by_uid[sess.uid] = sess
        self.scheduler.submit(sess)
        return sess

    @property
    def pending(self) -> List[Request]:
        """Legacy view: requests waiting for a slot (queued or paused)."""
        return [s.request for s in self.scheduler.waiting()]

    def _sample(self, logits: jax.Array) -> int:
        if self.temperature <= 0:
            return int(jnp.argmax(logits))
        self.key, sub = jax.random.split(self.key)
        return int(jax.random.categorical(sub, logits / self.temperature))

    def _retire(self, sess: Session, reason: str) -> None:
        sess.finish(reason)
        self.cache.release(sess)
        self.scheduler.on_retire(sess)
        self._release_quota(sess)
        self.finished.append(sess.request)

    def _release_quota(self, sess: Session) -> None:
        if self.quota is not None:
            self.quota.release_uid(sess.uid)

    def _session_pages(self, prompt_len: int, max_new: int) -> int:
        """Worst-case page reservation.  The prefill role has no page pool
        of its own but must still charge the reservation its decode peer
        will serve under — the shared-ledger charge follows the session."""
        if self.role == "prefill":
            rows = min(self.max_len, prompt_len + max_new)
            return pages_for(rows, self._page_size)
        return self.cache.session_pages(prompt_len, max_new)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine step: advance the scheduler clock, sweep
        cancellations, preempt, admit, back the next decode row with
        pages, then one decode step for every resident session.  Returns
        the number of resident sessions (prefill role: the number of
        handoffs shipped this step)."""
        self.scheduler.on_step()
        self._sweep_cancelled()
        if self.role == "prefill":
            self._admit()
            return self._publish_handoffs()
        self._preempt()
        self._admit()
        self._grow_pages()

        slots = self.cache.slots
        active = [i for i, s in enumerate(slots) if s is not None]
        if not active:
            return 0

        # batched decode: every resident session advances by one token;
        # idle slots decode a dummy token at index 0 (masked out).  Mixed
        # cache lengths decode per unique-length group: the shared
        # cache_index must match each slot's write position.
        tok = np.zeros((self.batch, 1), np.int32)
        for i in active:
            tok[i, 0] = slots[i].tokens[-1]
        groups: Dict[int, List[int]] = {}
        for i in active:
            groups.setdefault(slots[i].length, []).append(i)
        for length, idxs in sorted(groups.items()):
            pos = self._positions(1, length, self.batch)
            mask = np.zeros((self.batch,), bool)
            mask[idxs] = True
            m = jnp.asarray(mask)
            if self.cache.paged and getattr(self.cache, "decode_kernel",
                                            False):
                # in-place paged decode: the step's K/V row is written
                # straight into its page frame (masked slots land in
                # scratch) and attention runs over the pool via the
                # block table — no per-step gather of the whole pool
                page = self.cache.page_size
                wp, ro = divmod(length, page)
                pm_host = self.cache.page_map_host()
                write = np.where(mask, pm_host[:, wp],
                                 self.cache.scratch_id).astype(np.int32)
                # the write page is raw by construction (tail pages never
                # resume compressed); a translated id here would scribble
                # past the pool
                assert int(write.max()) <= self.cache.scratch_id, write
                self.cache.note_decode(length, len(idxs))
                logits, self.cache.pool, self.cache.slot_tree = \
                    self._decode_paged_kernel(
                        self.params, self.cache.pool, self.cache.cpool,
                        self.cache.cscale, self.cache.slot_tree,
                        self.cache.page_map(), jnp.asarray(tok), pos,
                        jnp.int32(length), jnp.int32(ro),
                        jnp.asarray(write), m)
            elif self.cache.paged:
                pm = jnp.asarray(self.cache.page_map())
                self.cache.note_decode(length, len(idxs))
                logits, self.cache.pool, self.cache.slot_tree = \
                    self._decode_paged(
                        self.params, self.cache.pool, self.cache.slot_tree,
                        pm, jnp.asarray(tok), pos, jnp.int32(length), m)
            else:
                logits, new_caches = self._decode(
                    self.params, jnp.asarray(tok), pos, self.cache.caches,
                    jnp.int32(length))
                # merge: only this length group takes the new cache (other
                # slots' caches must not see the dummy write at `length`)
                self.cache.caches = jax.tree.map(
                    _masked_merge(m), self.cache.caches, new_caches)
            for i in idxs:
                sess = slots[i]
                nxt = self._sample(logits[i])
                sess.emit(nxt)
                sess.length += 1
                if sess.done:
                    # cancelled from the on_token callback mid-stream
                    self.cache.release(sess)
                    self.scheduler.on_retire(sess)
                    self._release_quota(sess)
                elif nxt == sess.request.eos_id:
                    self._retire(sess, FINISH_EOS)
                elif len(sess.tokens) >= sess.request.max_new_tokens:
                    self._retire(sess, FINISH_LENGTH)
                elif sess.length >= self.max_len:
                    # the NEXT decode would write past the last cache row;
                    # this row itself is used (was an off-by-one retire)
                    self._retire(sess, FINISH_CACHE_FULL)
        return len(active)

    # ------------------------------------------------------------------
    def _sweep_cancelled(self) -> None:
        """Honour out-of-band Session.cancel(): free the slot of a
        cancelled resident session, drop the parked cache / pages
        (returning their SpillTier budget) of one cancelled while paused
        or queued — or its in-flight handoff when cancelled in transit —
        and return the tenant-quota charge.  Queued cancellations are
        dropped lazily by the scheduler's next_ready."""
        for sess in self.cache.running():
            if sess.done:
                self.cache.release(sess)
                self.scheduler.on_retire(sess)
        self.cache.sweep_cancelled()
        if self.transfer is not None:
            for sess in self.transfer.sweep_cancelled():
                self._release_quota(sess)
        if self.quota is not None:
            for uid in self.quota.charged_uids():
                sess = self._by_uid.get(uid)
                if sess is not None and sess.done:
                    self.quota.release_uid(uid)

    def _preempt(self) -> None:
        """Pause running sessions when the scheduler ranks waiting work
        above them (their KV goes cold: pages lazily, slots eagerly).
        On the decode role, handoffs parked in the transfer queue ARE
        waiting work — without counting them, a quantum policy would
        never turn slots over toward incoming adoptions."""
        if not self.cache.can_preempt:
            return
        want = len(self.scheduler.waiting())
        if self.role == "decode":
            want += self.transfer.depth()
        freed = self.cache.num_free()
        while freed < want:
            victim = self.scheduler.preempt_victim(self.cache.running())
            if victim is None:
                break
            self.cache.pause(victim)
            self.scheduler.requeue(victim)
            freed += 1

    def _admit(self) -> None:
        """Fill free slots in scheduler order.

        A popped paused session resumes (copy-free for pages never
        evicted); a fresh one is quota-checked, page-backed and prefilled.
        Quota-blocked sessions are *deferred* — later arrivals (other
        tenants) admit past them — unless their demand could never fit the
        tenant's quota, which rejects with finish reason ``"quota"``.
        Pool-pressure failures (every page hot) stop admission for this
        step.  Role splits: the prefill role additionally gates on
        transfer-queue headroom (queue pressure backs up into the prefill
        scheduler, not the transfer tier), and the decode role admits
        adoptions from the queue, then paused resumes from its scheduler
        — the same order a colocated fair/priority policy yields, where
        a requeued (paused) session waits behind fresh arrivals."""
        if self.role == "decode":
            self._admit_adoptions()
            self._admit_resumes()
            return
        deferred: List[Session] = []
        while True:
            slot = self.cache.free_slot()
            if slot is None:
                break
            if self.role == "prefill" and not self.transfer.has_room(
                    pending=len(self.cache.running())):
                break                   # decode-side backpressure
            sess = self.scheduler.next_ready()
            if sess is None:
                break
            if sess.state is SessionState.PAUSED:
                try:
                    self.cache.resume(sess, slot)
                except PageError:
                    deferred.append(sess)
                    break               # pool too hot; retry next step
                continue
            prompt = np.asarray(sess.request.prompt)
            if not self.cache.fits_prompt(len(prompt)):
                log.warning("req %d: prompt of %d tokens does not fit a "
                            "%d-row cache slot — rejected",
                            sess.uid, len(prompt), self.max_len)
                self._retire(sess, FINISH_REJECTED)
                continue
            pages_needed = self._session_pages(
                len(prompt), sess.request.max_new_tokens)
            # prefix-sharing: match BEFORE the quota gate — pages bound
            # read-only from the prefix cache are pooled capacity another
            # session already paid for, so the tenant is charged only the
            # private remainder (always >= 1: the suffix prefill needs at
            # least one writable page)
            match = self.cache.match_prefix(prompt)
            charge_pages = pages_needed - (match.shared_pages
                                           if match is not None else 0)
            if self.quota is not None:
                if not self.quota.admissible(sess.tenant, charge_pages):
                    log.warning("req %d: demand (%d pages) can never fit "
                                "tenant %r quota — rejected",
                                sess.uid, charge_pages, sess.tenant)
                    self._retire(sess, FINISH_QUOTA)
                    continue
                if not self.quota.can_admit(sess.tenant, charge_pages):
                    deferred.append(sess)
                    continue
            try:
                self.cache.prepare_slot(slot, sess, max(1, len(prompt)),
                                        match=match)
            except PageError:
                self.cache.abort_prepare(sess)
                deferred.append(sess)
                break                   # pool too hot; retry next step
            if self.quota is not None:
                self.quota.charge(sess.uid, sess.tenant, charge_pages)
            toks = jnp.asarray(prompt, jnp.int32)[None, :]
            S = toks.shape[1]
            if self.cache.paged:
                hot = np.zeros((self.batch,), bool)
                hot[slot] = True
                pm = jnp.asarray(self.cache.page_map_for(slot, sess))
                if match is not None:
                    # suffix prefill: matched rows are already in the
                    # page map (shared read-only + the forked copy) —
                    # compute only the tail
                    spos = self._positions(S - match.rows, match.rows, 1)
                    logits, self.cache.pool, self.cache.slot_tree = \
                        self._prefill_paged_shared(
                            self.params, self.cache.pool,
                            self.cache.slot_tree, pm,
                            toks[:, match.rows:], spos, slot,
                            jnp.asarray(hot), jnp.int32(match.rows),
                            jnp.int32(match.write_from))
                else:
                    pos = self._positions(S, 0, 1)
                    logits, self.cache.pool, self.cache.slot_tree = \
                        self._prefill_paged(
                            self.params, self.cache.pool,
                            self.cache.slot_tree, pm, toks, pos, slot,
                            jnp.asarray(hot))
            else:
                pos = self._positions(S, 0, 1)
                logits, self.cache.caches = self._prefill(
                    self.params, self.cache.caches, toks, pos, slot)
            self.cache.bind(slot, sess, S)
            self.cache.note_prefilled(sess, prompt, match)
            nxt = self._sample(logits)
            sess.emit(nxt)
            if nxt == sess.request.eos_id:
                self._retire(sess, FINISH_EOS)
            elif len(sess.tokens) >= sess.request.max_new_tokens:
                self._retire(sess, FINISH_LENGTH)
        for sess in reversed(deferred):
            self.scheduler.requeue(sess)

    # ------------------------------------------------------------------
    # disaggregated roles: publish (prefill side) / adopt (decode side)
    def _publish_handoffs(self) -> int:
        """Ship every freshly prefilled resident session to the decode
        side: chop the slot's KV into page-shaped chunks, stash them into
        the transfer tier (metered as ``kv_publish``), free the local
        slot, and keep the quota charge on the shared ledger — the
        reservation follows the session."""
        from repro.serve.disagg import KVHandoff
        shipped = 0
        for sess in list(self.cache.running()):
            if sess.done:
                continue
            one = self.cache.export_slot(sess)
            n_pages = pages_for(sess.length, self._page_size)
            pages, rest = tfm.slot_pages(one, self._page_size, n_pages)
            slot_one = rest if jax.tree_util.tree_leaves(rest) else None
            self.cache.release(sess)
            sess.state = SessionState.QUEUED    # in transit
            try:
                self.transfer.publish(
                    KVHandoff(session=sess, length=sess.length), pages,
                    slot_one)
            except Exception as e:              # noqa: BLE001
                from repro.serve.transport import TransportError
                if not isinstance(e, TransportError):
                    raise
                # mid-transfer failure: nothing reached the peer, so the
                # per-uid quota reservation must not leak — release it and
                # requeue for a fresh prefill (re-charged at readmission)
                log.warning("publish failed for uid=%d, requeueing: %s",
                            sess.uid, e)
                self._release_quota(sess)
                if not sess.done:
                    sess.rewind()
                    self.scheduler.submit(sess)
                continue
            self.scheduler.on_handoff(sess)
            shipped += 1
        return shipped

    def _admit_resumes(self) -> None:
        """Decode role: re-admit paused sessions in scheduler order (the
        decode queue — fresh work arrives through the transfer queue)."""
        deferred: List[Session] = []
        while True:
            slot = self.cache.free_slot()
            if slot is None:
                break
            sess = self.scheduler.next_ready()
            if sess is None:
                break
            assert sess.state is SessionState.PAUSED, \
                f"decode scheduler only holds paused sessions: {sess}"
            try:
                self.cache.resume(sess, slot)
            except PageError:
                deferred.append(sess)
                break                   # pool too hot; retry next step
        for sess in reversed(deferred):
            self.scheduler.requeue(sess)

    def _admit_adoptions(self) -> None:
        """Decode role: adopt transferred sessions into free slots.

        Adoption claims fresh page frames first (evicting cold pages if
        the spill tier allows) and only then fetches the shipped bytes; a
        pool-too-hot failure therefore costs no transfer traffic — the
        handoff requeues at the back of the queue and its pages stay
        parked in the transfer tier, never re-prefilled."""
        while True:
            slot = self.cache.free_slot()
            if slot is None:
                break
            handoff = self.transfer.next_ready()
            if handoff is None:
                break
            sess = handoff.session
            if sess.uid not in self._by_uid:
                self.sessions.append(sess)
                self._by_uid[sess.uid] = sess
            if sess.done:               # cancelled in transit
                self.transfer.discard(handoff)
                self._release_quota(sess)
                continue
            try:
                self.cache.adopt(slot, sess, handoff, self.transfer)
            except PageError:
                self.transfer.requeue(handoff)
                break                   # pool too hot; retry next step

    def _grow_pages(self) -> None:
        """Back every resident session's next decode row with a page.

        Under pool overcommit the allocation may find every page hot; the
        engine then pauses the longest other running session (making its
        pages evictable) and retries — at the limit a session alone in
        the pool retires ``cache_full``."""
        if not self.cache.paged:
            return
        for sess in list(self.cache.running()):
            if sess.slot is None or sess.done:
                continue    # paused by an earlier iteration's pressure
                            # relief: allocating to it now would pin a hot
                            # page to a non-resident owner
            while True:
                try:
                    self.cache.ensure_rows(sess, sess.length + 1)
                    break
                except PageError:
                    if not self._relieve_pressure(sess):
                        self._retire(sess, FINISH_CACHE_FULL)
                        break

    def _relieve_pressure(self, needy: Session) -> bool:
        others = [s for s in self.cache.running() if s is not needy]
        if not others or not self.cache.can_preempt:
            return False
        victim = max(others, key=lambda s: (s.length, s.seq))
        self.cache.pause(victim)
        self.scheduler.requeue(victim)
        return True

    # ------------------------------------------------------------------
    def _positions(self, S: int, offset: int, batch: int):
        if self.model.cfg.mrope_sections:
            return jnp.broadcast_to(
                jnp.arange(offset, offset + S, dtype=jnp.int32)[None, None],
                (3, batch, S))
        return jnp.broadcast_to(
            jnp.arange(offset, offset + S, dtype=jnp.int32)[None],
            (batch, S))

    def run(self, max_steps: int = 10_000) -> List[Request]:
        for _ in range(max_steps):
            busy = self.step()
            idle = busy == 0 and not self.scheduler.has_waiting()
            if idle and self.role == "decode" and self.transfer.depth():
                continue                # handoffs still parked in transit
            if idle:
                break
            if self.role == "prefill" and busy == 0 and \
                    not self.transfer.has_room():
                # a standalone prefill engine cannot drain the queue it
                # filled — spinning until max_steps would silently drop
                # the waiting prompts on return
                log.warning("prefill blocked: transfer queue full "
                            "(depth %d) with no consumer; %d prompts "
                            "still waiting", self.transfer.depth(),
                            len(self.scheduler.waiting()))
                break
        return self.finished

    # ------------------------------------------------------------------
    @property
    def caches(self):
        """Legacy alias of the manager-owned cache tree."""
        return self.cache.caches

    def traffic_report(self) -> Dict[str, Any]:
        """Spill-tier byte accounting (cold-KV kv_stash / kv_fetch) plus,
        in paged mode, the page-level transfer counters."""
        return self.cache.traffic_report()

    def quota_report(self) -> Dict[str, Any]:
        """Per-tenant session/page usage (empty without quotas)."""
        return self.quota.usage() if self.quota is not None else {}

    def describe(self) -> str:
        quota = f" {self.quota.describe()}" if self.quota else ""
        role = "" if self.role == "both" else f" role={self.role}"
        return (f"engine[{self.cache.describe()} "
                f"sched={self.scheduler.describe()}{quota}{role}]")
