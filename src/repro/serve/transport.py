"""Wire transport for KV handoffs — the cross-host serving fabric leg.

serve/disagg.py ships prefilled KV between roles through an in-process
:class:`~repro.serve.disagg.TransferQueue`; its docstring names the wire
transport as the out-of-scope remainder.  This module is that transport:
the same handoff unit (pickleable header + page-shaped arrays), serialized
into length-prefixed frames over a pluggable byte :class:`Channel`, so the
prefill and decode engines can live in different processes (or different
hosts) and still produce the bit-identical token streams the cross-role
trace-equivalence suite pins.

Wire format (versioned — satellite of PR 7)::

    frame := magic "KW" | schema u16 | kind u8 | len u32 | payload | crc u32

The CRC32 covers the header AND payload; a schema mismatch or a failed CRC
raises :class:`WireFormatError` *before* any unpickling — garbage frames
never reach ``pickle.loads``.  Payloads are pickled dicts of numpy leaves;
float page leaves optionally pass through a ``core/compress.py`` codec so
compressed pages cross the wire compressed (``_WireLeaf`` carries the
quantized data + scale + codec name).

Frame kinds: ``HANDOFF`` (prefill→decode: header + pages), ``ACK``
(decode→prefill on adoption/discard — drives the sender's ``max_depth``
credit window), ``CANCEL`` (prefill→decode: cancelled in transit),
``RESULT`` (decode→prefill on retire: the full token stream + finish
reason, applied to the original session so the submitter's ``Session``
object completes exactly as in the loopback), ``BYE`` (clean shutdown).

Metering: every frame a side *sends* is metered on that side's
:class:`~repro.core.runtime.MemoryRuntime` as ``kv_wire`` with the exact
frame byte count (``wire_bytes == raw_bytes == len(frame)``), via
``MemoryRuntime.meter_transfer``.  Page payloads additionally meter as
``kv_publish`` (serialize side: raw = tensor bytes, wire = encoded bytes)
and ``kv_adopt`` (decode side, same convention) so the wire reconciles
against the loopback accounting: summed over both runtimes, ``kv_wire``
equals the bytes that crossed the channel exactly, and
``kv_wire >= kv_publish.wire`` (framing + header overhead).

Partial reads retry with exponential backoff — the ``train/fault.py``
``retry_step`` idiom: ``backoff * 2**attempt`` between attempts, no
terminal sleep, ``sleep`` injectable for fake-clock tests — and exhaust
into :class:`TransportError`.  Channels come from a registry mirroring
the scheduler/codec registries: ``"memory"`` (in-process pair, test
default; ``max_chunk`` simulates fragmented reads) and ``"tcp"``
(loopback socket pair; :func:`tcp_listen`/:func:`tcp_connect` build the
two-process halves).
"""
from __future__ import annotations

import dataclasses
import logging
import pickle
import select
import socket
import struct
import threading
import time
import zlib
from collections import deque
from typing import (Any, Callable, Deque, Dict, List, Optional, Sequence,
                    Tuple, Union)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MemoryPlan
from repro.core.compress import decode_tensor, encode_tensor, get_codec
from repro.core.runtime import MemoryRuntime
from repro.serve.disagg import KVHandoff
from repro.serve.quota import QuotaManager, TenantQuota
from repro.serve.session import FINISH_CANCELLED, Session, SessionState

log = logging.getLogger(__name__)

#: bump on any change to the frame layout or the HANDOFF payload schema
SCHEMA_VERSION = 1

_MAGIC = b"KW"
_HEADER = struct.Struct(">2sHBI")        # magic, schema, kind, payload len
_CRC = struct.Struct(">I")

K_HANDOFF, K_ACK, K_CANCEL, K_RESULT, K_BYE = range(1, 6)
_KIND_NAMES = {K_HANDOFF: "HANDOFF", K_ACK: "ACK", K_CANCEL: "CANCEL",
               K_RESULT: "RESULT", K_BYE: "BYE"}


class TransportError(RuntimeError):
    """A channel failed mid-transfer (closed peer, exhausted retries)."""


class WireFormatError(TransportError):
    """A frame failed validation (magic/schema/CRC) — never unpickled."""


# ---------------------------------------------------------------------------
# framing
def pack_frame(kind: int, payload: bytes) -> bytes:
    head = _HEADER.pack(_MAGIC, SCHEMA_VERSION, kind, len(payload))
    crc = zlib.crc32(payload, zlib.crc32(head)) & 0xFFFFFFFF
    return head + payload + _CRC.pack(crc)


def _read_exact(channel: "Channel", n: int, *, started: bool,
                retries: int, backoff: float, sleep) -> Optional[bytes]:
    """Read exactly ``n`` bytes from ``channel``.

    Returns None when ``started`` is False and nothing at all is buffered
    (no frame on the wire — the polling case).  Once any byte of a frame
    has been read, an empty read retries with exponential backoff
    (``backoff * 2**attempt``, no sleep after the terminal attempt) and
    exhausts into :class:`TransportError` — a frame, once begun, must
    complete."""
    buf = bytearray()
    attempt = 0
    while len(buf) < n:
        chunk = channel.recv(n - len(buf))
        if chunk:
            buf += chunk
            attempt = 0
            continue
        if not buf and not started:
            return None
        if channel.closed and attempt >= retries:
            raise TransportError(
                f"channel closed mid-frame: got {len(buf)}/{n} bytes")
        if attempt >= retries:
            raise TransportError(
                f"partial read: {len(buf)}/{n} bytes after "
                f"{retries + 1} attempts")
        sleep(backoff * (2 ** attempt))
        attempt += 1
    return bytes(buf)


def recv_frame(channel: "Channel", *, retries: int = 10,
               backoff: float = 0.005, sleep=time.sleep
               ) -> Optional[Tuple[int, bytes]]:
    """Read one validated frame; None when no frame is on the wire.

    Validation order is deliberate: magic, then schema, then CRC — a
    mismatched schema or corrupted frame raises :class:`WireFormatError`
    with a clear message instead of handing garbage to ``pickle``."""
    head = _read_exact(channel, _HEADER.size, started=False,
                       retries=retries, backoff=backoff, sleep=sleep)
    if head is None:
        return None
    magic, schema, kind, n = _HEADER.unpack(head)
    if magic != _MAGIC:
        raise WireFormatError(
            f"bad frame magic {magic!r} (want {_MAGIC!r}): not a KV wire "
            "frame, refusing to unpickle")
    if schema != SCHEMA_VERSION:
        raise WireFormatError(
            f"wire schema v{schema} from peer, this build speaks "
            f"v{SCHEMA_VERSION} — upgrade the older side (refusing to "
            "unpickle a mismatched layout)")
    payload = _read_exact(channel, n, started=True, retries=retries,
                          backoff=backoff, sleep=sleep)
    (crc,) = _CRC.unpack(_read_exact(channel, _CRC.size, started=True,
                                     retries=retries, backoff=backoff,
                                     sleep=sleep))
    want = zlib.crc32(payload, zlib.crc32(head)) & 0xFFFFFFFF
    if crc != want:
        raise WireFormatError(
            f"frame CRC mismatch (got {crc:#010x}, computed {want:#010x}): "
            "corrupted frame, refusing to unpickle")
    return kind, payload


# ---------------------------------------------------------------------------
# channels
class Channel:
    """One endpoint of a byte pipe.

    ``send`` writes the whole buffer or raises :class:`TransportError`;
    ``recv(n)`` returns *up to* n bytes — possibly fewer, possibly ``b""``
    when nothing is buffered (framing handles reassembly + retry)."""

    def send(self, data: bytes) -> None:
        raise NotImplementedError

    def recv(self, n: int) -> bytes:
        raise NotImplementedError

    def close(self) -> None:
        raise NotImplementedError

    @property
    def closed(self) -> bool:
        raise NotImplementedError


class _Pipe:
    """One direction of an in-memory channel pair (lock-guarded)."""

    def __init__(self, max_chunk: Optional[int] = None):
        self.buf = bytearray()
        self.max_chunk = max_chunk
        self.closed = False
        self.lock = threading.Lock()


class InMemoryChannel(Channel):
    """In-process byte pipe: the test/loopback transport.

    ``max_chunk`` bounds one ``recv`` — set it small to exercise the
    partial-read reassembly path without a real socket.  ``bytes_sent``
    counts every byte pushed through ``send``, the ground truth the
    ``kv_wire`` reconciliation tests compare against."""

    def __init__(self, rx: _Pipe, tx: _Pipe):
        self._rx = rx
        self._tx = tx
        self._closed = False
        self.bytes_sent = 0

    def send(self, data: bytes) -> None:
        if self._closed:
            raise TransportError("send on closed channel")
        with self._tx.lock:
            if self._tx.closed:
                raise TransportError("peer closed the channel")
            self._tx.buf += data
        self.bytes_sent += len(data)

    def recv(self, n: int) -> bytes:
        with self._rx.lock:
            take = min(n, len(self._rx.buf))
            if self._rx.max_chunk is not None:
                take = min(take, self._rx.max_chunk)
            out = bytes(self._rx.buf[:take])
            del self._rx.buf[:take]
            return out

    def close(self) -> None:
        self._closed = True
        with self._tx.lock:
            self._tx.closed = True
        with self._rx.lock:
            self._rx.closed = True

    @property
    def closed(self) -> bool:
        return self._closed or self._rx.closed


def memory_pair(max_chunk: Optional[int] = None
                ) -> Tuple[InMemoryChannel, InMemoryChannel]:
    """A connected in-memory channel pair (a→b, b→a)."""
    ab, ba = _Pipe(max_chunk), _Pipe(max_chunk)
    return InMemoryChannel(rx=ba, tx=ab), InMemoryChannel(rx=ab, tx=ba)


class TcpChannel(Channel):
    """A connected TCP socket as a Channel (non-blocking reads)."""

    def __init__(self, sock: socket.socket):
        sock.setblocking(True)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.sock = sock
        self._closed = False
        self.bytes_sent = 0

    def send(self, data: bytes) -> None:
        if self._closed:
            raise TransportError("send on closed channel")
        try:
            self.sock.sendall(data)
        except OSError as e:
            self._closed = True
            raise TransportError(f"socket send failed: {e}") from e
        self.bytes_sent += len(data)

    def recv(self, n: int) -> bytes:
        if self._closed:
            return b""
        try:
            ready, _, _ = select.select([self.sock], [], [], 0)
            if not ready:
                return b""
            data = self.sock.recv(n)
        except OSError as e:
            self._closed = True
            raise TransportError(f"socket recv failed: {e}") from e
        if data == b"":
            self._closed = True      # orderly peer shutdown
        return data

    def close(self) -> None:
        self._closed = True
        try:
            self.sock.close()
        except OSError:
            pass

    @property
    def closed(self) -> bool:
        return self._closed


def tcp_listen(host: str = "127.0.0.1", port: int = 0
               ) -> Tuple[socket.socket, int]:
    """Bind a listener (port 0: ephemeral); returns (socket, bound port)."""
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    srv.bind((host, port))
    srv.listen(1)
    return srv, srv.getsockname()[1]


def tcp_accept(listener: socket.socket, timeout: float = 60.0) -> TcpChannel:
    listener.settimeout(timeout)
    try:
        conn, _ = listener.accept()
    except socket.timeout as e:
        raise TransportError(f"no peer connected within {timeout}s") from e
    finally:
        listener.close()
    return TcpChannel(conn)


def tcp_connect(host: str, port: int, *, retries: int = 20,
                backoff: float = 0.1, sleep=time.sleep) -> TcpChannel:
    """Connect with retry — the worker side may start before the listener."""
    err: Optional[Exception] = None
    for attempt in range(retries + 1):
        try:
            return TcpChannel(socket.create_connection((host, port),
                                                       timeout=30.0))
        except OSError as e:
            err = e
            if attempt < retries:
                sleep(backoff * (2 ** min(attempt, 6)))
    raise TransportError(f"connect to {host}:{port} failed: {err}") from err


def tcp_pair() -> Tuple[TcpChannel, TcpChannel]:
    """A connected loopback TCP pair in one process (real sockets)."""
    srv, port = tcp_listen()
    cli = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    cli.connect(("127.0.0.1", port))
    conn, _ = srv.accept()
    srv.close()
    return TcpChannel(conn), TcpChannel(cli)


# ---------------------------------------------------------------------------
# transport registry (mirrors the scheduler/codec registries)
_TRANSPORTS: Dict[str, Callable[..., Tuple[Channel, Channel]]] = {}


def register_transport(name: str,
                       factory: Callable[..., Tuple[Channel, Channel]]
                       ) -> None:
    _TRANSPORTS[name] = factory


def build_transport(name: str, **kwargs) -> Tuple[Channel, Channel]:
    """Build a connected channel pair (prefill end, decode end)."""
    if name not in _TRANSPORTS:
        raise KeyError(f"unknown transport {name!r}; "
                       f"registered: {registered_transports()}")
    return _TRANSPORTS[name](**kwargs)


def registered_transports() -> Tuple[str, ...]:
    return tuple(sorted(_TRANSPORTS))


register_transport("memory", memory_pair)
register_transport("tcp", tcp_pair)


# ---------------------------------------------------------------------------
# leaf/tree serialization (optionally through a tenant codec)
@dataclasses.dataclass
class _WireLeaf:
    """One tensor leaf in wire form: raw numpy, or codec (q, scale)."""

    data: np.ndarray
    scale: Optional[np.ndarray]
    dtype: str
    codec: Optional[str]

    @property
    def nbytes(self) -> int:
        return self.data.nbytes + (self.scale.nbytes
                                   if self.scale is not None else 0)


def _is_wire_leaf(x) -> bool:
    return isinstance(x, _WireLeaf)


def _encode_leaf(x, codec: Optional[str]) -> _WireLeaf:
    dtype = str(np.dtype(x.dtype))
    if codec is not None and jnp.issubdtype(x.dtype, jnp.floating):
        q, scale = encode_tensor(get_codec(codec), jnp.asarray(x))
        return _WireLeaf(np.asarray(q), np.asarray(scale), dtype, codec)
    return _WireLeaf(np.asarray(x), None, dtype, None)


def _decode_leaf(leaf: _WireLeaf) -> np.ndarray:
    if leaf.codec is None:
        return leaf.data
    x = decode_tensor(get_codec(leaf.codec), jnp.asarray(leaf.data),
                      jnp.asarray(leaf.scale), dtype=jnp.dtype(leaf.dtype))
    return np.asarray(x)


def _encode_tree(tree, codec: Optional[str]) -> Tuple[Any, float, float, int]:
    """→ (wired tree, raw tensor bytes, encoded wire bytes, leaf count)."""
    raw = wire = 0.0
    calls = 0

    def enc(x):
        nonlocal raw, wire, calls
        leaf = _encode_leaf(x, codec)
        raw += float(np.prod(np.shape(x)) or 1) * np.dtype(x.dtype).itemsize
        wire += leaf.nbytes
        calls += 1
        return leaf

    return jax.tree.map(enc, tree), raw, wire, calls


def _decode_tree(tree) -> Any:
    return jax.tree.map(_decode_leaf, tree, is_leaf=_is_wire_leaf)


# ---------------------------------------------------------------------------
class WireHandoff:
    """Decode-side view of one in-flight session, reconstructed off the
    wire.  Duck-types the :class:`~repro.serve.disagg.KVHandoff` surface
    the decode engine and ``PagedKVCacheManager.adopt`` consume."""

    def __init__(self, session: Session, length: int, pages: List[Any],
                 slot_one: Any, requeues: int = 0):
        self.session = session
        self.length = length
        self.pages = pages               # wired trees, decoded at fetch
        self.slot_one = slot_one
        self.requeues = requeues

    @property
    def uid(self) -> int:
        return self.session.uid

    @property
    def num_pages(self) -> int:
        return len(self.pages)


def _control(channel: Channel, runtime: MemoryRuntime, kind: int,
             msg: Dict[str, Any]) -> None:
    frame = pack_frame(kind, pickle.dumps(msg, pickle.HIGHEST_PROTOCOL))
    channel.send(frame)
    runtime.meter_transfer("kv_wire", len(frame), len(frame))


class WireSender:
    """Prefill-side half of the wire: duck-types the ``TransferQueue``
    surface the prefill-role Engine drives (``has_room`` / ``publish`` /
    ``depth`` / ``sweep_cancelled`` / ``traffic_report``).

    ``max_depth`` is enforced as a *credit window*: a published handoff
    occupies a credit until the decode side ACKs its adoption (or
    discard), so queue pressure backs up into the prefill scheduler
    exactly as in the loopback.  ``codec_for`` (tenant → codec name, e.g.
    ``QuotaManager.codec_for``) routes float page leaves through the
    tenant codec so compressed pages cross the wire compressed."""

    def __init__(self, channel: Channel, runtime: MemoryRuntime, *,
                 max_depth: Optional[int] = None,
                 codec_for: Optional[Callable[[str],
                                              Optional[str]]] = None,
                 quota: Optional[QuotaManager] = None,
                 retries: int = 10, backoff: float = 0.005,
                 sleep=time.sleep):
        if max_depth is not None and max_depth < 1:
            raise ValueError(f"max_depth must be >= 1: {max_depth}")
        self.channel = channel
        self.runtime = runtime
        self.max_depth = max_depth
        self.codec_for = codec_for
        self.quota = quota
        self._retries, self._backoff, self._sleep = retries, backoff, sleep
        self._inflight: Dict[int, Session] = {}   # published, not adopted
        self._adopted: Dict[int, Session] = {}    # ACKed, awaiting RESULT
        self.completed: List[Session] = []        # RESULT applied
        self.peer_done = False
        # counters named like TransferQueue's (trace suites cross-check)
        self.published = 0
        self.delivered = 0          # ACKs applied (adopted by the peer)
        self.requeued = 0
        self.swept = 0
        self.results = 0
        self.shipped_pages = 0

    # ------------------------------------------------------------------
    def depth(self) -> int:
        self.pump()
        return len(self._inflight)

    def outstanding(self) -> int:
        """Sessions the peer still owes a RESULT for."""
        return len(self._inflight) + len(self._adopted)

    def has_room(self, pending: int = 0) -> bool:
        self.pump()
        return self.max_depth is None or \
            len(self._inflight) + pending < self.max_depth

    def parked_uids(self) -> Tuple[int, ...]:
        return tuple(self._inflight)

    # ------------------------------------------------------------------
    def publish(self, handoff: KVHandoff, pages: List[Any],
                slot_one: Any = None) -> None:
        """Serialize + send one handoff as a HANDOFF frame.

        Metering happens only after a successful send — a
        :class:`TransportError` leaves the report, the credit window and
        the counters untouched (the engine requeues the session and
        releases its quota charge; see ``Engine._publish_handoffs``)."""
        sess = handoff.session
        req = sess.request
        codec = self.codec_for(sess.tenant) if self.codec_for else None
        wired_pages, raw, wire, calls = [], 0.0, 0.0, 0
        for page in pages:
            w, r, b, c = _encode_tree(page, codec)
            wired_pages.append(w)
            raw, wire, calls = raw + r, wire + b, calls + c
        wired_slot = None
        if slot_one is not None:
            wired_slot, r, b, c = _encode_tree(slot_one, codec)
            raw, wire, calls = raw + r, wire + b, calls + c
        msg = {
            "schema": SCHEMA_VERSION,
            "uid": sess.uid,
            "tenant": sess.tenant,
            "prompt": np.asarray(req.prompt),
            "max_new_tokens": int(req.max_new_tokens),
            "eos_id": int(req.eos_id),
            "priority": int(getattr(req, "priority", 0)),
            "deadline": getattr(req, "deadline", None),
            "tokens": list(sess.tokens),
            "length": int(handoff.length),
            "requeues": int(handoff.requeues),
            "pages": wired_pages,
            "slot_one": wired_slot,
        }
        frame = pack_frame(K_HANDOFF,
                           pickle.dumps(msg, pickle.HIGHEST_PROTOCOL))
        self.channel.send(frame)
        self.runtime.meter_transfer("kv_publish", raw, wire, calls=calls)
        self.runtime.meter_transfer("kv_wire", len(frame), len(frame))
        self._inflight[sess.uid] = sess
        self.published += 1
        self.shipped_pages += len(pages)

    # ------------------------------------------------------------------
    def pump(self) -> None:
        """Drain control frames (ACK / RESULT / BYE) off the channel."""
        while True:
            got = recv_frame(self.channel, retries=self._retries,
                             backoff=self._backoff, sleep=self._sleep)
            if got is None:
                return
            kind, payload = got
            msg = pickle.loads(payload)
            if kind == K_ACK:
                sess = self._inflight.pop(msg["uid"], None)
                if sess is not None:
                    self._adopted[msg["uid"]] = sess
                    self.delivered += 1
            elif kind == K_RESULT:
                self._apply_result(msg)
            elif kind == K_BYE:
                self.peer_done = True
            else:
                raise WireFormatError(
                    f"unexpected frame kind {_KIND_NAMES.get(kind, kind)} "
                    "on the prefill side")

    def _apply_result(self, msg: Dict[str, Any]) -> None:
        uid = msg["uid"]
        sess = self._adopted.pop(uid, None) or self._inflight.pop(uid, None)
        self.results += 1
        if self.quota is not None:
            self.quota.release_uid(uid)
        if sess is None:
            return
        if not sess.done:
            # same list object: keep the Request.out_tokens alias intact
            del sess.tokens[:]
            sess.tokens.extend(msg["tokens"])
            sess.length = int(msg["length"])
            sess.finish(msg["finish_reason"])
        self.completed.append(sess)

    # ------------------------------------------------------------------
    def sweep_cancelled(self) -> List[Session]:
        """CANCEL in-flight sessions whose submitter cancelled them;
        returns the swept sessions (the engine releases their quota)."""
        self.pump()
        swept: List[Session] = []
        for store in (self._inflight, self._adopted):
            for uid, sess in list(store.items()):
                if sess.done:
                    del store[uid]
                    _control(self.channel, self.runtime, K_CANCEL,
                             {"uid": uid})
                    self.swept += 1
                    swept.append(sess)
        return swept

    def send_bye(self) -> None:
        _control(self.channel, self.runtime, K_BYE, {})

    # ------------------------------------------------------------------
    def traffic_report(self) -> Dict[str, Any]:
        report = dict(self.runtime.traffic_report())
        report["transfer"] = {
            "published": self.published,
            "delivered": self.delivered,
            "requeued": self.requeued,
            "swept": self.swept,
            "depth": len(self._inflight),
            "shipped_pages": self.shipped_pages,
            "adopted_pages": 0,
            "results": self.results,
        }
        return report

    def describe(self) -> str:
        cap = "" if self.max_depth is None else f"/{self.max_depth}"
        return (f"wire-out[depth={len(self._inflight)}{cap} "
                f"shipped={self.shipped_pages}p results={self.results}]")


class WireReceiver:
    """Decode-side half of the wire: duck-types the ``TransferQueue``
    surface the decode-role Engine and ``PagedKVCacheManager.adopt``
    consume (``next_ready`` / ``requeue`` / ``fetch_pages`` /
    ``fetch_slot_leaves`` / ``discard`` / ``sweep_cancelled``).

    HANDOFF frames reconstruct the session (Request fields + the tokens
    emitted so far) and park a :class:`WireHandoff`; adoption ACKs back
    (freeing a sender credit), retirement sends RESULT with the full
    token stream.  ``flush_results`` runs inside ``sweep_cancelled`` so a
    plain ``Engine.step`` loop needs no extra wiring."""

    def __init__(self, channel: Channel, runtime: MemoryRuntime, *,
                 retries: int = 10, backoff: float = 0.005,
                 sleep=time.sleep):
        self.channel = channel
        self.runtime = runtime
        self._retries, self._backoff, self._sleep = retries, backoff, sleep
        self._parked: Deque[WireHandoff] = deque()
        self._sessions: Dict[int, Session] = {}
        self._result_sent: set = set()
        self._seq = 0
        self.peer_done = False
        self.published = 0          # HANDOFF frames received
        self.delivered = 0
        self.requeued = 0
        self.swept = 0
        self.shipped_pages = 0
        self.adopted_pages = 0

    # ------------------------------------------------------------------
    def _restore_session(self, msg: Dict[str, Any]) -> Session:
        from repro.serve.engine import Request
        req = Request(uid=msg["uid"], prompt=msg["prompt"],
                      max_new_tokens=msg["max_new_tokens"],
                      eos_id=msg["eos_id"], priority=msg["priority"],
                      tenant=msg["tenant"], deadline=msg["deadline"])
        sess = Session(request=req, seq=self._seq)
        self._seq += 1
        sess.tokens.extend(msg["tokens"])
        sess.length = msg["length"]
        return sess

    def pump(self) -> None:
        while True:
            got = recv_frame(self.channel, retries=self._retries,
                             backoff=self._backoff, sleep=self._sleep)
            if got is None:
                return
            kind, payload = got
            msg = pickle.loads(payload)
            if kind == K_HANDOFF:
                if msg["schema"] != SCHEMA_VERSION:
                    raise WireFormatError(
                        f"handoff header schema v{msg['schema']} != "
                        f"v{SCHEMA_VERSION}")
                sess = self._restore_session(msg)
                self._sessions[sess.uid] = sess
                self._parked.append(WireHandoff(
                    sess, msg["length"], msg["pages"], msg["slot_one"],
                    requeues=msg["requeues"]))
                self.published += 1
                self.shipped_pages += len(msg["pages"])
            elif kind == K_CANCEL:
                sess = self._sessions.get(msg["uid"])
                if sess is not None and not sess.done:
                    sess.cancel()
            elif kind == K_BYE:
                self.peer_done = True
            else:
                raise WireFormatError(
                    f"unexpected frame kind {_KIND_NAMES.get(kind, kind)} "
                    "on the decode side")

    # ------------------------------------------------------------------
    def depth(self) -> int:
        self.pump()
        return len(self._parked)

    def has_room(self, pending: int = 0) -> bool:
        return True                  # the sender's credit window bounds us

    def parked_uids(self) -> Tuple[int, ...]:
        return tuple(h.uid for h in self._parked)

    def next_ready(self) -> Optional[WireHandoff]:
        self.pump()
        if not self._parked:
            return None
        self.delivered += 1
        return self._parked.popleft()

    def requeue(self, handoff: WireHandoff) -> None:
        handoff.requeues += 1
        self.requeued += 1
        self._parked.append(handoff)

    # ------------------------------------------------------------------
    def _ack(self, handoff: WireHandoff) -> None:
        _control(self.channel, self.runtime, K_ACK, {"uid": handoff.uid})

    def fetch_pages(self, handoff: WireHandoff) -> List[Any]:
        """Decode the shipped pages (metered ``kv_adopt``: raw = tensor
        bytes, wire = encoded bytes) and ACK the adoption — the sender's
        credit frees once the pages have landed."""
        pages = []
        raw = wire = 0.0
        calls = 0
        for tree in handoff.pages:
            for leaf in jax.tree.leaves(tree, is_leaf=_is_wire_leaf):
                raw += float(np.prod(leaf.data.shape) or 1) * \
                    np.dtype(leaf.dtype).itemsize if leaf.codec else \
                    float(leaf.data.nbytes)
                wire += leaf.nbytes
                calls += 1
            pages.append(_decode_tree(tree))
        self.runtime.meter_transfer("kv_adopt", raw, wire, calls=calls)
        self.adopted_pages += len(pages)
        handoff.pages = []
        self._ack(handoff)
        return pages

    def fetch_slot_leaves(self, handoff: WireHandoff) -> Any:
        if handoff.slot_one is None:
            return None
        raw = wire = 0.0
        calls = 0
        for leaf in jax.tree.leaves(handoff.slot_one, is_leaf=_is_wire_leaf):
            raw += float(np.prod(leaf.data.shape) or 1) * \
                np.dtype(leaf.dtype).itemsize if leaf.codec else \
                float(leaf.data.nbytes)
            wire += leaf.nbytes
            calls += 1
        self.runtime.meter_transfer("kv_adopt", raw, wire, calls=calls)
        out = _decode_tree(handoff.slot_one)
        handoff.slot_one = None
        return out

    def discard(self, handoff: WireHandoff) -> None:
        """Drop an unconsumed handoff (cancelled in transit) and ACK so
        the sender's credit window frees anyway."""
        handoff.pages = []
        handoff.slot_one = None
        self._ack(handoff)

    # ------------------------------------------------------------------
    def sweep_cancelled(self) -> List[Session]:
        self.pump()
        swept: List[Session] = []
        for handoff in [h for h in self._parked if h.session.done]:
            self._parked.remove(handoff)
            self.discard(handoff)
            self.swept += 1
            swept.append(handoff.session)
        self.flush_results()
        return swept

    def flush_results(self) -> None:
        """Send RESULT for every locally retired session, exactly once."""
        parked = {h.uid for h in self._parked}
        for uid, sess in list(self._sessions.items()):
            if not sess.done or uid in self._result_sent or uid in parked:
                continue
            _control(self.channel, self.runtime, K_RESULT, {
                "uid": uid,
                "tokens": list(sess.tokens),
                "length": int(sess.length),
                "finish_reason": sess.finish_reason or FINISH_CANCELLED,
            })
            self._result_sent.add(uid)

    def pending_results(self) -> int:
        parked = {h.uid for h in self._parked}
        return sum(1 for uid, s in self._sessions.items()
                   if s.done and uid not in self._result_sent
                   and uid not in parked)

    def send_bye(self) -> None:
        _control(self.channel, self.runtime, K_BYE, {})

    # ------------------------------------------------------------------
    def traffic_report(self) -> Dict[str, Any]:
        report = dict(self.runtime.traffic_report())
        report["transfer"] = {
            "published": self.published,
            "delivered": self.delivered,
            "requeued": self.requeued,
            "swept": self.swept,
            "depth": len(self._parked),
            "shipped_pages": self.shipped_pages,
            "adopted_pages": self.adopted_pages,
        }
        return report

    def describe(self) -> str:
        return (f"wire-in[depth={len(self._parked)} "
                f"adopted={self.adopted_pages}p requeued={self.requeued}]")


# ---------------------------------------------------------------------------
def _wire_runtime(model) -> MemoryRuntime:
    """A metering runtime for one wire endpoint (kv_wire / kv_publish /
    kv_adopt accounting; nothing is stashed through its tier)."""
    return MemoryRuntime(
        model.plan,
        MemoryPlan(policy="host", placement=model.memory.placement),
        model.mesh, planner=model.planner)


class WirePrefill:
    """Prefill half of a cross-process pair: local prefill engine + the
    :class:`WireSender`; the decode engine lives behind the channel.
    Steppable/routable like a :class:`~repro.serve.disagg.DisaggPair`
    (``decode is None`` marks the remote half)."""

    decode = None

    def __init__(self, prefill, sender: WireSender,
                 window_hint: Optional[int] = None):
        if prefill.role != "prefill" or prefill.transfer is not sender:
            raise ValueError("need a prefill-role engine driving THIS "
                             "WireSender")
        self.prefill = prefill
        self.transfer = sender
        self.window_hint = window_hint

    def submit(self, req=None, on_token=None, session=None) -> Session:
        return self.prefill.submit(req, on_token=on_token, session=session)

    def step(self) -> int:
        shipped = self.prefill.step()
        self.transfer.pump()
        return shipped + self.transfer.outstanding()

    def has_work(self) -> bool:
        return (self.prefill.scheduler.has_waiting()
                or bool(self.prefill.cache.running())
                or self.transfer.outstanding() > 0)

    def run(self, max_steps: int = 100_000, idle_sleep: float = 0.002,
            sleep=time.sleep) -> List[Any]:
        for _ in range(max_steps):
            busy = self.step()
            if not self.has_work():
                break
            if busy == 0:
                sleep(idle_sleep)     # waiting on the remote decode
        return self.prefill.finished + \
            [s.request for s in self.transfer.completed]

    def close(self) -> None:
        self.transfer.send_bye()

    def traffic_report(self) -> Dict[str, Any]:
        return {"transfer": self.transfer.traffic_report(),
                "prefill": self.prefill.traffic_report()}

    def quota_report(self) -> Dict[str, Any]:
        return self.prefill.quota_report()

    def describe(self) -> str:
        return (f"wire-prefill[{self.prefill.describe()} -> "
                f"{self.transfer.describe()}]")


class WirePair:
    """Both halves in one process, joined by a real (byte-serialized)
    channel pair — the wire twin of the loopback
    :class:`~repro.serve.disagg.DisaggPair`, and the harness the
    bit-identity suite drives: every page crosses the channel as frames,
    yet the token streams must match the loopback exactly."""

    def __init__(self, prefill, decode, sender: WireSender,
                 receiver: WireReceiver):
        if prefill.role != "prefill" or decode.role != "decode":
            raise ValueError(f"need (prefill, decode) roles, got "
                             f"({prefill.role!r}, {decode.role!r})")
        if prefill.transfer is not sender or decode.transfer is not receiver:
            raise ValueError("engines must drive THIS sender/receiver pair")
        if prefill._page_size != decode.cache.page_size:
            raise ValueError(
                f"page_size mismatch: prefill ships {prefill._page_size}-row "
                f"pages, decode pools {decode.cache.page_size}-row frames")
        if prefill.max_len != decode.max_len:
            raise ValueError(f"max_len mismatch: {prefill.max_len} vs "
                             f"{decode.max_len}")
        self.prefill = prefill
        self.decode = decode
        self.sender = sender
        self.receiver = receiver
        # router-facing alias: the pair's transfer depth is the sender's
        # credit window (parked on either side of the wire)
        self.transfer = sender

    # ------------------------------------------------------------------
    def submit(self, req=None, on_token=None, session=None) -> Session:
        return self.prefill.submit(req, on_token=on_token, session=session)

    def step(self) -> int:
        shipped = self.prefill.step()
        active = self.decode.step()
        self.receiver.flush_results()
        self.sender.pump()
        return shipped + active

    def has_work(self) -> bool:
        return (self.prefill.scheduler.has_waiting()
                or bool(self.prefill.cache.running())
                or self.sender.outstanding() > 0
                or self.receiver.depth() > 0
                or self.receiver.pending_results() > 0
                or self.decode.scheduler.has_waiting()
                or bool(self.decode.cache.running()))

    def run(self, max_steps: int = 10_000) -> List[Any]:
        for _ in range(max_steps):
            self.step()
            if not self.has_work():
                break
        return self.prefill.finished + \
            [s.request for s in self.sender.completed]

    # ------------------------------------------------------------------
    def traffic_report(self) -> Dict[str, Any]:
        return {"wire_out": self.sender.traffic_report(),
                "wire_in": self.receiver.traffic_report(),
                "decode": self.decode.traffic_report(),
                "prefill": self.prefill.traffic_report()}

    def quota_report(self) -> Dict[str, Any]:
        return self.prefill.quota_report()

    def describe(self) -> str:
        return (f"wire[{self.prefill.describe()} -> "
                f"{self.sender.describe()} | {self.receiver.describe()} "
                f"-> {self.decode.describe()}]")


# ---------------------------------------------------------------------------
def build_wire_pair(model, params, *,
                    transport: str = "memory",
                    channels: Optional[Tuple[Channel, Channel]] = None,
                    batch: Optional[int] = None,
                    max_len: Optional[int] = None,
                    page_size: int = 16,
                    pages: Optional[int] = None,
                    prefill_batch: int = 1,
                    max_depth: Optional[int] = None,
                    scheduler: Union[str, Any] = "fcfs",
                    decode_scheduler: Union[str, Any, None] = None,
                    spill: Union[str, Any, None] = "spill",
                    quota: Union[QuotaManager, TenantQuota,
                                 Dict[str, TenantQuota], None] = None,
                    wire_codec: Union[bool, str, None] = None,
                    temperature: float = 0.0, seed: int = 0,
                    **cache_kwargs) -> WirePair:
    """Wire a prefill/decode pair over a real byte channel.

    Mirrors :func:`~repro.serve.disagg.build_disagg` (same seed
    discipline: decode samples from ``seed + 1``) with the loopback
    ``TransferQueue`` replaced by a serialized channel.  ``wire_codec``:
    None — raw pages; ``True`` — each tenant's quota codec
    (``QuotaManager.codec_for``, lossy codecs trade wire bytes for
    fidelity); a codec name — that codec for every tenant."""
    from repro.serve.engine import Engine   # circular-at-import avoidance

    tx, rx = channels if channels is not None else build_transport(transport)

    if quota is None or isinstance(quota, QuotaManager):
        shared_quota = quota
    elif isinstance(quota, TenantQuota):
        shared_quota = QuotaManager(default_quota=quota)
    else:
        shared_quota = QuotaManager(dict(quota))

    if wire_codec is True:
        codec_for = shared_quota.codec_for if shared_quota else None
    elif isinstance(wire_codec, str):
        get_codec(wire_codec)               # raise early on unknown codec
        codec_for = lambda tenant: wire_codec   # noqa: E731
    else:
        codec_for = None

    if decode_scheduler is None:
        decode_scheduler = scheduler if isinstance(scheduler, str) else "fcfs"

    sender = WireSender(tx, _wire_runtime(model), max_depth=max_depth,
                        codec_for=codec_for, quota=shared_quota)
    receiver = WireReceiver(rx, _wire_runtime(model))

    decode = Engine(model, params, batch=batch, max_len=max_len,
                    temperature=temperature, seed=seed + 1,
                    scheduler=decode_scheduler, spill=spill,
                    page_size=page_size, pages=pages, quota=shared_quota,
                    role="decode", transfer=receiver, **cache_kwargs)
    prefill = Engine(model, params, batch=prefill_batch,
                     max_len=decode.max_len,
                     temperature=temperature, seed=seed,
                     scheduler=scheduler, spill=None,
                     page_size=page_size, quota=shared_quota,
                     role="prefill", transfer=sender)
    return WirePair(prefill, decode, sender, receiver)


def build_wire_prefill(model, params, channel: Channel, *,
                       max_len: Optional[int] = None,
                       page_size: int = 16,
                       prefill_batch: int = 1,
                       max_depth: Optional[int] = None,
                       scheduler: Union[str, Any] = "fcfs",
                       quota: Optional[QuotaManager] = None,
                       wire_codec: Optional[str] = None,
                       window_hint: Optional[int] = None,
                       temperature: float = 0.0,
                       seed: int = 0) -> WirePrefill:
    """The prefill half for a two-process deployment (decode is remote)."""
    from repro.serve.engine import Engine

    codec_for = (lambda tenant: wire_codec) if wire_codec else None
    sender = WireSender(channel, _wire_runtime(model), max_depth=max_depth,
                        codec_for=codec_for, quota=quota)
    prefill = Engine(model, params, batch=prefill_batch, max_len=max_len,
                     temperature=temperature, seed=seed,
                     scheduler=scheduler, spill=None, page_size=page_size,
                     quota=quota, role="prefill", transfer=sender)
    return WirePrefill(prefill, sender, window_hint=window_hint)


def run_decode_worker(model, params, channel: Channel, *,
                      batch: Optional[int] = None,
                      max_len: Optional[int] = None,
                      page_size: int = 16,
                      pages: Optional[int] = None,
                      scheduler: Union[str, Any] = "fcfs",
                      spill: Union[str, Any, None] = "spill",
                      temperature: float = 0.0, seed: int = 1,
                      max_steps: int = 1_000_000,
                      idle_sleep: float = 0.002, sleep=time.sleep):
    """Decode-worker main loop for the two-process deployment.

    Adopts handoffs off ``channel``, decodes, RESULTs back; exits when the
    prefill side says BYE and everything local has retired.  ``seed``
    must be the prefill side's ``seed + 1`` for the cross-process streams
    to match the loopback (``build_disagg`` seed discipline).  Returns
    the decode engine (its traffic report prices the adopted bytes)."""
    from repro.serve.engine import Engine

    receiver = WireReceiver(channel, _wire_runtime(model))
    eng = Engine(model, params, batch=batch, max_len=max_len,
                 temperature=temperature, seed=seed, scheduler=scheduler,
                 spill=spill, page_size=page_size, pages=pages,
                 role="decode", transfer=receiver)
    for _ in range(max_steps):
        busy = eng.step()
        receiver.flush_results()
        idle = (busy == 0 and not eng.scheduler.has_waiting()
                and not eng.cache.running() and receiver.depth() == 0
                and receiver.pending_results() == 0)
        if idle and receiver.peer_done:
            break
        if idle:
            sleep(idle_sleep)        # poll the channel for the next frame
    receiver.send_bye()
    channel.close()
    return eng
