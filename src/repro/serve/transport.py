"""Wire transport for KV handoffs — the cross-host serving fabric leg.

serve/disagg.py ships prefilled KV between roles through an in-process
:class:`~repro.serve.disagg.TransferQueue`; its docstring names the wire
transport as the out-of-scope remainder.  This module is that transport:
the same handoff unit (pickleable header + page-shaped arrays), serialized
into length-prefixed frames over a pluggable byte :class:`Channel`, so the
prefill and decode engines can live in different processes (or different
hosts) and still produce the bit-identical token streams the cross-role
trace-equivalence suite pins.

Wire format (versioned — satellite of PR 7)::

    frame := magic "KW" | schema u16 | kind u8 | len u32 | payload | crc u32

The CRC32 covers the header AND payload; a schema mismatch or a failed CRC
raises :class:`WireFormatError` *before* any unpickling — garbage frames
never reach ``pickle.loads``.  Payloads are pickled dicts of numpy leaves;
float page leaves optionally pass through a ``core/compress.py`` codec so
compressed pages cross the wire compressed (``_WireLeaf`` carries the
quantized data + scale + codec name).

Frame kinds: ``HANDOFF`` (prefill→decode: header + pages), ``ACK``
(decode→prefill on adoption/discard — drives the sender's ``max_depth``
credit window), ``CANCEL`` (prefill→decode: cancelled in transit),
``RESULT`` (decode→prefill on retire: the full token stream + finish
reason, applied to the original session so the submitter's ``Session``
object completes exactly as in the loopback), ``BYE`` (clean shutdown).

Metering: every frame a side *sends* is metered on that side's
:class:`~repro.core.runtime.MemoryRuntime` as ``kv_wire`` with the exact
frame byte count (``wire_bytes == raw_bytes == len(frame)``), via
``MemoryRuntime.meter_transfer``.  Page payloads additionally meter as
``kv_publish`` (serialize side: raw = tensor bytes, wire = encoded bytes)
and ``kv_adopt`` (decode side, same convention) so the wire reconciles
against the loopback accounting: summed over both runtimes, ``kv_wire``
equals the bytes that crossed the channel exactly, and
``kv_wire >= kv_publish.wire`` (framing + header overhead).

Partial reads retry with exponential backoff — the ``train/fault.py``
``retry_step`` idiom: ``backoff * 2**attempt`` between attempts, no
terminal sleep, ``sleep`` injectable for fake-clock tests — and exhaust
into :class:`TransportError`.  Channels come from a registry mirroring
the scheduler/codec registries: ``"memory"`` (in-process pair, test
default; ``max_chunk`` simulates fragmented reads) and ``"tcp"``
(loopback socket pair; :func:`tcp_listen`/:func:`tcp_connect` build the
two-process halves).
"""
from __future__ import annotations

import dataclasses
import logging
import pickle
import queue
import select
import socket
import struct
import threading
import time
import zlib
from collections import deque
from typing import (Any, Callable, Deque, Dict, List, Optional, Sequence,
                    Tuple, Union)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MemoryPlan
from repro.core.compress import decode_tensor, encode_tensor, get_codec
from repro.core.runtime import MemoryRuntime
from repro.serve.disagg import KVHandoff
from repro.serve.quota import QuotaManager, TenantQuota
from repro.serve.session import FINISH_CANCELLED, Session, SessionState

log = logging.getLogger(__name__)

#: bump on any change to the frame layout or the HANDOFF payload schema
#: (v2: striped page frames, K_PAGE/K_ABORT/K_HELLO, federation kinds)
SCHEMA_VERSION = 2

_MAGIC = b"KW"
_HEADER = struct.Struct(">2sHBI")        # magic, schema, kind, payload len
_CRC = struct.Struct(">I")
_PAGE_SUB = struct.Struct(">II")         # meta len, out-of-band buffer count

(K_HANDOFF, K_ACK, K_CANCEL, K_RESULT, K_BYE,
 K_PAGE, K_ABORT, K_HELLO,
 K_FWD, K_FWD_RESULT, K_FWD_REJECT, K_LOAD, K_QUOTA, K_DRAIN) = range(1, 15)
_KIND_NAMES = {K_HANDOFF: "HANDOFF", K_ACK: "ACK", K_CANCEL: "CANCEL",
               K_RESULT: "RESULT", K_BYE: "BYE", K_PAGE: "PAGE",
               K_ABORT: "ABORT", K_HELLO: "HELLO", K_FWD: "FWD",
               K_FWD_RESULT: "FWD_RESULT", K_FWD_REJECT: "FWD_REJECT",
               K_LOAD: "LOAD", K_QUOTA: "QUOTA", K_DRAIN: "DRAIN"}


class TransportError(RuntimeError):
    """A channel failed mid-transfer (closed peer, exhausted retries)."""


class WireFormatError(TransportError):
    """A frame failed validation (magic/schema/CRC) — never unpickled."""


# ---------------------------------------------------------------------------
# framing
def pack_frame(kind: int, payload: bytes) -> bytes:
    head = _HEADER.pack(_MAGIC, SCHEMA_VERSION, kind, len(payload))
    crc = zlib.crc32(payload, zlib.crc32(head)) & 0xFFFFFFFF
    return head + payload + _CRC.pack(crc)


def _read_exact(channel: "Channel", n: int, *, started: bool,
                retries: int, backoff: float, sleep) -> Optional[bytes]:
    """Read exactly ``n`` bytes from ``channel``.

    Returns None when ``started`` is False and nothing at all is buffered
    (no frame on the wire — the polling case).  Once any byte of a frame
    has been read, an empty read retries with exponential backoff
    (``backoff * 2**attempt``, no sleep after the terminal attempt) and
    exhausts into :class:`TransportError` — a frame, once begun, must
    complete."""
    recv_into = getattr(channel, "recv_into", None)
    buf = bytearray(n) if recv_into is not None else bytearray()
    view = memoryview(buf) if recv_into is not None else None
    pos = 0
    attempt = 0
    while pos < n:
        if recv_into is not None:
            got = recv_into(view[pos:])         # straight into the buffer
            if got:
                pos += got
                attempt = 0
                continue
        else:
            chunk = channel.recv(n - pos)
            if chunk:
                buf += chunk
                pos += len(chunk)
                attempt = 0
                continue
        if pos == 0 and not started:
            return None
        if channel.closed and attempt >= retries:
            raise TransportError(
                f"channel closed mid-frame: got {pos}/{n} bytes")
        if attempt >= retries:
            raise TransportError(
                f"partial read: {pos}/{n} bytes after "
                f"{retries + 1} attempts")
        # a channel that can block on readability (TCP: select) waits at
        # the kernel instead of sleeping — mid-frame latency is then the
        # data's arrival time, not the backoff schedule
        waiter = getattr(channel, "wait_readable", None)
        if waiter is not None:
            waiter(backoff * (2 ** attempt))
        else:
            sleep(backoff * (2 ** attempt))
        attempt += 1
    return buf          # bytearray: skips a full copy on multi-MB frames


def recv_frame(channel: "Channel", *, retries: int = 10,
               backoff: float = 0.005, sleep=time.sleep
               ) -> Optional[Tuple[int, bytes]]:
    """Read one validated frame; None when no frame is on the wire.

    Validation order is deliberate: magic, then schema, then CRC — a
    mismatched schema or corrupted frame raises :class:`WireFormatError`
    with a clear message instead of handing garbage to ``pickle``.

    A failure mid-frame (exhausted retries with a frame begun, or a
    validation error) leaves the byte stream desynchronized: the next
    read would parse payload bytes as a header.  The channel is therefore
    *poisoned* — every later ``recv_frame`` on it fails fast with the
    original reason instead of returning garbage frames."""
    reason = getattr(channel, "poisoned", None)
    if reason is not None:
        raise TransportError(
            f"channel poisoned by an earlier framing failure ({reason}); "
            "the byte stream is desynchronized — reconnect required")
    try:
        head = _read_exact(channel, _HEADER.size, started=False,
                           retries=retries, backoff=backoff, sleep=sleep)
        if head is None:
            return None
        magic, schema, kind, n = _HEADER.unpack(head)
        if magic != _MAGIC:
            raise WireFormatError(
                f"bad frame magic {magic!r} (want {_MAGIC!r}): not a KV wire "
                "frame, refusing to unpickle")
        if schema != SCHEMA_VERSION:
            raise WireFormatError(
                f"wire schema v{schema} from peer, this build speaks "
                f"v{SCHEMA_VERSION} — upgrade the older side (refusing to "
                "unpickle a mismatched layout)")
        payload = _read_exact(channel, n, started=True, retries=retries,
                              backoff=backoff, sleep=sleep)
        (crc,) = _CRC.unpack(_read_exact(channel, _CRC.size, started=True,
                                         retries=retries, backoff=backoff,
                                         sleep=sleep))
        # bulk K_PAGE frames checksum with Adler-32 (zlib's own stream
        # check — ~2x CRC32 throughput, same burst detection at MB
        # scale); control/header frames keep CRC32
        if kind == K_PAGE:
            want = zlib.adler32(payload, zlib.adler32(head)) & 0xFFFFFFFF
        else:
            want = zlib.crc32(payload, zlib.crc32(head)) & 0xFFFFFFFF
        if crc != want:
            raise WireFormatError(
                f"frame CRC mismatch (got {crc:#010x}, computed {want:#010x}): "
                "corrupted frame, refusing to unpickle")
        return kind, payload
    except (WireFormatError, TransportError) as e:
        try:
            channel.poisoned = str(e)
        except AttributeError:
            pass
        raise


# ---------------------------------------------------------------------------
# channels
class Channel:
    """One endpoint of a byte pipe.

    ``send`` writes the whole buffer or raises :class:`TransportError`;
    ``recv(n)`` returns *up to* n bytes — possibly fewer, possibly ``b""``
    when nothing is buffered (framing handles reassembly + retry).

    ``poisoned`` is set by :func:`recv_frame` when a framing failure
    leaves the byte stream desynchronized; later reads fail fast."""

    poisoned: Optional[str] = None

    def send(self, data: bytes) -> None:
        raise NotImplementedError

    def recv(self, n: int) -> bytes:
        raise NotImplementedError

    def close(self) -> None:
        raise NotImplementedError

    @property
    def closed(self) -> bool:
        raise NotImplementedError


class _Pipe:
    """One direction of an in-memory channel pair (lock-guarded)."""

    def __init__(self, max_chunk: Optional[int] = None):
        self.buf = bytearray()
        self.max_chunk = max_chunk
        self.closed = False
        self.lock = threading.Lock()


class InMemoryChannel(Channel):
    """In-process byte pipe: the test/loopback transport.

    ``max_chunk`` bounds one ``recv`` — set it small to exercise the
    partial-read reassembly path without a real socket.  ``bytes_sent``
    counts every byte pushed through ``send``, the ground truth the
    ``kv_wire`` reconciliation tests compare against."""

    def __init__(self, rx: _Pipe, tx: _Pipe):
        self._rx = rx
        self._tx = tx
        self._closed = False
        self.bytes_sent = 0

    def send(self, data: bytes) -> None:
        if self._closed:
            raise TransportError("send on closed channel")
        with self._tx.lock:
            if self._tx.closed:
                raise TransportError("peer closed the channel")
            self._tx.buf += data
        self.bytes_sent += len(data)

    def recv(self, n: int) -> bytes:
        with self._rx.lock:
            take = min(n, len(self._rx.buf))
            if self._rx.max_chunk is not None:
                take = min(take, self._rx.max_chunk)
            out = bytes(self._rx.buf[:take])
            del self._rx.buf[:take]
            return out

    def close(self) -> None:
        self._closed = True
        with self._tx.lock:
            self._tx.closed = True
        with self._rx.lock:
            self._rx.closed = True

    @property
    def closed(self) -> bool:
        return self._closed or self._rx.closed


def memory_pair(max_chunk: Optional[int] = None
                ) -> Tuple[InMemoryChannel, InMemoryChannel]:
    """A connected in-memory channel pair (a→b, b→a)."""
    ab, ba = _Pipe(max_chunk), _Pipe(max_chunk)
    return InMemoryChannel(rx=ba, tx=ab), InMemoryChannel(rx=ab, tx=ba)


class TcpChannel(Channel):
    """A connected TCP socket as a Channel (non-blocking reads).

    ``TCP_NODELAY`` is always set (control frames must not sit behind
    Nagle); ``bufsize`` sizes ``SO_SNDBUF``/``SO_RCVBUF`` so a multi-MB
    handoff is not throttled by default kernel buffers (the
    ``--wire-bufsize`` flag; measured in the ``BENCH_wire`` sweep)."""

    def __init__(self, sock: socket.socket, *,
                 bufsize: Optional[int] = None):
        sock.setblocking(True)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        if bufsize:
            sock.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, int(bufsize))
            sock.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, int(bufsize))
        self.sock = sock
        self._closed = False
        self.bytes_sent = 0

    def send(self, data: bytes) -> None:
        if self._closed:
            raise TransportError("send on closed channel")
        try:
            self.sock.sendall(data)
        except OSError as e:
            self._closed = True
            raise TransportError(f"socket send failed: {e}") from e
        self.bytes_sent += len(data)

    def recv(self, n: int) -> bytes:
        if self._closed:
            return b""
        try:
            ready, _, _ = select.select([self.sock], [], [], 0)
            if not ready:
                return b""
            data = self.sock.recv(n)
        except OSError as e:
            self._closed = True
            raise TransportError(f"socket recv failed: {e}") from e
        if data == b"":
            self._closed = True      # orderly peer shutdown
        return data

    def recv_into(self, view: memoryview) -> int:
        """Read directly into ``view`` (zero intermediate copy); 0 when
        nothing is buffered."""
        if self._closed:
            return 0
        try:
            ready, _, _ = select.select([self.sock], [], [], 0)
            if not ready:
                return 0
            got = self.sock.recv_into(view)
        except OSError as e:
            self._closed = True
            raise TransportError(f"socket recv failed: {e}") from e
        if got == 0:
            self._closed = True      # readable + 0 bytes: peer shutdown
        return got

    def wait_readable(self, timeout: float) -> bool:
        """Block until data is readable (or timeout); lets frame reads
        park at the kernel instead of backoff-sleeping."""
        if self._closed:
            return False
        try:
            ready, _, _ = select.select([self.sock], [], [], timeout)
        except OSError:
            return False
        return bool(ready)

    def close(self) -> None:
        self._closed = True
        try:
            self.sock.close()
        except OSError:
            pass

    @property
    def closed(self) -> bool:
        return self._closed


def tcp_listen(host: str = "127.0.0.1", port: int = 0, *,
               backlog: int = 1) -> Tuple[socket.socket, int]:
    """Bind a listener (port 0: ephemeral); returns (socket, bound port)."""
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    srv.bind((host, port))
    srv.listen(max(1, backlog))
    return srv, srv.getsockname()[1]


def tcp_accept(listener: socket.socket, timeout: float = 60.0, *,
               bufsize: Optional[int] = None) -> TcpChannel:
    listener.settimeout(timeout)
    try:
        conn, _ = listener.accept()
    except socket.timeout as e:
        raise TransportError(f"no peer connected within {timeout}s") from e
    finally:
        listener.close()
    return TcpChannel(conn, bufsize=bufsize)


def tcp_connect(host: str, port: int, *, retries: int = 20,
                backoff: float = 0.1, sleep=time.sleep,
                bufsize: Optional[int] = None) -> TcpChannel:
    """Connect with retry — the worker side may start before the listener."""
    err: Optional[Exception] = None
    for attempt in range(retries + 1):
        try:
            return TcpChannel(socket.create_connection((host, port),
                                                       timeout=30.0),
                              bufsize=bufsize)
        except OSError as e:
            err = e
            if attempt < retries:
                sleep(backoff * (2 ** min(attempt, 6)))
    raise TransportError(f"connect to {host}:{port} failed: {err}") from err


def tcp_pair(*, bufsize: Optional[int] = None
             ) -> Tuple[TcpChannel, TcpChannel]:
    """A connected loopback TCP pair in one process (real sockets)."""
    srv, port = tcp_listen()
    cli = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    cli.connect(("127.0.0.1", port))
    conn, _ = srv.accept()
    srv.close()
    return TcpChannel(conn, bufsize=bufsize), TcpChannel(cli, bufsize=bufsize)


def tcp_accept_many(listener: socket.socket, n: int,
                    timeout: float = 60.0, *,
                    bufsize: Optional[int] = None) -> List[TcpChannel]:
    """Accept ``n`` stripe connections; each announces its stripe index
    with a HELLO frame, so accept order need not match connect order."""
    listener.settimeout(timeout)
    chans: List[Optional[TcpChannel]] = [None] * n
    deadline = time.monotonic() + timeout
    try:
        for _ in range(n):
            conn, _ = listener.accept()
            ch = TcpChannel(conn, bufsize=bufsize)
            got = None
            while got is None:
                if time.monotonic() > deadline:
                    raise TransportError(
                        f"stripe HELLO did not arrive within {timeout}s")
                got = recv_frame(ch, retries=4, backoff=0.01)
                if got is None:
                    time.sleep(0.01)
            kind, payload = got
            if kind != K_HELLO:
                raise TransportError(
                    f"expected HELLO on a new stripe connection, got "
                    f"{_KIND_NAMES.get(kind, kind)}")
            hello = pickle.loads(payload)
            if int(hello.get("streams", 0)) != n:
                raise TransportError(
                    f"stripe-count mismatch: peer connected with "
                    f"{hello.get('streams')} streams, this side expects {n}")
            idx = int(hello["stripe"])
            if not 0 <= idx < n or chans[idx] is not None:
                raise TransportError(f"bad or duplicate stripe index {idx}")
            chans[idx] = ch
    except socket.timeout as e:
        raise TransportError(
            f"{sum(c is not None for c in chans)}/{n} stripes connected "
            f"within {timeout}s") from e
    finally:
        listener.close()
    return [c for c in chans if c is not None]


def tcp_accept_striped(listener: socket.socket, streams: int,
                       timeout: float = 60.0, *,
                       bufsize: Optional[int] = None) -> "StripedChannel":
    return StripedChannel(tcp_accept_many(listener, streams, timeout,
                                          bufsize=bufsize))


def tcp_connect_striped(host: str, port: int, streams: int, *,
                        retries: int = 20, backoff: float = 0.1,
                        sleep=time.sleep,
                        bufsize: Optional[int] = None) -> "StripedChannel":
    """Open ``streams`` connections to one listener, announcing each
    stripe index with a HELLO frame."""
    chans: List[TcpChannel] = []
    for i in range(streams):
        ch = tcp_connect(host, port, retries=retries, backoff=backoff,
                         sleep=sleep, bufsize=bufsize)
        ch.send(pack_frame(K_HELLO, pickle.dumps(
            {"stripe": i, "streams": streams}, pickle.HIGHEST_PROTOCOL)))
        chans.append(ch)
    return StripedChannel(chans)


# ---------------------------------------------------------------------------
# transport registry (mirrors the scheduler/codec registries)
_TRANSPORTS: Dict[str, Callable[..., Tuple[Channel, Channel]]] = {}


def register_transport(name: str,
                       factory: Callable[..., Tuple[Channel, Channel]]
                       ) -> None:
    _TRANSPORTS[name] = factory


def build_transport(name: str, **kwargs) -> Tuple[Channel, Channel]:
    """Build a connected channel pair (prefill end, decode end)."""
    if name not in _TRANSPORTS:
        raise KeyError(f"unknown transport {name!r}; "
                       f"registered: {registered_transports()}")
    return _TRANSPORTS[name](**kwargs)


def registered_transports() -> Tuple[str, ...]:
    return tuple(sorted(_TRANSPORTS))


register_transport("memory", memory_pair)
register_transport("tcp", tcp_pair)


# ---------------------------------------------------------------------------
# leaf/tree serialization (optionally through a tenant codec)
@dataclasses.dataclass
class _WireLeaf:
    """One tensor leaf in wire form: raw numpy, or codec (q, scale)."""

    data: np.ndarray
    scale: Optional[np.ndarray]
    dtype: str
    codec: Optional[str]

    @property
    def nbytes(self) -> int:
        return self.data.nbytes + (self.scale.nbytes
                                   if self.scale is not None else 0)


def _is_wire_leaf(x) -> bool:
    return isinstance(x, _WireLeaf)


def _encode_leaf(x, codec: Optional[str]) -> _WireLeaf:
    dtype = str(np.dtype(x.dtype))
    if codec is not None and jnp.issubdtype(x.dtype, jnp.floating):
        q, scale = encode_tensor(get_codec(codec), jnp.asarray(x))
        return _WireLeaf(np.asarray(q), np.asarray(scale), dtype, codec)
    return _WireLeaf(np.asarray(x), None, dtype, None)


def _decode_leaf(leaf: _WireLeaf) -> np.ndarray:
    if leaf.codec is None:
        return leaf.data
    x = decode_tensor(get_codec(leaf.codec), jnp.asarray(leaf.data),
                      jnp.asarray(leaf.scale), dtype=jnp.dtype(leaf.dtype))
    return np.asarray(x)


def _encode_tree(tree, codec: Optional[str]) -> Tuple[Any, float, float, int]:
    """→ (wired tree, raw tensor bytes, encoded wire bytes, leaf count)."""
    raw = wire = 0.0
    calls = 0

    def enc(x):
        nonlocal raw, wire, calls
        leaf = _encode_leaf(x, codec)
        raw += float(np.prod(np.shape(x)) or 1) * np.dtype(x.dtype).itemsize
        wire += leaf.nbytes
        calls += 1
        return leaf

    return jax.tree.map(enc, tree), raw, wire, calls


def _decode_tree(tree) -> Any:
    return jax.tree.map(_decode_leaf, tree, is_leaf=_is_wire_leaf)


# ---------------------------------------------------------------------------
# message-level channels: striped multi-stream + zero-copy shared memory.
#
# These speak whole messages instead of bytes (``send_msg`` /
# ``send_handoff`` / ``poll_msg``); WireSender/WireReceiver detect that
# surface via the ``_send_msg``/``_send_handoff``/``_poll_msg`` helpers
# below and skip their own framing.
def _send_msg(channel, kind: int, msg: Any) -> int:
    """Send one message; returns the exact bytes that hit the wire."""
    if hasattr(channel, "send_msg"):
        return channel.send_msg(kind, msg)
    frame = pack_frame(kind, pickle.dumps(msg, pickle.HIGHEST_PROTOCOL))
    channel.send(frame)
    return len(frame)


def _send_handoff_msg(channel, msg: Dict[str, Any],
                      wired_pages: List[Any]) -> int:
    """Send one HANDOFF (header ``msg`` without pages + the wired page
    trees); single-stream channels carry the pages inline in the header
    frame exactly as the v1 wire did."""
    if hasattr(channel, "send_handoff"):
        return channel.send_handoff(msg, wired_pages)
    whole = dict(msg)
    whole["pages"] = wired_pages
    return _send_msg(channel, K_HANDOFF, whole)


def _poll_msg(channel, *, retries: int = 10, backoff: float = 0.005,
              sleep=time.sleep) -> Optional[Tuple[int, Any]]:
    """Receive one whole message; None when nothing is deliverable."""
    if hasattr(channel, "poll_msg"):
        return channel.poll_msg()
    got = recv_frame(channel, retries=retries, backoff=backoff, sleep=sleep)
    if got is None:
        return None
    kind, payload = got
    return kind, pickle.loads(payload)


def _send_page_frame(channel: Channel, msg: Dict[str, Any]) -> int:
    """Send one K_PAGE frame with pickle-5 out-of-band buffers.

    Payload layout: ``meta_len u32 | nbufs u32 | nbufs × len u64 | meta
    (pickle) | buffers``.  The page's tensor bytes go to the channel as
    raw buffer views — no intermediate pickle copy, no frame join — and
    the checksum folds incrementally over each segment (Adler-32: zlib's
    stream check, ~2x CRC32 throughput on the bulk bytes that dominate a
    handoff), so a stripe worker spends its time on checksum + syscalls
    instead of memcpy."""
    bufs: List[memoryview] = []
    meta = pickle.dumps(msg, protocol=pickle.HIGHEST_PROTOCOL,
                        buffer_callback=lambda b: bufs.append(b.raw()))
    sizes = [b.nbytes for b in bufs]
    sub = _PAGE_SUB.pack(len(meta), len(bufs)) + \
        struct.pack(f">{len(bufs)}Q", *sizes)
    total = len(sub) + len(meta) + sum(sizes)
    head = _HEADER.pack(_MAGIC, SCHEMA_VERSION, K_PAGE, total)
    crc = zlib.adler32(meta, zlib.adler32(sub, zlib.adler32(head)))
    for mv in bufs:
        crc = zlib.adler32(mv, crc)
    channel.send(head + sub + meta)
    for mv in bufs:
        channel.send(mv)
    channel.send(_CRC.pack(crc & 0xFFFFFFFF))
    return _HEADER.size + total + _CRC.size


def _unpack_page_payload(payload: bytes) -> Dict[str, Any]:
    meta_len, nbufs = _PAGE_SUB.unpack_from(payload, 0)
    off = _PAGE_SUB.size
    sizes = struct.unpack_from(f">{nbufs}Q", payload, off)
    off += 8 * nbufs
    view = memoryview(payload)
    meta = view[off:off + meta_len]
    off += meta_len
    bufs = []
    for s in sizes:
        bufs.append(view[off:off + s])
        off += s
    return pickle.loads(meta, buffers=bufs)


class _SendBatch:
    """Completion barrier for one multi-frame send across stripes."""

    def __init__(self, n: int):
        self._cv = threading.Condition()
        self._left = n
        self.bytes = 0
        self.errors: List[BaseException] = []

    def done(self, nbytes: int, err: Optional[BaseException] = None) -> None:
        with self._cv:
            if err is None:
                self.bytes += nbytes
            else:
                self.errors.append(err)
            self._left -= 1
            if self._left <= 0:
                self._cv.notify_all()

    def wait(self, timeout: float = 300.0) -> None:
        with self._cv:
            if not self._cv.wait_for(lambda: self._left <= 0, timeout):
                self.errors.append(TransportError(
                    f"stripe send stalled for {timeout}s"))


class _StripeTx(threading.Thread):
    """Per-stripe send worker: pickles, frames, CRCs, writes its stripe."""

    def __init__(self, index: int, channel: Channel):
        super().__init__(name=f"kv-wire-tx{index}", daemon=True)
        self.channel = channel
        self.jobs: "queue.Queue" = queue.Queue()
        self.start()

    def run(self) -> None:
        while True:
            job = self.jobs.get()
            if job is None:
                return
            kind, msg, batch = job
            try:
                if kind == K_PAGE:
                    batch.done(_send_page_frame(self.channel, msg))
                else:
                    frame = pack_frame(
                        kind, pickle.dumps(msg, pickle.HIGHEST_PROTOCOL))
                    self.channel.send(frame)
                    batch.done(len(frame))
            except BaseException as e:            # surfaced via the batch
                batch.done(0, err=e)

    def stop(self) -> None:
        self.jobs.put(None)


class _StripeRx(threading.Thread):
    """Per-stripe receive worker: reads, validates and unpickles frames
    into the shared inbox, so CRC + decode parallelize across stripes."""

    def __init__(self, index: int, channel: Channel, inbox, cond, *,
                 retries: int, backoff: float, poll_sleep: float):
        super().__init__(name=f"kv-wire-rx{index}", daemon=True)
        self.index = index
        self.channel = channel
        self.inbox = inbox
        self.cond = cond
        self.retries, self.backoff = retries, backoff
        self.poll_sleep = poll_sleep
        self.failed: Optional[BaseException] = None
        self._halt = False
        self.start()

    def run(self) -> None:
        while not self._halt:
            try:
                got = recv_frame(self.channel, retries=self.retries,
                                 backoff=self.backoff)
                if got is None:
                    if self.channel.closed:
                        return
                    waiter = getattr(self.channel, "wait_readable", None)
                    if waiter is not None:
                        waiter(self.poll_sleep)
                    else:
                        time.sleep(self.poll_sleep)
                    continue
                kind, payload = got
                msg = (_unpack_page_payload(payload) if kind == K_PAGE
                       else pickle.loads(payload))
            except BaseException as e:
                self.failed = e
                with self.cond:
                    self.cond.notify_all()
                return
            with self.cond:
                self.inbox.append((self.index, kind, msg))
                self.cond.notify_all()

    def halt(self) -> None:
        self._halt = True


class StripedChannel:
    """Bandwidth-scalable frame fan-out over N byte sub-channels.

    Each HANDOFF shards page-wise: the header rides stripe 0 — the same
    FIFO every control frame (ACK/CANCEL/RESULT/BYE) uses, so ordered
    delivery of control traffic is preserved — and page ``seq`` goes to
    stripe ``seq % N`` as a K_PAGE frame tagged ``(msg_id, seq)``.  The
    receive side reassembles by sequence number and delivers messages
    strictly in stripe-0 arrival order, which makes the striped wire
    observationally identical to the single-stream one (the bit-identity
    suite pins this).  Per-stripe send/recv worker threads carry the
    pickle/CRC work, and K_PAGE frames use pickle-5 out-of-band buffers
    so page bytes reach the socket without an intermediate copy.

    A stripe dying mid-handoff surfaces :class:`TransportError` from
    ``send_handoff`` (the engine requeues the session) and a best-effort
    ABORT on stripe 0 tells the peer to drop the partial reassembly; if
    stripe 0 itself is dead the channel poisons and fails fast."""

    def __init__(self, channels: Sequence[Channel], *, retries: int = 10,
                 backoff: float = 0.005, poll_sleep: float = 0.002):
        if not channels:
            raise ValueError("need at least one stripe channel")
        self.stripes = list(channels)
        self.poisoned: Optional[str] = None
        self._closed = False
        self._send_id = 0
        self._cond = threading.Condition()
        self._inbox: Deque[Tuple[int, int, Any]] = deque()
        self._ordered: Deque[Tuple[int, Any]] = deque()
        self._partial: Dict[int, Dict[int, Any]] = {}   # msg_id -> seq->page
        self._aborted: set = set()
        self._tx = [_StripeTx(i, ch) for i, ch in enumerate(self.stripes)]
        self._rx = [_StripeRx(i, ch, self._inbox, self._cond,
                              retries=retries, backoff=backoff,
                              poll_sleep=poll_sleep)
                    for i, ch in enumerate(self.stripes)]

    # ------------------------------------------------------------------
    @property
    def streams(self) -> int:
        return len(self.stripes)

    @property
    def bytes_sent(self) -> int:
        return sum(getattr(ch, "bytes_sent", 0) for ch in self.stripes)

    @property
    def closed(self) -> bool:
        return self._closed or any(ch.closed for ch in self.stripes)

    def _fail_fast(self) -> None:
        if self.poisoned is not None:
            raise TransportError(
                f"striped channel poisoned: {self.poisoned}")
        for rx in self._rx:
            if rx.failed is not None:
                self.poisoned = (f"stripe {rx.index} receive failed: "
                                 f"{rx.failed}")
                raise TransportError(self.poisoned) from rx.failed

    # ------------------------------------------------------------------
    def send_msg(self, kind: int, msg: Any) -> int:
        self._fail_fast()
        batch = _SendBatch(1)
        self._tx[0].jobs.put((kind, msg, batch))
        batch.wait()
        if batch.errors:
            err = TransportError(f"stripe 0 send failed: {batch.errors[0]}")
            err.wire_bytes = batch.bytes
            raise err from batch.errors[0]
        return batch.bytes

    def send_handoff(self, msg: Dict[str, Any],
                     wired_pages: List[Any]) -> int:
        self._fail_fast()
        self._send_id += 1
        mid = self._send_id
        header = dict(msg)
        header["pages"] = []
        header["striped"] = {"msg_id": mid, "n_pages": len(wired_pages)}
        batch = _SendBatch(1 + len(wired_pages))
        self._tx[0].jobs.put((K_HANDOFF, header, batch))
        for seq, page in enumerate(wired_pages):
            self._tx[seq % len(self._tx)].jobs.put(
                (K_PAGE, {"msg_id": mid, "seq": seq, "page": page}, batch))
        batch.wait()
        if batch.errors:
            sent = batch.bytes
            ab = _SendBatch(1)
            self._tx[0].jobs.put((K_ABORT, {"msg_id": mid}, ab))
            ab.wait(timeout=10.0)
            if ab.errors:
                self.poisoned = (f"stripe 0 dead while aborting a partial "
                                 f"handoff: {ab.errors[0]}")
            else:
                sent += ab.bytes
            err = TransportError(
                f"striped handoff failed mid-send: {batch.errors[0]}")
            err.wire_bytes = sent
            raise err from batch.errors[0]
        return batch.bytes

    # ------------------------------------------------------------------
    def poll_msg(self) -> Optional[Tuple[int, Any]]:
        self._fail_fast()
        with self._cond:
            items = list(self._inbox)
            self._inbox.clear()
        for _idx, kind, msg in items:
            if kind == K_PAGE:
                mid = msg["msg_id"]
                if mid in self._aborted:
                    continue
                self._partial.setdefault(mid, {})[msg["seq"]] = msg["page"]
            else:
                self._ordered.append((kind, msg))
        while self._ordered:
            kind, msg = self._ordered[0]
            if kind == K_ABORT:
                self._ordered.popleft()
                self._drop(msg["msg_id"])
                continue
            meta = msg.get("striped") if kind == K_HANDOFF else None
            if meta is not None:
                mid, n = meta["msg_id"], meta["n_pages"]
                got = self._partial.get(mid, {})
                if len(got) < n:
                    if self._pending_abort(mid):
                        self._ordered.popleft()
                        self._drop(mid)
                        continue
                    return None       # wait for the rest of the pages
                self._ordered.popleft()
                self._partial.pop(mid, None)
                msg = dict(msg)
                msg["pages"] = [got[i] for i in range(n)]
                del msg["striped"]
                return kind, msg
            self._ordered.popleft()
            return kind, msg
        return None

    def _pending_abort(self, mid: int) -> bool:
        found = next((item for item in self._ordered
                      if item[0] == K_ABORT and item[1]["msg_id"] == mid),
                     None)
        if found is None:
            return False
        self._ordered.remove(found)
        return True

    def _drop(self, mid: int) -> None:
        self._partial.pop(mid, None)
        self._aborted.add(mid)

    # ------------------------------------------------------------------
    def close(self) -> None:
        self._closed = True
        for tx in self._tx:
            tx.stop()
        for rx in self._rx:
            rx.halt()
        for ch in self.stripes:
            ch.close()
        for t in (*self._tx, *self._rx):
            t.join(timeout=5.0)

    def describe(self) -> str:
        return f"striped[{len(self.stripes)} stripes]"


def striped_pair(streams: int, *, base: str = "memory",
                 max_chunk: Optional[int] = None,
                 bufsize: Optional[int] = None
                 ) -> Tuple[StripedChannel, StripedChannel]:
    """A connected striped pair over ``streams`` sub-channel pairs."""
    pairs = []
    for _ in range(streams):
        if base == "memory":
            pairs.append(memory_pair(max_chunk))
        elif base == "tcp":
            pairs.append(tcp_pair(bufsize=bufsize))
        else:
            pairs.append(build_transport(base))
    return (StripedChannel([p[0] for p in pairs]),
            StripedChannel([p[1] for p in pairs]))


# ---------------------------------------------------------------------------
# zero-copy same-host path: payload leaves land in a shared-memory arena
DEFAULT_ARENA_BYTES = 64 << 20


class ShmArena:
    """A shared-memory block with a first-fit free-list allocator.

    The *sender* owns the arena: it creates the segment, allocates and
    writes payload blocks, and frees a handoff's blocks when the ACK for
    that handoff arrives (adoption or discard both ACK, so cancel-in-
    transit cannot leak arena space).  The receiver attaches by name and
    only ever reads."""

    def __init__(self, nbytes: Optional[int] = None, *,
                 name: Optional[str] = None):
        from multiprocessing import shared_memory
        if name is None:
            self.shm = shared_memory.SharedMemory(create=True,
                                                  size=int(nbytes))
            self.owner = True
        else:
            # the creator owns cleanup; suppress the attach-side
            # resource_tracker registration so unlink happens exactly once
            from multiprocessing import resource_tracker
            orig_register = resource_tracker.register
            resource_tracker.register = lambda *a, **k: None
            try:
                self.shm = shared_memory.SharedMemory(name=name)
            finally:
                resource_tracker.register = orig_register
            self.owner = False
        self.size = self.shm.size
        self._lock = threading.Lock()
        self._free: List[Tuple[int, int]] = [(0, self.size)]

    @property
    def name(self) -> str:
        return self.shm.name

    @staticmethod
    def _align(n: int) -> int:
        return (int(n) + 63) & ~63

    def alloc(self, nbytes: int) -> Optional[int]:
        n = self._align(nbytes)
        with self._lock:
            for i, (off, sz) in enumerate(self._free):
                if sz >= n:
                    if sz == n:
                        self._free.pop(i)
                    else:
                        self._free[i] = (off + n, sz - n)
                    return off
        return None

    def free(self, offset: int, nbytes: int) -> None:
        n = self._align(nbytes)
        with self._lock:
            self._free.append((offset, n))
            self._free.sort()
            merged: List[List[int]] = []
            for off, sz in self._free:
                if merged and merged[-1][0] + merged[-1][1] == off:
                    merged[-1][1] += sz
                else:
                    merged.append([off, sz])
            self._free = [(off, sz) for off, sz in merged]

    def write(self, offset: int, arr: np.ndarray) -> None:
        flat = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
        dst = np.frombuffer(self.shm.buf, np.uint8, count=flat.nbytes,
                            offset=offset)
        np.copyto(dst, flat)

    def read(self, offset: int, nbytes: int, dtype: str,
             shape: Tuple[int, ...]) -> np.ndarray:
        src = np.frombuffer(self.shm.buf, np.uint8, count=int(nbytes),
                            offset=offset)
        return src.copy().view(np.dtype(dtype)).reshape(shape)

    def free_bytes(self) -> int:
        with self._lock:
            return sum(sz for _, sz in self._free)

    def close(self) -> None:
        try:
            self.shm.close()
            if self.owner:
                self.shm.unlink()
        except Exception:
            pass


@dataclasses.dataclass
class _ShmLeaf:
    """One tensor leaf parked in the arena: only this descriptor (plus
    the tiny codec scale) crosses the control socket."""

    offset: int
    nbytes: int
    shape: Tuple[int, ...]
    data_dtype: str
    scale: Optional[np.ndarray]
    dtype: str
    codec: Optional[str]


class ShmChannel:
    """Zero-copy same-host transport endpoint (message-level).

    HANDOFF page leaves are copied into a shared-memory arena; only the
    header + arena offsets cross the control channel, so ``kv_wire``
    meters header bytes while ``kv_publish``/``kv_adopt`` still reconcile
    the tensor bytes.  The receiver attaches the arena by name from the
    first header (works across processes on one host) and copies leaves
    out at delivery; the sender frees a handoff's blocks when its ACK
    comes back.  If the arena is full, leaves ship inline in the header
    (counted in ``arena_spills``) — correctness never depends on arena
    headroom."""

    def __init__(self, control: Channel, *,
                 arena_bytes: int = DEFAULT_ARENA_BYTES,
                 retries: int = 10, backoff: float = 0.005,
                 sleep=time.sleep):
        self.control = control
        self.arena_bytes = int(arena_bytes)
        self._arena: Optional[ShmArena] = None        # lazily on first send
        self._peer_arena: Optional[ShmArena] = None   # attached on recv
        self._allocs: Dict[int, List[Tuple[int, int]]] = {}  # uid -> blocks
        self._retries, self._backoff, self._sleep = retries, backoff, sleep
        self.arena_spills = 0

    # ------------------------------------------------------------------
    @property
    def poisoned(self) -> Optional[str]:
        return getattr(self.control, "poisoned", None)

    @property
    def bytes_sent(self) -> int:
        return getattr(self.control, "bytes_sent", 0)

    @property
    def closed(self) -> bool:
        return self.control.closed

    @property
    def arena(self) -> Optional[ShmArena]:
        return self._arena

    # ------------------------------------------------------------------
    def send_msg(self, kind: int, msg: Any) -> int:
        frame = pack_frame(kind, pickle.dumps(msg, pickle.HIGHEST_PROTOCOL))
        self.control.send(frame)
        return len(frame)

    def send_handoff(self, msg: Dict[str, Any],
                     wired_pages: List[Any]) -> int:
        if self._arena is None:
            need = sum(leaf.data.nbytes
                       for tree in wired_pages
                       for leaf in jax.tree.leaves(tree,
                                                   is_leaf=_is_wire_leaf))
            self._arena = ShmArena(max(self.arena_bytes, 2 * int(need)))
        arena = self._arena
        blocks: List[Tuple[int, int]] = []

        def stash(leaf: _WireLeaf):
            data = np.ascontiguousarray(leaf.data)
            off = arena.alloc(data.nbytes)
            if off is None:
                self.arena_spills += 1
                return leaf              # arena full: ship inline
            arena.write(off, data)
            blocks.append((off, data.nbytes))
            return _ShmLeaf(off, data.nbytes, tuple(data.shape),
                            str(data.dtype), leaf.scale, leaf.dtype,
                            leaf.codec)

        shipped = [jax.tree.map(stash, tree, is_leaf=_is_wire_leaf)
                   for tree in wired_pages]
        out = dict(msg)
        out["pages"] = shipped
        out["arena"] = {"name": arena.name, "size": arena.size}
        try:
            nbytes = self.send_msg(K_HANDOFF, out)
        except TransportError:
            for off, sz in blocks:
                arena.free(off, sz)
            raise
        if blocks:
            self._allocs.setdefault(int(msg["uid"]), []).extend(blocks)
        return nbytes

    # ------------------------------------------------------------------
    def poll_msg(self) -> Optional[Tuple[int, Any]]:
        got = recv_frame(self.control, retries=self._retries,
                         backoff=self._backoff, sleep=self._sleep)
        if got is None:
            return None
        kind, payload = got
        msg = pickle.loads(payload)
        if kind == K_ACK:
            self._free_uid(msg.get("uid"))
        elif kind == K_HANDOFF and "arena" in msg:
            if self._peer_arena is None:
                self._peer_arena = ShmArena(name=msg["arena"]["name"])
            msg = dict(msg)
            msg.pop("arena")
            msg["pages"] = [self._inflate(t) for t in msg["pages"]]
        return kind, msg

    def _inflate(self, tree):
        def load(leaf):
            if isinstance(leaf, _WireLeaf):      # inline (arena-full) leaf
                return leaf
            data = self._peer_arena.read(leaf.offset, leaf.nbytes,
                                         leaf.data_dtype, leaf.shape)
            return _WireLeaf(data, leaf.scale, leaf.dtype, leaf.codec)

        return jax.tree.map(
            load, tree, is_leaf=lambda x: isinstance(x, (_ShmLeaf,
                                                         _WireLeaf)))

    def _free_uid(self, uid) -> None:
        for off, sz in self._allocs.pop(uid, []):
            self._arena.free(off, sz)

    # ------------------------------------------------------------------
    def close(self) -> None:
        self.control.close()
        for arena in (self._peer_arena, self._arena):
            if arena is not None:
                arena.close()
        self._peer_arena = self._arena = None

    def describe(self) -> str:
        size = self._arena.size if self._arena else 0
        return f"shm[arena={size >> 20}MB spills={self.arena_spills}]"


def shm_pair(*, arena_bytes: int = DEFAULT_ARENA_BYTES,
             max_chunk: Optional[int] = None
             ) -> Tuple[ShmChannel, ShmChannel]:
    """A connected same-host pair: in-memory control pipe + shm arena."""
    a, b = memory_pair(max_chunk)
    return (ShmChannel(a, arena_bytes=arena_bytes),
            ShmChannel(b, arena_bytes=arena_bytes))


register_transport("shm", shm_pair)


def probe_wire(*, transport: str = "memory", streams: int = 1,
               payload_mb: float = 64.0, pages: int = 64,
               codec: Optional[str] = None, repeats: int = 3,
               bufsize: Optional[int] = None,
               max_chunk: Optional[int] = None) -> Dict[str, float]:
    """Measure raw handoff throughput of one wire configuration.

    Ships a synthetic multi-page HANDOFF (``payload_mb`` of float32 KV
    split over ``pages`` page trees, optionally codec-encoded) through a
    freshly built channel pair and times send-to-full-reassembly; a drain
    thread plays the decode side so blocking transports (TCP) make
    progress.  Returns the best of ``repeats`` as ``mb_per_s`` /
    ``handoff_ms`` plus the exact ``wire_bytes`` one handoff costs — the
    numbers behind the BENCH_wire sweep and the ROADMAP wire table."""
    if streams > 1:
        tx, rx = striped_pair(streams, base=transport, bufsize=bufsize,
                              max_chunk=max_chunk)
    elif transport == "shm":
        tx, rx = shm_pair(max_chunk=max_chunk)
    elif transport == "tcp":
        tx, rx = tcp_pair(bufsize=bufsize)
    else:
        tx, rx = memory_pair(max_chunk)

    per_page = int(payload_mb * (1 << 20)) // (pages * 8)  # f32 k+v leaves
    rng = np.random.default_rng(0)
    raw_pages = [{"k": rng.standard_normal(per_page).astype(np.float32),
                  "v": rng.standard_normal(per_page).astype(np.float32)}
                 for _ in range(pages)]
    wired = [_encode_tree(p, codec)[0] for p in raw_pages]

    done = threading.Event()
    state: Dict[str, Any] = {}

    def drain(expect_uid: int) -> None:
        while True:
            got = _poll_msg(rx, retries=50, backoff=0.001)
            if got is None:
                time.sleep(0.0005)
                continue
            kind, msg = got
            if kind == K_HANDOFF and msg["uid"] == expect_uid:
                state["t_end"] = time.perf_counter()
                state["n_pages"] = len(msg["pages"])
                _send_msg(rx, K_ACK, {"uid": expect_uid})
                done.set()
                return

    best = float("inf")
    sent_bytes = 0
    try:
        for rep in range(repeats):
            done.clear()
            t = threading.Thread(target=drain, args=(rep,), daemon=True)
            t.start()
            msg = {"schema": SCHEMA_VERSION, "uid": rep, "pages": [],
                   "slot_one": None}
            t0 = time.perf_counter()
            sent_bytes = _send_handoff_msg(tx, msg, wired)
            if not done.wait(timeout=300.0):
                raise TransportError("wire probe stalled")
            best = min(best, state["t_end"] - t0)
            assert state["n_pages"] == pages
            while _poll_msg(tx) is None:     # the ACK (frees shm blocks)
                time.sleep(0.0005)
            t.join(timeout=10.0)
    finally:
        tx.close()
        rx.close()
    return {"transport": transport, "streams": float(streams),
            "payload_mb": payload_mb,
            "mb_per_s": payload_mb / best,
            "handoff_ms": best * 1e3,
            "wire_bytes": float(sent_bytes)}


# ---------------------------------------------------------------------------
class WireHandoff:
    """Decode-side view of one in-flight session, reconstructed off the
    wire.  Duck-types the :class:`~repro.serve.disagg.KVHandoff` surface
    the decode engine and ``PagedKVCacheManager.adopt`` consume."""

    def __init__(self, session: Session, length: int, pages: List[Any],
                 slot_one: Any, requeues: int = 0):
        self.session = session
        self.length = length
        self.pages = pages               # wired trees, decoded at fetch
        self.slot_one = slot_one
        self.requeues = requeues

    @property
    def uid(self) -> int:
        return self.session.uid

    @property
    def num_pages(self) -> int:
        return len(self.pages)


def _control(channel: Channel, runtime: MemoryRuntime, kind: int,
             msg: Dict[str, Any]) -> None:
    nbytes = _send_msg(channel, kind, msg)
    runtime.meter_transfer("kv_wire", nbytes, nbytes)


class WireSender:
    """Prefill-side half of the wire: duck-types the ``TransferQueue``
    surface the prefill-role Engine drives (``has_room`` / ``publish`` /
    ``depth`` / ``sweep_cancelled`` / ``traffic_report``).

    ``max_depth`` is enforced as a *credit window*: a published handoff
    occupies a credit until the decode side ACKs its adoption (or
    discard), so queue pressure backs up into the prefill scheduler
    exactly as in the loopback.  ``codec_for`` (tenant → codec name, e.g.
    ``QuotaManager.codec_for``) routes float page leaves through the
    tenant codec so compressed pages cross the wire compressed."""

    def __init__(self, channel: Channel, runtime: MemoryRuntime, *,
                 max_depth: Optional[int] = None,
                 codec_for: Optional[Callable[[str],
                                              Optional[str]]] = None,
                 quota: Optional[QuotaManager] = None,
                 retries: int = 10, backoff: float = 0.005,
                 sleep=time.sleep):
        if max_depth is not None and max_depth < 1:
            raise ValueError(f"max_depth must be >= 1: {max_depth}")
        self.channel = channel
        self.runtime = runtime
        self.max_depth = max_depth
        self.codec_for = codec_for
        self.quota = quota
        self._retries, self._backoff, self._sleep = retries, backoff, sleep
        self._inflight: Dict[int, Session] = {}   # published, not adopted
        self._adopted: Dict[int, Session] = {}    # ACKed, awaiting RESULT
        self.completed: List[Session] = []        # RESULT applied
        self.peer_done = False
        # counters named like TransferQueue's (trace suites cross-check)
        self.published = 0
        self.delivered = 0          # ACKs applied (adopted by the peer)
        self.requeued = 0
        self.swept = 0
        self.results = 0
        self.shipped_pages = 0

    # ------------------------------------------------------------------
    def depth(self) -> int:
        self.pump()
        return len(self._inflight)

    def outstanding(self) -> int:
        """Sessions the peer still owes a RESULT for."""
        return len(self._inflight) + len(self._adopted)

    def has_room(self, pending: int = 0) -> bool:
        self.pump()
        return self.max_depth is None or \
            len(self._inflight) + pending < self.max_depth

    def parked_uids(self) -> Tuple[int, ...]:
        return tuple(self._inflight)

    # ------------------------------------------------------------------
    def publish(self, handoff: KVHandoff, pages: List[Any],
                slot_one: Any = None) -> None:
        """Serialize + send one handoff as a HANDOFF frame.

        Full metering happens only after a successful send — a
        :class:`TransportError` leaves the credit window and the counters
        untouched (the engine requeues the session and releases its quota
        charge; see ``Engine._publish_handoffs``).  Bytes a striped
        channel *did* put on the wire before a stripe died are still
        metered as ``kv_wire`` (``err.wire_bytes``) so the summed-stripe
        reconciliation stays byte-exact even across faults."""
        sess = handoff.session
        req = sess.request
        codec = self.codec_for(sess.tenant) if self.codec_for else None
        wired_pages, raw, wire, calls = [], 0.0, 0.0, 0
        for page in pages:
            w, r, b, c = _encode_tree(page, codec)
            wired_pages.append(w)
            raw, wire, calls = raw + r, wire + b, calls + c
        wired_slot = None
        if slot_one is not None:
            wired_slot, r, b, c = _encode_tree(slot_one, codec)
            raw, wire, calls = raw + r, wire + b, calls + c
        msg = {
            "schema": SCHEMA_VERSION,
            "uid": sess.uid,
            "tenant": sess.tenant,
            "prompt": np.asarray(req.prompt),
            "max_new_tokens": int(req.max_new_tokens),
            "eos_id": int(req.eos_id),
            "priority": int(getattr(req, "priority", 0)),
            "deadline": getattr(req, "deadline", None),
            "tokens": list(sess.tokens),
            "length": int(handoff.length),
            "requeues": int(handoff.requeues),
            "pages": [],         # placeholder; the channel ships the pages
            "slot_one": wired_slot,
        }
        try:
            nbytes = _send_handoff_msg(self.channel, msg, wired_pages)
        except TransportError as e:
            partial = int(getattr(e, "wire_bytes", 0))
            if partial:
                self.runtime.meter_transfer("kv_wire", partial, partial)
            raise
        self.runtime.meter_transfer("kv_publish", raw, wire, calls=calls)
        self.runtime.meter_transfer("kv_wire", nbytes, nbytes)
        self._inflight[sess.uid] = sess
        self.published += 1
        self.shipped_pages += len(pages)

    # ------------------------------------------------------------------
    def pump(self) -> None:
        """Drain control frames (ACK / RESULT / BYE) off the channel."""
        while True:
            got = _poll_msg(self.channel, retries=self._retries,
                            backoff=self._backoff, sleep=self._sleep)
            if got is None:
                return
            kind, msg = got
            if kind == K_ACK:
                sess = self._inflight.pop(msg["uid"], None)
                if sess is not None:
                    self._adopted[msg["uid"]] = sess
                    self.delivered += 1
            elif kind == K_RESULT:
                self._apply_result(msg)
            elif kind == K_BYE:
                self.peer_done = True
            else:
                raise WireFormatError(
                    f"unexpected frame kind {_KIND_NAMES.get(kind, kind)} "
                    "on the prefill side")

    def _apply_result(self, msg: Dict[str, Any]) -> None:
        uid = msg["uid"]
        sess = self._adopted.pop(uid, None) or self._inflight.pop(uid, None)
        self.results += 1
        if self.quota is not None:
            self.quota.release_uid(uid)
        if sess is None:
            return
        if not sess.done:
            # same list object: keep the Request.out_tokens alias intact
            del sess.tokens[:]
            sess.tokens.extend(msg["tokens"])
            sess.length = int(msg["length"])
            sess.finish(msg["finish_reason"])
        self.completed.append(sess)

    # ------------------------------------------------------------------
    def sweep_cancelled(self) -> List[Session]:
        """CANCEL in-flight sessions whose submitter cancelled them;
        returns the swept sessions (the engine releases their quota)."""
        self.pump()
        swept: List[Session] = []
        for store in (self._inflight, self._adopted):
            for uid, sess in list(store.items()):
                if sess.done:
                    del store[uid]
                    _control(self.channel, self.runtime, K_CANCEL,
                             {"uid": uid})
                    self.swept += 1
                    swept.append(sess)
        return swept

    def send_bye(self) -> None:
        _control(self.channel, self.runtime, K_BYE, {})

    # ------------------------------------------------------------------
    def traffic_report(self) -> Dict[str, Any]:
        report = dict(self.runtime.traffic_report())
        report["transfer"] = {
            "published": self.published,
            "delivered": self.delivered,
            "requeued": self.requeued,
            "swept": self.swept,
            "depth": len(self._inflight),
            "shipped_pages": self.shipped_pages,
            "adopted_pages": 0,
            "results": self.results,
        }
        return report

    def describe(self) -> str:
        cap = "" if self.max_depth is None else f"/{self.max_depth}"
        return (f"wire-out[depth={len(self._inflight)}{cap} "
                f"shipped={self.shipped_pages}p results={self.results}]")


class WireReceiver:
    """Decode-side half of the wire: duck-types the ``TransferQueue``
    surface the decode-role Engine and ``PagedKVCacheManager.adopt``
    consume (``next_ready`` / ``requeue`` / ``fetch_pages`` /
    ``fetch_slot_leaves`` / ``discard`` / ``sweep_cancelled``).

    HANDOFF frames reconstruct the session (Request fields + the tokens
    emitted so far) and park a :class:`WireHandoff`; adoption ACKs back
    (freeing a sender credit), retirement sends RESULT with the full
    token stream.  ``flush_results`` runs inside ``sweep_cancelled`` so a
    plain ``Engine.step`` loop needs no extra wiring."""

    def __init__(self, channel: Channel, runtime: MemoryRuntime, *,
                 retries: int = 10, backoff: float = 0.005,
                 sleep=time.sleep):
        self.channel = channel
        self.runtime = runtime
        self._retries, self._backoff, self._sleep = retries, backoff, sleep
        self._parked: Deque[WireHandoff] = deque()
        self._sessions: Dict[int, Session] = {}
        self._result_sent: set = set()
        self._seq = 0
        self.peer_done = False
        self.published = 0          # HANDOFF frames received
        self.delivered = 0
        self.requeued = 0
        self.swept = 0
        self.shipped_pages = 0
        self.adopted_pages = 0

    # ------------------------------------------------------------------
    def _restore_session(self, msg: Dict[str, Any]) -> Session:
        from repro.serve.engine import Request
        req = Request(uid=msg["uid"], prompt=msg["prompt"],
                      max_new_tokens=msg["max_new_tokens"],
                      eos_id=msg["eos_id"], priority=msg["priority"],
                      tenant=msg["tenant"], deadline=msg["deadline"])
        sess = Session(request=req, seq=self._seq)
        self._seq += 1
        sess.tokens.extend(msg["tokens"])
        sess.length = msg["length"]
        return sess

    def pump(self) -> None:
        while True:
            got = _poll_msg(self.channel, retries=self._retries,
                            backoff=self._backoff, sleep=self._sleep)
            if got is None:
                return
            kind, msg = got
            if kind == K_HANDOFF:
                if msg["schema"] != SCHEMA_VERSION:
                    raise WireFormatError(
                        f"handoff header schema v{msg['schema']} != "
                        f"v{SCHEMA_VERSION}")
                sess = self._restore_session(msg)
                self._sessions[sess.uid] = sess
                self._parked.append(WireHandoff(
                    sess, msg["length"], msg["pages"], msg["slot_one"],
                    requeues=msg["requeues"]))
                self.published += 1
                self.shipped_pages += len(msg["pages"])
            elif kind == K_CANCEL:
                sess = self._sessions.get(msg["uid"])
                if sess is not None and not sess.done:
                    sess.cancel()
            elif kind == K_BYE:
                self.peer_done = True
            else:
                raise WireFormatError(
                    f"unexpected frame kind {_KIND_NAMES.get(kind, kind)} "
                    "on the decode side")

    # ------------------------------------------------------------------
    def depth(self) -> int:
        self.pump()
        return len(self._parked)

    def has_room(self, pending: int = 0) -> bool:
        return True                  # the sender's credit window bounds us

    def parked_uids(self) -> Tuple[int, ...]:
        return tuple(h.uid for h in self._parked)

    def next_ready(self) -> Optional[WireHandoff]:
        self.pump()
        if not self._parked:
            return None
        self.delivered += 1
        return self._parked.popleft()

    def requeue(self, handoff: WireHandoff) -> None:
        handoff.requeues += 1
        self.requeued += 1
        self._parked.append(handoff)

    # ------------------------------------------------------------------
    def _ack(self, handoff: WireHandoff) -> None:
        _control(self.channel, self.runtime, K_ACK, {"uid": handoff.uid})

    def fetch_pages(self, handoff: WireHandoff) -> List[Any]:
        """Decode the shipped pages (metered ``kv_adopt``: raw = tensor
        bytes, wire = encoded bytes) and ACK the adoption — the sender's
        credit frees once the pages have landed."""
        pages = []
        raw = wire = 0.0
        calls = 0
        for tree in handoff.pages:
            for leaf in jax.tree.leaves(tree, is_leaf=_is_wire_leaf):
                raw += float(np.prod(leaf.data.shape) or 1) * \
                    np.dtype(leaf.dtype).itemsize if leaf.codec else \
                    float(leaf.data.nbytes)
                wire += leaf.nbytes
                calls += 1
            pages.append(_decode_tree(tree))
        self.runtime.meter_transfer("kv_adopt", raw, wire, calls=calls)
        self.adopted_pages += len(pages)
        handoff.pages = []
        self._ack(handoff)
        return pages

    def fetch_slot_leaves(self, handoff: WireHandoff) -> Any:
        if handoff.slot_one is None:
            return None
        raw = wire = 0.0
        calls = 0
        for leaf in jax.tree.leaves(handoff.slot_one, is_leaf=_is_wire_leaf):
            raw += float(np.prod(leaf.data.shape) or 1) * \
                np.dtype(leaf.dtype).itemsize if leaf.codec else \
                float(leaf.data.nbytes)
            wire += leaf.nbytes
            calls += 1
        self.runtime.meter_transfer("kv_adopt", raw, wire, calls=calls)
        out = _decode_tree(handoff.slot_one)
        handoff.slot_one = None
        return out

    def discard(self, handoff: WireHandoff) -> None:
        """Drop an unconsumed handoff (cancelled in transit) and ACK so
        the sender's credit window frees anyway."""
        handoff.pages = []
        handoff.slot_one = None
        self._ack(handoff)

    # ------------------------------------------------------------------
    def sweep_cancelled(self) -> List[Session]:
        self.pump()
        swept: List[Session] = []
        for handoff in [h for h in self._parked if h.session.done]:
            self._parked.remove(handoff)
            self.discard(handoff)
            self.swept += 1
            swept.append(handoff.session)
        self.flush_results()
        return swept

    def flush_results(self) -> None:
        """Send RESULT for every locally retired session, exactly once."""
        parked = {h.uid for h in self._parked}
        for uid, sess in list(self._sessions.items()):
            if not sess.done or uid in self._result_sent or uid in parked:
                continue
            _control(self.channel, self.runtime, K_RESULT, {
                "uid": uid,
                "tokens": list(sess.tokens),
                "length": int(sess.length),
                "finish_reason": sess.finish_reason or FINISH_CANCELLED,
            })
            self._result_sent.add(uid)

    def pending_results(self) -> int:
        parked = {h.uid for h in self._parked}
        return sum(1 for uid, s in self._sessions.items()
                   if s.done and uid not in self._result_sent
                   and uid not in parked)

    def send_bye(self) -> None:
        _control(self.channel, self.runtime, K_BYE, {})

    # ------------------------------------------------------------------
    def traffic_report(self) -> Dict[str, Any]:
        report = dict(self.runtime.traffic_report())
        report["transfer"] = {
            "published": self.published,
            "delivered": self.delivered,
            "requeued": self.requeued,
            "swept": self.swept,
            "depth": len(self._parked),
            "shipped_pages": self.shipped_pages,
            "adopted_pages": self.adopted_pages,
        }
        return report

    def describe(self) -> str:
        return (f"wire-in[depth={len(self._parked)} "
                f"adopted={self.adopted_pages}p requeued={self.requeued}]")


# ---------------------------------------------------------------------------
def _wire_runtime(model) -> MemoryRuntime:
    """A metering runtime for one wire endpoint (kv_wire / kv_publish /
    kv_adopt accounting; nothing is stashed through its tier)."""
    return MemoryRuntime(
        model.plan,
        MemoryPlan(policy="host", placement=model.memory.placement),
        model.mesh, planner=model.planner)


class WirePrefill:
    """Prefill half of a cross-process pair: local prefill engine + the
    :class:`WireSender`; the decode engine lives behind the channel.
    Steppable/routable like a :class:`~repro.serve.disagg.DisaggPair`
    (``decode is None`` marks the remote half)."""

    decode = None

    def __init__(self, prefill, sender: WireSender,
                 window_hint: Optional[int] = None):
        if prefill.role != "prefill" or prefill.transfer is not sender:
            raise ValueError("need a prefill-role engine driving THIS "
                             "WireSender")
        self.prefill = prefill
        self.transfer = sender
        self.window_hint = window_hint

    def submit(self, req=None, on_token=None, session=None) -> Session:
        return self.prefill.submit(req, on_token=on_token, session=session)

    def step(self) -> int:
        shipped = self.prefill.step()
        self.transfer.pump()
        return shipped + self.transfer.outstanding()

    def has_work(self) -> bool:
        return (self.prefill.scheduler.has_waiting()
                or bool(self.prefill.cache.running())
                or self.transfer.outstanding() > 0)

    def run(self, max_steps: int = 100_000, idle_sleep: float = 0.002,
            sleep=time.sleep) -> List[Any]:
        for _ in range(max_steps):
            busy = self.step()
            if not self.has_work():
                break
            if busy == 0:
                sleep(idle_sleep)     # waiting on the remote decode
        return self.prefill.finished + \
            [s.request for s in self.transfer.completed]

    def close(self) -> None:
        self.transfer.send_bye()
        # drop the channel too: striped worker threads join, and an shm
        # arena unlinks here instead of leaking to interpreter shutdown
        # (BYE is already queued — peers drain buffered bytes past close)
        self.transfer.channel.close()

    def traffic_report(self) -> Dict[str, Any]:
        return {"transfer": self.transfer.traffic_report(),
                "prefill": self.prefill.traffic_report()}

    def quota_report(self) -> Dict[str, Any]:
        return self.prefill.quota_report()

    def describe(self) -> str:
        return (f"wire-prefill[{self.prefill.describe()} -> "
                f"{self.transfer.describe()}]")


class WirePair:
    """Both halves in one process, joined by a real (byte-serialized)
    channel pair — the wire twin of the loopback
    :class:`~repro.serve.disagg.DisaggPair`, and the harness the
    bit-identity suite drives: every page crosses the channel as frames,
    yet the token streams must match the loopback exactly."""

    def __init__(self, prefill, decode, sender: WireSender,
                 receiver: WireReceiver):
        if prefill.role != "prefill" or decode.role != "decode":
            raise ValueError(f"need (prefill, decode) roles, got "
                             f"({prefill.role!r}, {decode.role!r})")
        if prefill.transfer is not sender or decode.transfer is not receiver:
            raise ValueError("engines must drive THIS sender/receiver pair")
        if prefill._page_size != decode.cache.page_size:
            raise ValueError(
                f"page_size mismatch: prefill ships {prefill._page_size}-row "
                f"pages, decode pools {decode.cache.page_size}-row frames")
        if prefill.max_len != decode.max_len:
            raise ValueError(f"max_len mismatch: {prefill.max_len} vs "
                             f"{decode.max_len}")
        self.prefill = prefill
        self.decode = decode
        self.sender = sender
        self.receiver = receiver
        # router-facing alias: the pair's transfer depth is the sender's
        # credit window (parked on either side of the wire)
        self.transfer = sender

    # ------------------------------------------------------------------
    def submit(self, req=None, on_token=None, session=None) -> Session:
        return self.prefill.submit(req, on_token=on_token, session=session)

    def step(self) -> int:
        shipped = self.prefill.step()
        active = self.decode.step()
        self.receiver.flush_results()
        self.sender.pump()
        return shipped + active

    def has_work(self) -> bool:
        return (self.prefill.scheduler.has_waiting()
                or bool(self.prefill.cache.running())
                or self.sender.outstanding() > 0
                or self.receiver.depth() > 0
                or self.receiver.pending_results() > 0
                or self.decode.scheduler.has_waiting()
                or bool(self.decode.cache.running()))

    def run(self, max_steps: int = 10_000) -> List[Any]:
        for _ in range(max_steps):
            self.step()
            if not self.has_work():
                break
        return self.prefill.finished + \
            [s.request for s in self.sender.completed]

    # ------------------------------------------------------------------
    def traffic_report(self) -> Dict[str, Any]:
        return {"wire_out": self.sender.traffic_report(),
                "wire_in": self.receiver.traffic_report(),
                "decode": self.decode.traffic_report(),
                "prefill": self.prefill.traffic_report()}

    def quota_report(self) -> Dict[str, Any]:
        return self.prefill.quota_report()

    def describe(self) -> str:
        return (f"wire[{self.prefill.describe()} -> "
                f"{self.sender.describe()} | {self.receiver.describe()} "
                f"-> {self.decode.describe()}]")


# ---------------------------------------------------------------------------
def build_wire_pair(model, params, *,
                    transport: str = "memory",
                    channels: Optional[Tuple[Channel, Channel]] = None,
                    batch: Optional[int] = None,
                    max_len: Optional[int] = None,
                    page_size: int = 16,
                    pages: Optional[int] = None,
                    prefill_batch: int = 1,
                    max_depth: Optional[int] = None,
                    scheduler: Union[str, Any] = "fcfs",
                    decode_scheduler: Union[str, Any, None] = None,
                    spill: Union[str, Any, None] = "spill",
                    quota: Union[QuotaManager, TenantQuota,
                                 Dict[str, TenantQuota], None] = None,
                    wire_codec: Union[bool, str, None] = None,
                    streams: int = 1,
                    temperature: float = 0.0, seed: int = 0,
                    **cache_kwargs) -> WirePair:
    """Wire a prefill/decode pair over a real byte channel.

    Mirrors :func:`~repro.serve.disagg.build_disagg` (same seed
    discipline: decode samples from ``seed + 1``) with the loopback
    ``TransferQueue`` replaced by a serialized channel.  ``wire_codec``:
    None — raw pages; ``True`` — each tenant's quota codec
    (``QuotaManager.codec_for``, lossy codecs trade wire bytes for
    fidelity); a codec name — that codec for every tenant.  ``streams``
    > 1 stripes the handoff across that many sub-channels of the base
    ``transport`` (incompatible with ``"shm"``, which is already
    header-only on its single control socket)."""
    from repro.serve.engine import Engine   # circular-at-import avoidance

    if streams < 1:
        raise ValueError(f"streams must be >= 1: {streams}")
    if channels is not None:
        tx, rx = channels
    elif streams > 1:
        if transport == "shm":
            raise ValueError("shm is single-control-socket; striping it "
                             "is meaningless — use streams=1")
        tx, rx = striped_pair(streams, base=transport)
    else:
        tx, rx = build_transport(transport)

    if quota is None or isinstance(quota, QuotaManager):
        shared_quota = quota
    elif isinstance(quota, TenantQuota):
        shared_quota = QuotaManager(default_quota=quota)
    else:
        shared_quota = QuotaManager(dict(quota))

    if wire_codec is True:
        codec_for = shared_quota.codec_for if shared_quota else None
    elif isinstance(wire_codec, str):
        get_codec(wire_codec)               # raise early on unknown codec
        codec_for = lambda tenant: wire_codec   # noqa: E731
    else:
        codec_for = None

    if decode_scheduler is None:
        decode_scheduler = scheduler if isinstance(scheduler, str) else "fcfs"

    sender = WireSender(tx, _wire_runtime(model), max_depth=max_depth,
                        codec_for=codec_for, quota=shared_quota)
    receiver = WireReceiver(rx, _wire_runtime(model))

    decode = Engine(model, params, batch=batch, max_len=max_len,
                    temperature=temperature, seed=seed + 1,
                    scheduler=decode_scheduler, spill=spill,
                    page_size=page_size, pages=pages, quota=shared_quota,
                    role="decode", transfer=receiver, **cache_kwargs)
    prefill = Engine(model, params, batch=prefill_batch,
                     max_len=decode.max_len,
                     temperature=temperature, seed=seed,
                     scheduler=scheduler, spill=None,
                     page_size=page_size, quota=shared_quota,
                     role="prefill", transfer=sender)
    return WirePair(prefill, decode, sender, receiver)


def build_wire_prefill(model, params, channel: Channel, *,
                       max_len: Optional[int] = None,
                       page_size: int = 16,
                       prefill_batch: int = 1,
                       max_depth: Optional[int] = None,
                       scheduler: Union[str, Any] = "fcfs",
                       quota: Optional[QuotaManager] = None,
                       wire_codec: Optional[str] = None,
                       window_hint: Optional[int] = None,
                       temperature: float = 0.0,
                       seed: int = 0) -> WirePrefill:
    """The prefill half for a two-process deployment (decode is remote)."""
    from repro.serve.engine import Engine

    codec_for = (lambda tenant: wire_codec) if wire_codec else None
    sender = WireSender(channel, _wire_runtime(model), max_depth=max_depth,
                        codec_for=codec_for, quota=quota)
    prefill = Engine(model, params, batch=prefill_batch, max_len=max_len,
                     temperature=temperature, seed=seed,
                     scheduler=scheduler, spill=None, page_size=page_size,
                     quota=quota, role="prefill", transfer=sender)
    return WirePrefill(prefill, sender, window_hint=window_hint)


def run_decode_worker(model, params, channel: Channel, *,
                      batch: Optional[int] = None,
                      max_len: Optional[int] = None,
                      page_size: int = 16,
                      pages: Optional[int] = None,
                      scheduler: Union[str, Any] = "fcfs",
                      spill: Union[str, Any, None] = "spill",
                      temperature: float = 0.0, seed: int = 1,
                      max_steps: int = 1_000_000,
                      idle_sleep: float = 0.002, sleep=time.sleep):
    """Decode-worker main loop for the two-process deployment.

    Adopts handoffs off ``channel``, decodes, RESULTs back; exits when the
    prefill side says BYE and everything local has retired.  ``seed``
    must be the prefill side's ``seed + 1`` for the cross-process streams
    to match the loopback (``build_disagg`` seed discipline).  Returns
    the decode engine (its traffic report prices the adopted bytes)."""
    from repro.serve.engine import Engine

    receiver = WireReceiver(channel, _wire_runtime(model))
    eng = Engine(model, params, batch=batch, max_len=max_len,
                 temperature=temperature, seed=seed, scheduler=scheduler,
                 spill=spill, page_size=page_size, pages=pages,
                 role="decode", transfer=receiver)
    for _ in range(max_steps):
        busy = eng.step()
        receiver.flush_results()
        idle = (busy == 0 and not eng.scheduler.has_waiting()
                and not eng.cache.running() and receiver.depth() == 0
                and receiver.pending_results() == 0)
        if idle and receiver.peer_done:
            break
        if idle:
            sleep(idle_sleep)        # poll the channel for the next frame
    try:
        receiver.send_bye()          # courtesy only: the peer that said
    except TransportError:           # BYE may have hung up already
        pass
    channel.close()
    return eng
