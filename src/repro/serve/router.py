"""Cluster routing front-end over N prefill/decode engine pairs.

The serving fabric's top layer: a :class:`Router` owns a cluster-wide
admission queue and N steppable pairs (loopback
:class:`~repro.serve.disagg.DisaggPair`, in-process
:class:`~repro.serve.transport.WirePair`, or cross-process
:class:`~repro.serve.transport.WirePrefill` halves — anything with
``submit(session=)`` / ``step`` / ``has_work`` and a ``prefill`` engine),
placing sessions by a pluggable policy from a registry that mirrors the
scheduler/codec/transport registries:

- ``least_loaded`` — fewest in-system sessions (waiting + resident +
  in-flight on the transfer leg), ties to the lowest index;
- ``prefix_affinity`` — rendezvous (highest-random-weight) hash of the
  prompt's first ``prefix_len`` tokens, so sessions sharing a system
  prompt land on the same engine (KV reuse locality) yet redistribute
  minimally when an engine drains or is lost;
- ``round_robin`` — strict rotation, the baseline.

Admission is continuous-batching: each :meth:`Router.step` tops every
engine up to a bounded per-engine backlog (its ``window``) from the
cluster queue, so the load signal stays meaningful — an engine never
hoards the whole queue.  Per-tenant quotas are enforced cluster-wide for
free: every engine shares ONE :class:`~repro.serve.quota.QuotaManager`
ledger, so a tenant's pages are bounded across the cluster, not per
engine (`test_router.py` pins admitted-pages <= summed quotas).

Lifecycle: :meth:`drain` marks an engine DRAINING — placement stops
immediately, its un-admitted queue and parked transfer handoffs are
pulled back and redistributed, resident sessions retire in place, and the
engine detaches once idle (zero dropped sessions).  :meth:`fail` models
engine loss: every non-done session on the engine is reset and requeued
for a fresh prefill elsewhere — at temperature 0 the re-decoded stream is
identical, so a lost engine costs latency, never tokens.
"""
from __future__ import annotations

import logging
import zlib
from collections import deque
from typing import Any, Callable, Deque, Dict, List, NamedTuple, Optional

from repro.serve.engine import Request
from repro.serve.session import Session, SessionState

log = logging.getLogger(__name__)

ACTIVE, DRAINING, LOST, DETACHED = "active", "draining", "lost", "detached"


class EngineView(NamedTuple):
    """What a placement policy sees of one engine."""

    index: int
    load: int           # in-system sessions (waiting + resident + in-flight)
    headroom: int       # admission window minus load (placeable slots)


# ---------------------------------------------------------------------------
# placement-policy registry (mirrors scheduler/codec/transport registries)
_PLACEMENTS: Dict[str, Callable[..., "PlacementPolicy"]] = {}


def register_placement(name: str, factory: Callable[..., "PlacementPolicy"]
                       ) -> None:
    _PLACEMENTS[name] = factory


def build_placement(policy, **kwargs) -> "PlacementPolicy":
    if not isinstance(policy, str):
        return policy
    if policy not in _PLACEMENTS:
        raise KeyError(f"unknown placement policy {policy!r}; "
                       f"registered: {registered_placements()}")
    return _PLACEMENTS[policy](**kwargs)


def registered_placements() -> tuple:
    return tuple(sorted(_PLACEMENTS))


class PlacementPolicy:
    """Chooses an engine index from the placeable views (never sees
    draining/lost engines — the router filters them first)."""

    name = "base"

    def choose(self, views: List[EngineView], sess: Session) -> int:
        raise NotImplementedError

    def describe(self) -> str:
        return self.name


class LeastLoaded(PlacementPolicy):
    name = "least_loaded"

    def choose(self, views: List[EngineView], sess: Session) -> int:
        return min(views, key=lambda v: (v.load, v.index)).index


class RoundRobin(PlacementPolicy):
    """Rotate by engine *identity*, not list position: when an engine
    drains or is lost the placeable list shrinks, and a positional
    ``turn % len(views)`` cursor would shift onto whichever engine
    happens to inherit the vacated slot — double-placing on it while
    skipping another.  Remembering the last-placed engine index and
    advancing to the next-larger live index keeps the rotation fair
    across membership changes."""

    name = "round_robin"

    def __init__(self):
        self._last = -1                 # engine index placed last

    def choose(self, views: List[EngineView], sess: Session) -> int:
        order = sorted(v.index for v in views)
        nxt = next((i for i in order if i > self._last), order[0])
        self._last = nxt
        return nxt


class PrefixAffinity(PlacementPolicy):
    """Rendezvous-hash the prompt prefix onto the live engines.

    Sessions sharing their first ``prefix_len`` tokens (system prompts,
    few-shot preambles) map to the same engine, concentrating prefix KV
    where it can be reused; because each (prefix, engine) pair scores
    independently, removing an engine only remaps ITS sessions — the
    affinity of everyone else survives a drain.  ``spill_at`` headroom
    exhaustion falls back to least-loaded so a hot prefix cannot wedge
    the cluster behind one engine."""

    name = "prefix_affinity"

    def __init__(self, prefix_len: int = 8):
        self.prefix_len = prefix_len

    def _key(self, sess: Session) -> tuple:
        prompt = sess.request.prompt
        return tuple(int(t) for t in prompt[:self.prefix_len])

    def choose(self, views: List[EngineView], sess: Session) -> int:
        key = self._key(sess)

        def score(v: EngineView) -> int:
            return zlib.crc32(repr((key, v.index)).encode())

        ranked = sorted(views, key=score, reverse=True)
        for v in ranked:
            if v.headroom > 0:
                return v.index
        return ranked[0].index


register_placement("least_loaded", LeastLoaded)
register_placement("round_robin", RoundRobin)
register_placement("prefix_affinity", PrefixAffinity)


# ---------------------------------------------------------------------------
class RouterEngine:
    """One routable pair plus its cluster-side state."""

    def __init__(self, pair, index: int, window: Optional[int] = None):
        self.pair = pair
        self.index = index
        self.state = ACTIVE
        if window is None:
            window = getattr(pair, "window_hint", None)
        if window is None:
            decode = getattr(pair, "decode", None)
            window = pair.prefill.batch + (decode.batch if decode is not None
                                           else pair.prefill.batch)
        self.window = max(1, window)

    # ------------------------------------------------------------------
    def load(self) -> int:
        """In-system sessions: the placement signal."""
        p = self.pair.prefill
        n = len(p.scheduler.waiting()) + len(p.cache.running())
        n += self.pair.transfer.depth()
        decode = getattr(self.pair, "decode", None)
        if decode is not None:
            n += len(decode.scheduler.waiting()) + len(decode.cache.running())
        else:
            n += self.pair.transfer.outstanding() - self.pair.transfer.depth()
        return n

    def view(self) -> EngineView:
        load = self.load()
        return EngineView(self.index, load, self.window - load)

    def placeable(self) -> bool:
        return self.state == ACTIVE

    def live(self) -> bool:
        return self.state in (ACTIVE, DRAINING)

    def describe(self) -> str:
        return (f"engine[{self.index} {self.state} load={self.load()}"
                f"/{self.window}]")


class Router:
    """Cluster-wide admission queue + placement over N engine pairs.

    The router owns session identity: it mints each :class:`Session` with
    a cluster-global ``seq`` and hands the SAME object to whichever
    engine serves it (``Engine.submit(session=)``), so scheduler
    ordering, the token-stream alias, and quota charges survive
    redistribution.  ``now`` counts router steps — deadlines and the SLO
    report are measured on this clock."""

    def __init__(self, pairs, *, placement="least_loaded",
                 window: Optional[int] = None, **placement_kwargs):
        if not pairs:
            raise ValueError("need at least one engine pair")
        self.engines = [RouterEngine(p, i, window=window)
                        for i, p in enumerate(pairs)]
        self.policy = build_placement(placement, **placement_kwargs)
        self.queue: Deque[Session] = deque()
        self.sessions: Dict[int, Session] = {}
        self.now = 0
        self._seq = 0
        self.submitted_at: Dict[int, int] = {}
        self.first_token_at: Dict[int, int] = {}
        self.finished_at: Dict[int, int] = {}
        self.placement_log: List[tuple] = []   # (uid, engine index)
        self.requeues = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request, on_token=None) -> Session:
        """Queue a request cluster-wide; placement happens at step time."""

        def hook(sess: Session, token: int) -> None:
            self.first_token_at.setdefault(sess.uid, self.now)
            if on_token is not None:
                on_token(sess, token)

        sess = Session(request=req, seq=self._seq, on_token=hook)
        self._seq += 1
        self.sessions[sess.uid] = sess
        self.submitted_at[sess.uid] = self.now
        self.queue.append(sess)
        return sess

    def cancel(self, uid: int) -> None:
        sess = self.sessions.get(uid)
        if sess is not None and not sess.done:
            sess.cancel()

    # ------------------------------------------------------------------
    def _views(self) -> List[EngineView]:
        return [e.view() for e in self.engines if e.placeable()]

    def _place(self) -> int:
        """Top engines up from the cluster queue (continuous batching)."""
        placed = 0
        while self.queue:
            if self.queue[0].done:          # cancelled while queued
                self.queue.popleft()
                continue
            views = [v for v in self._views() if v.headroom > 0]
            if not views:
                break
            sess = self.queue.popleft()
            idx = self.policy.choose(views, sess)
            eng = self.engines[idx]
            assert eng.placeable(), \
                f"policy placed uid={sess.uid} on a {eng.state} engine"
            eng.pair.submit(session=sess)
            self.placement_log.append((sess.uid, idx))
            placed += 1
        return placed

    def step(self) -> int:
        """One cluster round: place, step every live engine, account
        retirements, detach drained engines.  Returns placed + busy."""
        self.now += 1
        placed = self._place()
        busy = 0
        for eng in self.engines:
            if eng.live():
                busy += eng.pair.step()
        self._scan_finished()
        self._advance_drains()
        return placed + busy

    def _scan_finished(self) -> None:
        for uid, sess in self.sessions.items():
            if sess.done and uid not in self.finished_at:
                self.finished_at[uid] = self.now

    def _advance_drains(self) -> None:
        for eng in self.engines:
            if eng.state == DRAINING and not eng.pair.has_work():
                eng.state = DETACHED
                log.info("engine %d drained and detached", eng.index)

    # ------------------------------------------------------------------
    def _requeue_session(self, sess: Session) -> None:
        """Reset a displaced session for a fresh prefill elsewhere.

        The quota charge is released (re-charged at readmission) and the
        partial stream is discarded — at temperature 0 the replacement
        engine re-derives the identical tokens, so displacement costs
        latency, never correctness."""
        quota = self.engines[0].pair.prefill.quota   # ONE shared ledger
        if quota is not None:
            quota.release_uid(sess.uid)
        if sess.done:
            return
        sess.rewind()                   # keeps the Request.out_tokens alias
        self.queue.append(sess)
        self.requeues += 1

    def _pull_unadmitted(self, eng: RouterEngine) -> int:
        """Pull not-yet-admitted sessions off an engine's prefill queue."""
        pulled = 0
        sched = eng.pair.prefill.scheduler
        while True:
            sess = sched.next_ready()
            if sess is None:
                break
            self._requeue_session(sess)
            pulled += 1
        return pulled

    def _pull_parked(self, eng: RouterEngine) -> int:
        """Pull parked handoffs back out of an engine's transfer leg.

        Loopback queues hand their parked sessions back (payloads
        discarded, budget returned).  A wire sender's in-flight handoffs
        are already on the remote side — they ride to completion there
        and the drain simply waits them out (``pair.has_work``)."""
        transfer = eng.pair.transfer
        if not hasattr(transfer, "discard"):
            return 0
        pulled = 0
        while True:
            handoff = transfer.next_ready()
            if handoff is None:
                break
            transfer.discard(handoff)
            self._requeue_session(handoff.session)
            pulled += 1
        return pulled

    def drain(self, index: int) -> None:
        """Gracefully drain one engine: stop placing on it immediately,
        redistribute everything not yet resident, let resident sessions
        retire in place; it detaches once idle."""
        eng = self.engines[index]
        if eng.state != ACTIVE:
            raise ValueError(f"cannot drain engine {index}: {eng.state}")
        eng.state = DRAINING
        pulled = self._pull_unadmitted(eng) + self._pull_parked(eng)
        log.info("draining engine %d: redistributed %d sessions",
                 index, pulled)

    def fail(self, index: int) -> None:
        """Engine loss: its resident KV is gone; every non-done session
        it held is requeued for a fresh prefill elsewhere."""
        eng = self.engines[index]
        if not eng.live():
            raise ValueError(f"cannot fail engine {index}: {eng.state}")
        eng.state = LOST
        displaced: Dict[int, Session] = {}
        for owner in filter(None, (eng.pair.prefill,
                                   getattr(eng.pair, "decode", None))):
            for sess in owner.sessions:
                if not sess.done:
                    displaced[sess.uid] = sess
        for sess in displaced.values():
            self._requeue_session(sess)
        log.warning("engine %d lost: requeued %d sessions",
                    index, len(displaced))

    # ------------------------------------------------------------------
    def has_work(self) -> bool:
        return bool(self.queue) or any(
            e.live() and e.pair.has_work() for e in self.engines)

    def run(self, max_steps: int = 100_000,
            on_step: Optional[Callable[["Router"], None]] = None
            ) -> List[Request]:
        """Drain the cluster; ``on_step`` (called after each round) is
        the hook drain/fail scenarios inject themselves through."""
        for _ in range(max_steps):
            if not self.has_work():
                break
            self.step()
            if on_step is not None:
                on_step(self)
        return [s.request for s in self.sessions.values() if s.done]

    # ------------------------------------------------------------------
    def slo_report(self) -> Dict[str, Any]:
        """Deadline outcomes on the router clock (finish step vs the
        request's absolute-step deadline)."""
        met = missed = 0
        for uid, sess in self.sessions.items():
            deadline = sess.request.deadline
            if deadline is None or uid not in self.finished_at:
                continue
            ok = (self.finished_at[uid] <= deadline
                  and sess.finish_reason in ("eos", "length"))
            met, missed = met + ok, missed + (not ok)
        total = met + missed
        return {"met": met, "missed": missed,
                "miss_rate": missed / total if total else 0.0}

    def ttft_report(self) -> Dict[str, float]:
        waits = [self.first_token_at[uid] - self.submitted_at[uid]
                 for uid in self.first_token_at]
        if not waits:
            return {"mean": 0.0, "p99": 0.0, "n": 0}
        waits.sort()
        return {"mean": sum(waits) / len(waits),
                "p99": float(waits[min(len(waits) - 1,
                                       int(0.99 * len(waits)))]),
                "n": len(waits)}

    def traffic_report(self) -> Dict[str, Any]:
        return {f"engine{e.index}": e.pair.traffic_report()
                for e in self.engines if e.state != LOST}

    def describe(self) -> str:
        states = " ".join(e.describe() for e in self.engines)
        return (f"router[{self.policy.describe()} queue={len(self.queue)} "
                f"now={self.now} | {states}]")


# ---------------------------------------------------------------------------
# router-to-router federation: clusters peer over the same wire framing
class _Peer:
    """Cluster-side state for one federated peer."""

    def __init__(self, name: str, channel):
        self.name = name
        self.channel = channel
        self.free = 0                   # last advertised placeable headroom
        self.draining = False
        self.closed = False
        self.outstanding: Dict[int, Session] = {}   # fid -> origin session

    def sendable(self) -> bool:
        return not (self.draining or self.closed)


#: local uids for foreign (forwarded-in) sessions live far above any
#: origin-minted uid so the two spaces can never collide on one ledger
FOREIGN_UID_BASE = 1 << 40


class FederatedRouter:
    """A cluster :class:`Router` peered with remote clusters over the wire.

    Peers speak the transport framing (``K_FWD`` / ``K_FWD_RESULT`` /
    ``K_FWD_REJECT`` / ``K_LOAD`` / ``K_QUOTA`` / ``K_DRAIN`` / ``K_BYE``)
    over any :class:`~repro.serve.transport.Channel`.  Each step the
    local router places what it can; if the cluster queue is still
    backed up and a peer advertises free headroom (LOAD frames), the
    queue head is forwarded (FWD) — the peer admits it as a *foreign*
    session under a collision-free local uid, serves it to completion,
    and returns the token stream (FWD_RESULT), which is applied to the
    origin :class:`Session` object exactly like a wire RESULT.  A
    draining peer rejects inbound forwards (FWD_REJECT → the origin
    requeues locally; zero dropped sessions) and broadcasts DRAIN so
    origins stop selecting it.

    Quota stays consistent across clusters without a central ledger:
    every step each cluster broadcasts its local
    :meth:`~repro.serve.quota.QuotaManager.usage` snapshot (QUOTA), and
    each receiver installs it as a remote overlay
    (:meth:`~repro.serve.quota.QuotaManager.set_remote_usage`) that
    ``can_admit`` counts — one tenant's page budget binds over the sum
    of local + remote holdings, eventually consistent at the broadcast
    cadence."""

    def __init__(self, router: Router, *, name: str = "cluster"):
        self.router = router
        self.name = name
        self.peers: Dict[str, _Peer] = {}
        self.draining = False
        # foreign sessions this cluster serves for its peers
        self._foreign: Dict[int, tuple] = {}    # local uid -> (peer, fid)
        self._foreign_done: set = set()         # result already returned
        self._next_foreign = FOREIGN_UID_BASE
        self.forwarded = 0
        self.adopted = 0
        self.rejected = 0

    # ------------------------------------------------------------------
    def add_peer(self, name: str, channel) -> None:
        if name in self.peers:
            raise ValueError(f"peer {name!r} already registered")
        self.peers[name] = _Peer(name, channel)

    @property
    def quota(self):
        return self.router.engines[0].pair.prefill.quota

    def submit(self, req: Request, on_token=None) -> Session:
        return self.router.submit(req, on_token=on_token)

    def cancel(self, uid: int) -> None:
        self.router.cancel(uid)

    # ------------------------------------------------------------------
    def _send(self, peer: _Peer, kind: int, msg: Dict[str, Any]) -> None:
        from repro.serve import transport as tfm
        try:
            tfm._send_msg(peer.channel, kind, msg)
        except tfm.TransportError as e:
            log.warning("peer %s unreachable, detaching: %s", peer.name, e)
            self._lose_peer(peer)

    def _lose_peer(self, peer: _Peer) -> None:
        """A dead peer's forwarded sessions requeue locally — the
        federation analogue of :meth:`Router.fail`."""
        peer.closed = True
        if self.quota is not None:
            self.quota.set_remote_usage(peer.name, None)
        for fid, sess in list(peer.outstanding.items()):
            if not sess.done:
                sess.rewind()
                self.router.queue.append(sess)
                self.router.requeues += 1
        peer.outstanding.clear()

    # ------------------------------------------------------------------
    def _pump_peer(self, peer: _Peer) -> None:
        from repro.serve import transport as tfm
        while True:
            try:
                got = tfm._poll_msg(peer.channel, retries=2, backoff=0.0,
                                    sleep=lambda s: None)
            except tfm.TransportError as e:
                log.warning("peer %s channel failed: %s", peer.name, e)
                self._lose_peer(peer)
                return
            if got is None:
                return
            kind, msg = got
            if kind == tfm.K_LOAD:
                peer.free = int(msg["free"])
            elif kind == tfm.K_QUOTA:
                if self.quota is not None:
                    self.quota.set_remote_usage(peer.name, msg["usage"])
            elif kind == tfm.K_FWD:
                self._adopt_forward(peer, msg)
            elif kind == tfm.K_FWD_RESULT:
                self._apply_forward_result(peer, msg)
            elif kind == tfm.K_FWD_REJECT:
                sess = peer.outstanding.pop(msg["fid"], None)
                if sess is not None and not sess.done:
                    sess.rewind()
                    self.router.queue.append(sess)
                    self.router.requeues += 1
            elif kind == tfm.K_DRAIN:
                peer.draining = True
                peer.free = 0
            elif kind == tfm.K_BYE:
                self._lose_peer(peer)
            else:
                raise tfm.WireFormatError(
                    f"unexpected federation frame kind {kind}")

    def _adopt_forward(self, peer: _Peer, msg: Dict[str, Any]) -> None:
        if self.draining:
            self.rejected += 1
            self._send(peer, _k().K_FWD_REJECT, {"fid": msg["fid"]})
            return
        uid = self._next_foreign
        self._next_foreign += 1
        req = Request(uid=uid, prompt=msg["prompt"],
                      max_new_tokens=msg["max_new_tokens"],
                      eos_id=msg["eos_id"], priority=msg["priority"],
                      tenant=msg["tenant"], deadline=msg["deadline"])
        self.router.submit(req)
        self._foreign[uid] = (peer.name, msg["fid"])
        self.adopted += 1

    def _apply_forward_result(self, peer: _Peer, msg: Dict[str, Any]) -> None:
        sess = peer.outstanding.pop(msg["fid"], None)
        if self.quota is not None:
            self.quota.release_uid(msg["fid"])
        if sess is None:
            return
        if not sess.done:
            # same list object: keep the Request.out_tokens alias intact
            del sess.tokens[:]
            sess.tokens.extend(msg["tokens"])
            sess.length = int(msg["length"])
            sess.finish(msg["finish_reason"])
        self.router.finished_at.setdefault(sess.uid, self.router.now)

    def _flush_foreign_results(self) -> None:
        for uid, (peer_name, fid) in list(self._foreign.items()):
            sess = self.router.sessions.get(uid)
            peer = self.peers.get(peer_name)
            if sess is None or not sess.done or uid in self._foreign_done:
                continue
            self._foreign_done.add(uid)
            if peer is not None and not peer.closed:
                self._send(peer, _k().K_FWD_RESULT, {
                    "fid": fid,
                    "tokens": list(sess.tokens),
                    "length": int(sess.length),
                    "finish_reason": sess.finish_reason,
                })

    # ------------------------------------------------------------------
    def _forward_backlog(self) -> int:
        """Forward queue-head sessions no local engine has headroom for."""
        if self.draining:
            return 0
        sent = 0
        while self.router.queue:
            if any(v.headroom > 0 for v in self.router._views()):
                break                    # local placement will take it
            targets = [p for p in self.peers.values()
                       if p.sendable() and p.free > 0]
            if not targets:
                break
            sess = self.router.queue.popleft()
            if sess.done:
                continue
            peer = max(targets, key=lambda p: p.free)
            peer.free -= 1               # optimistic; refreshed by LOAD
            self._send(peer, _k().K_FWD, {
                "fid": sess.uid,
                "prompt": sess.request.prompt,
                "max_new_tokens": int(sess.request.max_new_tokens),
                "eos_id": int(sess.request.eos_id),
                "priority": int(getattr(sess.request, "priority", 0)),
                "tenant": sess.tenant,
                "deadline": getattr(sess.request, "deadline", None),
            })
            if peer.closed:              # send failed, session requeued
                continue
            peer.outstanding[sess.uid] = sess
            self.forwarded += 1
            sent += 1
        return sent

    def _broadcast_state(self) -> None:
        free = sum(max(0, v.headroom) for v in self.router._views())
        if self.draining:
            free = 0
        usage = self.quota.usage() if self.quota is not None else None
        for peer in list(self.peers.values()):
            if peer.closed:
                continue
            self._send(peer, _k().K_LOAD, {"free": free})
            if usage is not None and not peer.closed:
                self._send(peer, _k().K_QUOTA, {"usage": usage})

    # ------------------------------------------------------------------
    def step(self) -> int:
        for peer in list(self.peers.values()):
            if not peer.closed:
                self._pump_peer(peer)
        busy = self.router.step()
        busy += self._forward_backlog()
        self._flush_foreign_results()
        self._broadcast_state()
        return busy

    def drain(self) -> None:
        """Drain this whole cluster: stop forwarding out, reject inbound
        forwards, broadcast DRAIN; local + already-adopted work retires
        in place and forwarded-out sessions ride to completion on their
        peers."""
        self.draining = True
        for peer in list(self.peers.values()):
            if not peer.closed:
                self._send(peer, _k().K_DRAIN, {})

    def close(self) -> None:
        for peer in list(self.peers.values()):
            if not peer.closed:
                self._send(peer, _k().K_BYE, {})
                peer.closed = True

    # ------------------------------------------------------------------
    def has_work(self) -> bool:
        return (self.router.has_work()
                or any(p.outstanding for p in self.peers.values())
                or any(uid not in self._foreign_done
                       for uid in self._foreign))

    def run(self, max_steps: int = 100_000,
            on_step: Optional[Callable[["FederatedRouter"], None]] = None
            ) -> List[Request]:
        for _ in range(max_steps):
            if not self.has_work():
                break
            self.step()
            if on_step is not None:
                on_step(self)
        return [s.request for s in self.router.sessions.values() if s.done]

    def describe(self) -> str:
        peers = " ".join(
            f"{p.name}:{'x' if p.closed else ('drain' if p.draining else p.free)}"
            for p in self.peers.values())
        return (f"fed[{self.name} fwd={self.forwarded} "
                f"adopted={self.adopted} | {peers or 'no peers'}]")


def _k():
    """Frame-kind namespace (import deferred: transport imports session,
    router imports transport lazily to stay cycle-free)."""
    from repro.serve import transport
    return transport


def federate(routers: List[Router], *, names: Optional[List[str]] = None,
             max_chunk: Optional[int] = None) -> List[FederatedRouter]:
    """Peer N local routers into a full federation mesh over in-memory
    channels (the same-process harness; cross-host uses TCP channels via
    :meth:`FederatedRouter.add_peer`)."""
    from repro.serve.transport import memory_pair

    names = names or [f"cluster{i}" for i in range(len(routers))]
    feds = [FederatedRouter(r, name=n) for r, n in zip(routers, names)]
    for i in range(len(feds)):
        for j in range(i + 1, len(feds)):
            a, b = memory_pair(max_chunk)
            feds[i].add_peer(names[j], a)
            feds[j].add_peer(names[i], b)
    return feds


# ---------------------------------------------------------------------------
def build_router(model, params, *, engines: int = 2,
                 placement="least_loaded", window: Optional[int] = None,
                 quota=None, seed: int = 0,
                 placement_kwargs: Optional[Dict[str, Any]] = None,
                 **pair_kwargs) -> Router:
    """A router over N loopback pairs sharing ONE quota ledger.

    Each pair gets a disjoint seed block (``seed + 2*i``: prefill, +1
    decode — the ``build_disagg`` discipline), so engines sample
    independently while staying reproducible.  ``pair_kwargs`` forward to
    :func:`~repro.serve.disagg.build_disagg` (batch, page_size, pages,
    transfer, spill, scheduler, ...)."""
    from repro.serve.disagg import build_disagg
    from repro.serve.quota import QuotaManager, TenantQuota

    if quota is None or isinstance(quota, QuotaManager):
        shared = quota
    elif isinstance(quota, TenantQuota):
        shared = QuotaManager(default_quota=quota)
    else:
        shared = QuotaManager(dict(quota))

    pairs = [build_disagg(model, params, quota=shared, seed=seed + 2 * i,
                          **pair_kwargs)
             for i in range(engines)]
    return Router(pairs, placement=placement, window=window,
                  **(placement_kwargs or {}))


def replay_trace(router: Router, trace, vocab: int, *,
                 arrivals_per_step: float = 1.0,
                 max_steps: int = 200_000,
                 on_step: Optional[Callable[[Router], None]] = None
                 ) -> List[Request]:
    """Replay a :func:`repro.sim.workloads.generate_traffic` trace
    against a real router, scaled down: arrival times are quantized onto
    the router's step clock at ``arrivals_per_step`` sessions per step.

    Prompts are derived deterministically from each synthetic session's
    ``prefix_id``/``uid`` (shared prefixes really share tokens, so
    ``prefix_affinity`` has something to exploit); deadlines become
    absolute router steps from the session's SLO slack."""
    import numpy as np

    sessions = sorted(trace, key=lambda s: (s.arrival, s.uid))
    pending = deque()
    for i, s in enumerate(sessions):
        arrive_step = int(i / max(arrivals_per_step, 1e-9))
        prompt = synth_prompt(s, vocab)
        deadline = None
        if s.slo != "batch":
            # slack scales with the decode budget; floor keeps tiny
            # requests from being born dead on the step clock
            deadline = arrive_step + max(8, int(s.slack_steps))
        pending.append((arrive_step, Request(
            uid=s.uid, prompt=prompt, max_new_tokens=s.decode_len,
            tenant=s.tenant, deadline=deadline,
            priority=1 if s.slo == "interactive" else 0)))

    def feed(r: Router) -> None:
        while pending and pending[0][0] <= r.now:
            r.submit(pending.popleft()[1])
        if on_step is not None:
            on_step(r)

    feed(router)
    for _ in range(max_steps):
        if not pending and not router.has_work():
            break
        router.step()
        feed(router)
    return [s.request for s in router.sessions.values() if s.done]


def synth_prompt(s, vocab: int):
    """Deterministic tokens for a synthetic session: the shared prefix is
    a pure function of ``prefix_id``, the tail of ``uid`` — two sessions
    with the same prefix_id share their first ``prefix_len`` tokens
    exactly."""
    import numpy as np

    lo, hi = 1, max(2, vocab - 1)
    parts = []
    if s.prefix_id is not None and s.prefix_len > 0:
        rng = np.random.default_rng(10_000 + s.prefix_id)
        parts.append(rng.integers(lo, hi, size=min(s.prefix_len,
                                                   s.prompt_len)))
    tail = s.prompt_len - (len(parts[0]) if parts else 0)
    if tail > 0:
        rng = np.random.default_rng(20_000 + s.uid)
        parts.append(rng.integers(lo, hi, size=tail))
    return np.concatenate(parts).astype(np.int32) if parts else \
        np.array([lo], np.int32)
