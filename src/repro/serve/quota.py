"""Multi-tenant admission quotas for the serving engine.

The pooled KV cache is a shared resource; without admission control one
tenant's long-context burst evicts everyone else's pages.  A
:class:`TenantQuota` caps what one tenant may hold — a **page budget**
(the unit of pool placement, enforced by reservation at admission so a
mid-decode page allocation can never deadlock on quota) and a **max
concurrent sessions** count — and optionally picks the tenant's spill
codec from the ``core/compress.py`` registry (a latency-insensitive batch
tenant can take int8 pages at half the spill bytes; an interactive tenant
keeps raw pages).

:class:`QuotaManager` is the engine-side ledger: ``charge``/``release_uid``
record and return one session's reservation, ``can_admit``/``admissible``
answer the scheduler-time questions, ``usage`` feeds the traffic report.
Page budgets only bind in paged mode (the unpaged slot cache has no page
notion); session caps bind in both.

The per-session ledger lives *here* (not in the Engine) so a reservation
can follow a session across cooperating runtimes: under disaggregated
serving (serve/disagg.py) the prefill and decode engines share one
QuotaManager — the charge taken at prefill admission stays on the ledger
while the session's KV pages are in flight through the transfer tier and
is released by whichever side retires (or sweeps a cancellation of) the
session.  ``release_uid`` is idempotent for exactly that reason: a
cancelled-in-transit session may be swept by both sides.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

DEFAULT_TENANT = "default"


@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """Admission contract of one tenant (None fields: unlimited)."""

    max_pages: Optional[int] = None      # page budget (paged mode)
    max_sessions: Optional[int] = None   # concurrent in-flight sessions
    codec: Optional[str] = None          # spill codec for this tenant's pages

    def validate(self) -> "TenantQuota":
        if self.max_pages is not None and self.max_pages < 0:
            raise ValueError(f"max_pages must be >= 0: {self.max_pages}")
        if self.max_sessions is not None and self.max_sessions < 0:
            raise ValueError(f"max_sessions must be >= 0: {self.max_sessions}")
        if self.codec is not None:
            from repro.core.compress import get_codec
            get_codec(self.codec)        # raises KeyError on unknown codec
        return self

    def describe(self) -> str:
        bits = []
        if self.max_pages is not None:
            bits.append(f"pages={self.max_pages}")
        if self.max_sessions is not None:
            bits.append(f"sessions={self.max_sessions}")
        if self.codec is not None:
            bits.append(f"codec={self.codec}")
        return ",".join(bits) or "unlimited"


class QuotaManager:
    """Per-tenant reservation ledger enforced by the Engine at admission.

    ``quotas`` maps tenant name → :class:`TenantQuota`; tenants without an
    entry fall back to ``default_quota`` (unlimited unless given).  Pages
    are charged as a *reservation* — the worst case the session can grow
    to — when it is first admitted, and returned when it retires; paused
    sessions keep their charge (their pages still occupy pool or spill
    capacity).
    """

    def __init__(self, quotas: Optional[Dict[str, TenantQuota]] = None,
                 default_quota: Optional[TenantQuota] = None):
        self.quotas = {t: q.validate() for t, q in (quotas or {}).items()}
        self.default_quota = (default_quota or TenantQuota()).validate()
        self._pages: Dict[str, int] = {}
        self._sessions: Dict[str, int] = {}
        self._charged: Dict[int, Tuple[str, int]] = {}  # uid -> (tenant, pages)
        # federation overlay: peer name -> usage() snapshot of that peer's
        # ledger.  ``can_admit`` counts remote holdings too, so a tenant's
        # quota binds cluster-wide even though each cluster charges locally.
        self._remote: Dict[str, Dict[str, Dict[str, int]]] = {}

    # ------------------------------------------------------------------
    def quota_for(self, tenant: str) -> TenantQuota:
        return self.quotas.get(tenant, self.default_quota)

    def codec_for(self, tenant: str) -> Optional[str]:
        return self.quota_for(tenant).codec

    # ------------------------------------------------------------------
    def admissible(self, tenant: str, pages: int) -> bool:
        """Could this session EVER be admitted (empty-tenant headroom)?
        False means the engine should reject it outright instead of
        deferring forever."""
        q = self.quota_for(tenant)
        if q.max_sessions is not None and q.max_sessions < 1:
            return False
        return q.max_pages is None or pages <= q.max_pages

    def can_admit(self, tenant: str, pages: int) -> bool:
        q = self.quota_for(tenant)
        if q.max_sessions is not None and \
                self._sessions.get(tenant, 0) + \
                self._remote_held(tenant, "sessions") + 1 > q.max_sessions:
            return False
        if q.max_pages is not None and \
                self._pages.get(tenant, 0) + \
                self._remote_held(tenant, "pages") + pages > q.max_pages:
            return False
        return True

    def admit(self, tenant: str, pages: int) -> None:
        self._sessions[tenant] = self._sessions.get(tenant, 0) + 1
        self._pages[tenant] = self._pages.get(tenant, 0) + pages

    def release(self, tenant: str, pages: int) -> None:
        self._sessions[tenant] = max(0, self._sessions.get(tenant, 0) - 1)
        self._pages[tenant] = max(0, self._pages.get(tenant, 0) - pages)

    # ------------------------------------------------------------------
    # per-session ledger (reservations that survive role handoffs)
    def charge(self, uid: int, tenant: str, pages: int) -> None:
        """Record one session's worst-case reservation against its tenant."""
        assert uid not in self._charged, f"session {uid} already charged"
        self.admit(tenant, pages)
        self._charged[uid] = (tenant, pages)

    def release_uid(self, uid: int) -> bool:
        """Return a session's reservation; idempotent (False: not charged).

        Safe to call from every runtime that ever saw the session — the
        first caller wins, later sweeps are no-ops — which is what makes
        cancel-while-parked (paused, deferred, or in a transfer queue)
        leak-free without coordinating the sweepers."""
        entry = self._charged.pop(uid, None)
        if entry is None:
            return False
        self.release(*entry)
        return True

    def charge_of(self, uid: int) -> Optional[Tuple[str, int]]:
        return self._charged.get(uid)

    def charged_uids(self) -> Tuple[int, ...]:
        return tuple(self._charged)

    # ------------------------------------------------------------------
    # federation: fold peer clusters' usage snapshots into admission
    def set_remote_usage(self, peer: str,
                         usage: Optional[Dict[str, Dict[str, int]]]) -> None:
        """Install (or with None, drop) one peer cluster's usage snapshot.

        Snapshots arrive over the wire as QUOTA frames; admission then
        treats remote holdings as if they were local, which keeps one
        tenant's quota consistent across federated clusters (eventually
        consistent — bounded by the broadcast cadence)."""
        if usage is None:
            self._remote.pop(peer, None)
        else:
            self._remote[peer] = {t: dict(u) for t, u in usage.items()}

    def _remote_held(self, tenant: str, key: str) -> int:
        return sum(snap.get(tenant, {}).get(key, 0)
                   for snap in self._remote.values())

    def remote_peers(self) -> Tuple[str, ...]:
        return tuple(self._remote)

    # ------------------------------------------------------------------
    def usage(self) -> Dict[str, Dict[str, int]]:
        tenants = set(self._sessions) | set(self._pages) | set(self.quotas)
        return {t: {"sessions": self._sessions.get(t, 0),
                    "pages": self._pages.get(t, 0)}
                for t in sorted(tenants)}

    def describe(self) -> str:
        per = [f"{t}:{q.describe()}" for t, q in sorted(self.quotas.items())]
        per.append(f"*:{self.default_quota.describe()}")
        return f"quota[{' '.join(per)}]"


# ---------------------------------------------------------------------------
def parse_quota_spec(spec: str) -> Tuple[Dict[str, TenantQuota], TenantQuota]:
    """Parse the ``--tenant-quota`` CLI string.

    Grammar: ``[tenant:]k=v[,k=v...][;[tenant:]...]`` with keys
    ``pages`` / ``sessions`` / ``codec``.  A clause without a tenant name
    sets the default quota for every tenant.  Examples::

        pages=16,sessions=2
        interactive:sessions=4;batch:pages=8,codec=int8

    Returns ``(per_tenant, default_quota)`` for :class:`QuotaManager`.
    """
    per: Dict[str, TenantQuota] = {}
    default = TenantQuota()
    for clause in filter(None, (c.strip() for c in spec.split(";"))):
        tenant = None
        if ":" in clause:
            tenant, clause = clause.split(":", 1)
            tenant = tenant.strip()
        kw: Dict[str, object] = {}
        for item in filter(None, (i.strip() for i in clause.split(","))):
            if "=" not in item:
                raise ValueError(f"bad quota item {item!r} (want k=v)")
            k, v = (s.strip() for s in item.split("=", 1))
            if k == "pages":
                kw["max_pages"] = int(v)
            elif k == "sessions":
                kw["max_sessions"] = int(v)
            elif k == "codec":
                kw["codec"] = v
            else:
                raise ValueError(f"unknown quota key {k!r} "
                                 "(want pages/sessions/codec)")
        quota = TenantQuota(**kw).validate()
        if tenant:
            per[tenant] = quota
        else:
            default = quota
    return per, default


def quota_from_cli(spec: Optional[str],
                   page_codec: Optional[str] = None
                   ) -> Optional[QuotaManager]:
    """Build the Engine's QuotaManager from the ``--tenant-quota`` /
    ``--page-codec`` CLI pair.

    ``page_codec`` is the fleet-wide spill-codec default: it fills every
    quota — named tenants included — that does not pick its own ``codec``.
    Returns None when neither flag is given (no quota enforcement).
    """
    if not spec and not page_codec:
        return None
    per, default = parse_quota_spec(spec) if spec else ({}, TenantQuota())
    if page_codec:
        def fill(q: TenantQuota) -> TenantQuota:
            return q if q.codec else dataclasses.replace(q, codec=page_codec)
        per = {t: fill(q) for t, q in per.items()}
        default = fill(default)
    return QuotaManager(per, default)
