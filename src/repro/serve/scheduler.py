"""Scheduler: admission, continuous batching and preemption policies.

One of the three serving APIs behind the ``Engine`` facade (DESIGN.md §6).
The scheduler owns every *waiting* session — freshly submitted and paused
(preempted) alike — and answers three questions each engine step:

  ``next_ready()``        which session takes the next free decode slot
  ``preempt_victim()``    which running session to pause when waiting work
                          outranks it (its KV spills to the secondary tier)
  ``has_waiting()``       is there admission pressure at all

Policies are registry-pluggable (:func:`register_scheduler` /
:func:`build_scheduler`), mirroring the tier/codec registries in
``core.tiers``:

* :class:`FCFSScheduler`     — run-to-completion first-come-first-served
  (the legacy engine behaviour; ``deque`` admission, no preemption).
* :class:`PriorityScheduler` — highest ``Request.priority`` first; a
  strictly higher-priority arrival preempts the lowest-priority running
  session (strict inequality prevents equal-priority thrash).
* :class:`FairScheduler`     — round-robin with a decode-token quantum:
  once a session has decoded ``quantum`` tokens while others wait, it is
  paused and requeued behind them.  This is the policy that keeps a
  many-requests/few-slots workload live for everyone (cold sessions wait
  in the spill tier, not in HBM).
* :class:`SRPTScheduler`     — shortest-remaining-processing-time first
  (``Session.remaining`` from ``max_new_tokens``), the mean-latency-
  optimal policy; strictly shorter waiting work preempts the longest
  running session.
* :class:`DeadlineScheduler` — earliest-deadline-first over
  ``Request.deadline`` (absolute engine steps via the :meth:`on_step`
  clock) with met/missed accounting at retirement.

Disaggregated serving (serve/disagg.py) splits admission across TWO
queues, each behind its own engine's scheduler: the *prefill queue* is
the prefill-role engine's scheduler ordering fresh prompts toward the
prefill slots (gated by transfer-tier backpressure), while the *decode
queue* is the decode-role engine's scheduler ordering paused-session
resumes — fresh work reaches the decode side only through the
``TransferQueue`` (arrival-ordered, requeue-to-back under backpressure,
so adoptions never starve resumes nor each other).  A session leaving
the prefill role is announced via :meth:`on_handoff`, NOT
:meth:`on_retire`: it has not finished, and deadline accounting must
happen exactly once, on the side that retires it.
"""
from __future__ import annotations

import abc
import heapq
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

from repro.serve.session import Session


class Scheduler(abc.ABC):
    """Admission + preemption policy over waiting sessions."""

    name: str = "abstract"

    @abc.abstractmethod
    def submit(self, sess: Session) -> None:
        """Enqueue a freshly submitted session."""

    @abc.abstractmethod
    def next_ready(self) -> Optional[Session]:
        """Pop the session that should take the next free slot (or None)."""

    @abc.abstractmethod
    def requeue(self, sess: Session) -> None:
        """Put a just-paused session back in the waiting set."""

    @abc.abstractmethod
    def has_waiting(self) -> bool:
        """True when any session waits for a slot."""

    @abc.abstractmethod
    def waiting(self) -> Tuple[Session, ...]:
        """Snapshot of the waiting set (admission order, for reporting)."""

    def preempt_victim(self, running: List[Session]) -> Optional[Session]:
        """Running session to pause in favour of waiting work (None: keep
        all running sessions resident — run-to-completion)."""
        return None

    def on_retire(self, sess: Session) -> None:
        """Hook: a session finished and left its slot."""

    def on_handoff(self, sess: Session) -> None:
        """Hook: a prefill-role engine shipped this session to the decode
        side.  Not a retirement — the session is still live, and any
        SLO/latency accounting belongs to the engine that retires it."""

    def on_step(self) -> None:
        """Hook: the engine completed one decode step (scheduler clock)."""

    def describe(self) -> str:
        return self.name


# ---------------------------------------------------------------------------
class FCFSScheduler(Scheduler):
    """First-come-first-served, run-to-completion.

    The legacy engine policy, minus its O(n²) ``list.pop(0)`` admission
    queue — a deque pops the head in O(1), which matters at the
    heavy-traffic queue depths the north star targets.
    """

    name = "fcfs"

    def __init__(self):
        self._queue: deque = deque()

    def submit(self, sess: Session) -> None:
        self._queue.append(sess)

    def next_ready(self) -> Optional[Session]:
        while self._queue:
            sess = self._queue.popleft()
            if not sess.done:           # cancelled-while-queued sessions drop
                return sess
        return None

    def requeue(self, sess: Session) -> None:
        # paused sessions resume ahead of fresh arrivals (they hold spilled
        # state the fetch path should drain first)
        self._queue.appendleft(sess)

    def has_waiting(self) -> bool:
        return any(not s.done for s in self._queue)

    def waiting(self) -> Tuple[Session, ...]:
        return tuple(s for s in self._queue if not s.done)


# ---------------------------------------------------------------------------
class PriorityScheduler(Scheduler):
    """Highest ``Request.priority`` first; FCFS within a priority level.

    A waiting session with *strictly* higher priority preempts the
    lowest-priority running session — its KV moves to the spill tier and
    the slot turns over immediately.
    """

    name = "priority"

    def __init__(self):
        self._heap: List[Tuple[int, int, Session]] = []

    def submit(self, sess: Session) -> None:
        heapq.heappush(self._heap, (-sess.priority, sess.seq, sess))

    def next_ready(self) -> Optional[Session]:
        while self._heap:
            _, _, sess = heapq.heappop(self._heap)
            if not sess.done:
                return sess
        return None

    def requeue(self, sess: Session) -> None:
        heapq.heappush(self._heap, (-sess.priority, sess.seq, sess))

    def has_waiting(self) -> bool:
        return any(not s.done for _, _, s in self._heap)

    def waiting(self) -> Tuple[Session, ...]:
        return tuple(s for _, _, s in sorted(self._heap, key=lambda t: t[:2])
                     if not s.done)

    def preempt_victim(self, running: List[Session]) -> Optional[Session]:
        best_waiting = max((s.priority for _, _, s in self._heap
                            if not s.done), default=None)
        if best_waiting is None or not running:
            return None
        victim = min(running, key=lambda s: (s.priority, -s.seq))
        return victim if victim.priority < best_waiting else None


# ---------------------------------------------------------------------------
class FairScheduler(FCFSScheduler):
    """Round-robin over sessions with a decode-token quantum.

    When sessions wait and a running session has decoded ``quantum``
    tokens since admission/resume, it is paused (KV spilled) and requeued
    *behind* the waiters — every session makes progress even when the
    request count far exceeds the slot count.
    """

    name = "fair"

    def __init__(self, quantum: int = 8):
        super().__init__()
        assert quantum >= 1, quantum
        self.quantum = quantum

    def requeue(self, sess: Session) -> None:
        # round-robin: an expired quantum goes to the back of the line
        self._queue.append(sess)

    def preempt_victim(self, running: List[Session]) -> Optional[Session]:
        expired = [s for s in running if s.steps_since_admit >= self.quantum]
        if not expired:
            return None
        # the longest-over-quantum session yields first
        return max(expired, key=lambda s: (s.steps_since_admit, -s.seq))

    def describe(self) -> str:
        return f"{self.name}[q={self.quantum}]"


# ---------------------------------------------------------------------------
class SRPTScheduler(Scheduler):
    """Shortest-remaining-processing-time first.

    The remaining time of a session is the decode tokens it is still owed
    (``Session.remaining``, from ``Request.max_new_tokens``) — the classic
    mean-latency-optimal policy when service times are known, which they
    are here up to early EOS.  A waiting session with *strictly* less
    remaining work preempts the longest-remaining running session; ties
    break FCFS by admission ticket so equal-length jobs never thrash.

    Remaining work only changes while a session runs, so heap keys frozen
    at push time stay correct for every *waiting* session.
    """

    name = "srpt"

    def __init__(self):
        self._heap: List[Tuple[int, int, Session]] = []

    def submit(self, sess: Session) -> None:
        heapq.heappush(self._heap, (sess.remaining, sess.seq, sess))

    def next_ready(self) -> Optional[Session]:
        while self._heap:
            _, _, sess = heapq.heappop(self._heap)
            if not sess.done:
                return sess
        return None

    def requeue(self, sess: Session) -> None:
        heapq.heappush(self._heap, (sess.remaining, sess.seq, sess))

    def has_waiting(self) -> bool:
        return any(not s.done for _, _, s in self._heap)

    def waiting(self) -> Tuple[Session, ...]:
        return tuple(s for _, _, s in sorted(self._heap, key=lambda t: t[:2])
                     if not s.done)

    def preempt_victim(self, running: List[Session]) -> Optional[Session]:
        shortest = min((s.remaining for _, _, s in self._heap if not s.done),
                       default=None)
        if shortest is None or not running:
            return None
        victim = max(running, key=lambda s: (s.remaining, -s.seq))
        return victim if victim.remaining > shortest else None


# ---------------------------------------------------------------------------
class DeadlineScheduler(Scheduler):
    """Earliest-deadline-first with deadline-miss accounting.

    ``Request.deadline`` is an absolute engine-step number (the scheduler's
    clock advances by one per :meth:`on_step`); deadline-less requests rank
    last (+inf) and can never miss.  EDF never idles while an unmet
    deadline waits: ``next_ready`` always yields the earliest-deadline
    waiting session.  A strictly earlier waiting deadline preempts the
    latest-deadline running session.  Misses are counted at retirement
    (``now > deadline``) and per-tenant in :attr:`misses_by_tenant`.
    """

    name = "deadline"

    def __init__(self):
        self._heap: List[Tuple[float, int, Session]] = []
        self.now = 0
        self.misses = 0
        self.met = 0
        self.max_lateness = 0          # worst (now - deadline) over misses
        self.misses_by_tenant: Dict[str, int] = {}
        self.met_by_tenant: Dict[str, int] = {}

    def submit(self, sess: Session) -> None:
        heapq.heappush(self._heap, (sess.deadline, sess.seq, sess))

    def next_ready(self) -> Optional[Session]:
        while self._heap:
            _, _, sess = heapq.heappop(self._heap)
            if not sess.done:
                return sess
        return None

    def requeue(self, sess: Session) -> None:
        heapq.heappush(self._heap, (sess.deadline, sess.seq, sess))

    def has_waiting(self) -> bool:
        return any(not s.done for _, _, s in self._heap)

    def waiting(self) -> Tuple[Session, ...]:
        return tuple(s for _, _, s in sorted(self._heap, key=lambda t: t[:2])
                     if not s.done)

    def preempt_victim(self, running: List[Session]) -> Optional[Session]:
        earliest = min((s.deadline for _, _, s in self._heap if not s.done),
                       default=None)
        if earliest is None or not running:
            return None
        victim = max(running, key=lambda s: (s.deadline, -s.seq))
        return victim if victim.deadline > earliest else None

    def on_step(self) -> None:
        self.now += 1

    #: terminal reasons outside the SLO: the request was never served
    #: (rejected / over-quota) or the client walked away — counting them
    #: as met/missed would skew the deadline accounting either way
    _UNSERVED = ("rejected", "quota", "cancelled")

    def on_retire(self, sess: Session) -> None:
        if sess.deadline == float("inf") or \
                sess.finish_reason in self._UNSERVED:
            return
        if self.now > sess.deadline:
            self.misses += 1
            self.max_lateness = max(self.max_lateness,
                                    int(self.now - sess.deadline))
            self.misses_by_tenant[sess.tenant] = \
                self.misses_by_tenant.get(sess.tenant, 0) + 1
        else:
            self.met += 1
            self.met_by_tenant[sess.tenant] = \
                self.met_by_tenant.get(sess.tenant, 0) + 1

    def miss_report(self) -> Dict[str, object]:
        """Per-tenant SLO ledger: both sides of the met/missed split."""
        tenants = set(self.misses_by_tenant) | set(self.met_by_tenant)
        return {"now": self.now, "met": self.met, "missed": self.misses,
                "max_lateness": self.max_lateness,
                "by_tenant": {t: {"met": self.met_by_tenant.get(t, 0),
                                  "missed": self.misses_by_tenant.get(t, 0)}
                              for t in sorted(tenants)}}

    def describe(self) -> str:
        return f"{self.name}[met={self.met} missed={self.misses}]"


# ---------------------------------------------------------------------------
# registry (mirrors core.tiers' policy/codec registries)
_SCHEDULERS: Dict[str, Callable[..., Scheduler]] = {}


def register_scheduler(name: str, factory: Callable[..., Scheduler]) -> None:
    _SCHEDULERS[name] = factory


def registered_schedulers() -> Tuple[str, ...]:
    return tuple(sorted(_SCHEDULERS))


def build_scheduler(name: str, **kwargs) -> Scheduler:
    if name not in _SCHEDULERS:
        raise KeyError(f"unknown scheduler {name!r}; "
                       f"registered: {registered_schedulers()}")
    return _SCHEDULERS[name](**kwargs)


register_scheduler("fcfs", FCFSScheduler)
register_scheduler("priority", PriorityScheduler)
register_scheduler("fair", FairScheduler)
register_scheduler("srpt", SRPTScheduler)
register_scheduler("deadline", DeadlineScheduler)
