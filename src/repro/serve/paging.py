"""PageTable: free-list page allocation for the paged KV cache.

The paper's pooled-memory thesis says capacity management must be
transparent to the algorithm while the runtime decides placement; the page
is the unit of that placement for serving.  This module is the pure-Python
bookkeeping half (no jax): which session owns which fixed-size page, which
pages are *cold* (owner paused) and therefore evictable, and which logical
positions of a session currently live in the spill tier.  The array
surgery — extracting/inserting page contents, codecs, the spill-tier
stash/fetch — stays in :class:`~repro.serve.cache_manager.PagedKVCacheManager`,
which drives this table and hands it an eviction callback.

Lifecycle of one page position of one session:

          alloc                    mark_cold        (demand) evict_cb
  FREE ─────────► RESIDENT+hot ───────────► RESIDENT+cold ───────────► SPILLED
                      ▲                          │ mark_hot                │
                      └──────────────────────────┘ (copy-free readmit)     │
                      ▲                                 set_resident       │
                      └────────────────────────────────────────────────────┘

Pausing a session costs nothing: its pages merely become eviction
candidates (LRU by pause order).  They are spilled *lazily*, one page at a
time, only when an allocation finds the free list empty — and a session
resumed before that happens re-binds with **zero copies** (the
Buddy-Compression cold-page pattern, arXiv:1903.02596).  Every invariant
the property suite drives is checked by :meth:`check`.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Tuple


class PageError(RuntimeError):
    """Allocation failure: every page is hot (resident running sessions)."""


def pages_for(rows: int, page_size: int) -> int:
    """Pages needed to hold ``rows`` cache rows (always >= 1).

    Module-level so every party to the page contract rounds identically:
    the decode table's charge/claim (:meth:`PageTable.pages_for`), the
    prefill role's quota reservation and handoff chunk count
    (serve/engine.py) — a divergence would break the shared-ledger
    reservation that follows a session across the disaggregated split."""
    return max(1, -(-rows // page_size))


#: evict_cb(owner_sid, position, page_id) -> payload
#: Called while the page is still resident; must copy the page's contents
#: out (spill-tier stash) and return an opaque payload the table stores in
#: the owner's entry.  Raising aborts the allocation.
EvictFn = Callable[[int, int, int], Any]


@dataclasses.dataclass
class PageEntry:
    """One logical page position of one session."""

    pid: Optional[int] = None          # resident page id (None: spilled)
    payload: Any = None                # spill payload when not resident
    refetched: bool = False            # copied back through the spill tier
    #                                    during the current pause/resume
    #                                    cycle (NOT a copy-free readmit)

    @property
    def resident(self) -> bool:
        return self.pid is not None


class PageTable:
    """Session → ordered pages over a fixed pool, with lazy cold eviction."""

    def __init__(self, num_pages: int, page_size: int):
        assert num_pages >= 1 and page_size >= 1, (num_pages, page_size)
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO free list: a just-freed (warm) page is reused first
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._owner: Dict[int, Tuple[int, int]] = {}   # pid -> (sid, pos)
        self._entries: Dict[int, List[PageEntry]] = {}
        self._cold: "OrderedDict[int, None]" = OrderedDict()  # pid, LRU order
        # counters (the metering the property suite cross-checks)
        self.evictions = 0
        self.refetches = 0
        self.readmits_free = 0         # pages re-bound without a copy
        self.adoptions = 0             # sessions claimed from another role

    # ------------------------------------------------------------------
    # queries
    def pages_for(self, rows: int) -> int:
        """Pages needed to hold ``rows`` cache rows."""
        return pages_for(rows, self.page_size)

    def sessions(self) -> Tuple[int, ...]:
        return tuple(sorted(self._entries))

    def entries(self, sid: int) -> List[PageEntry]:
        return self._entries.get(sid, [])

    def resident_pids(self, sid: int) -> List[Optional[int]]:
        """Page ids in logical order (None where the position is spilled)."""
        return [e.pid for e in self.entries(sid)]

    def spilled_positions(self, sid: int) -> List[int]:
        return [i for i, e in enumerate(self.entries(sid)) if not e.resident]

    def num_free(self) -> int:
        return len(self._free)

    def num_cold(self) -> int:
        return len(self._cold)

    def holds(self, sid: int) -> int:
        """Total pages charged to a session (resident + spilled)."""
        return len(self.entries(sid))

    # ------------------------------------------------------------------
    # allocation
    def _take_page(self, evict: Optional[EvictFn]) -> int:
        if self._free:
            return self._free.pop()
        if not self._cold:
            raise PageError(f"page pool exhausted: all {self.num_pages} "
                            f"pages are hot")
        if evict is None:
            raise PageError("free list empty and no eviction callback "
                            "(cache manager built with spill=None?)")
        vpid = next(iter(self._cold))                  # LRU victim (peek)
        v_sid, v_pos = self._owner[vpid]
        payload = evict(v_sid, v_pos, vpid)   # may raise: table untouched
        self._cold.pop(vpid)
        self._owner.pop(vpid)
        entry = self._entries[v_sid][v_pos]
        entry.pid, entry.payload = None, payload
        self.evictions += 1
        return vpid

    def alloc(self, sid: int, evict: Optional[EvictFn] = None) -> int:
        """Append one fresh page to ``sid``'s logical sequence."""
        pid = self._take_page(evict)
        self._owner[pid] = (sid, len(self._entries.setdefault(sid, [])))
        self._entries[sid].append(PageEntry(pid=pid))
        return pid

    def ensure(self, sid: int, rows: int,
               evict: Optional[EvictFn] = None) -> List[int]:
        """Grow ``sid`` to cover ``rows`` cache rows; returns new page ids."""
        new = []
        while self.holds(sid) < self.pages_for(rows):
            new.append(self.alloc(sid, evict))
        return new

    def claim(self, sid: int, n_pages: int,
              evict: Optional[EvictFn] = None) -> List[int]:
        """Allocate exactly ``n_pages`` fresh pages for an *adopted* session
        (disaggregated serving: the decode role takes ownership of KV pages
        prefilled by another runtime).

        Cross-role ownership handoff must never alias: ``sid`` has to be
        unknown to this table — the shipped pages become the one and only
        copy this role serves from.  All-or-nothing: a :class:`PageError`
        mid-claim (pool too hot) returns every page already taken and
        re-raises, so a backpressured adoption leaves no residue."""
        assert sid not in self._entries, \
            f"adoption would alias existing session {sid}"
        pids = []
        try:
            for _ in range(n_pages):
                pids.append(self.alloc(sid, evict))
        except PageError:
            self.free_session(sid)
            raise
        self.adoptions += 1
        return pids

    def set_resident(self, sid: int, pos: int,
                     evict: Optional[EvictFn] = None) -> int:
        """Give a *spilled* position a fresh page to be re-fetched into."""
        entry = self._entries[sid][pos]
        assert not entry.resident, (sid, pos, entry)
        pid = self._take_page(evict)
        self._owner[pid] = (sid, pos)
        entry.pid, entry.payload = pid, None
        entry.refetched = True
        self.refetches += 1
        return pid

    # ------------------------------------------------------------------
    # temperature (pause / resume)
    def mark_cold(self, sid: int) -> None:
        """Owner paused: its resident pages become eviction candidates."""
        for e in self.entries(sid):
            if e.resident and e.pid not in self._cold:
                self._cold[e.pid] = None

    def mark_hot(self, sid: int) -> int:
        """Owner resuming: pull surviving pages off the eviction queue.

        Returns how many pages are still resident.  Counting them as
        copy-free readmits is deferred to :meth:`note_resumed` — a resume
        attempt can still fail (pool too hot to re-home spilled pages),
        and pages refetched through the spill tier were copied, not kept."""
        kept = 0
        for e in self.entries(sid):
            if e.resident:
                self._cold.pop(e.pid, None)
                kept += 1
        return kept

    def note_resumed(self, sid: int) -> int:
        """Commit a SUCCESSFUL resume: count (and return) the pages that
        survived the whole pause in place — resident and never refetched —
        and start a fresh cycle for the next pause."""
        kept = 0
        for e in self.entries(sid):
            if e.resident and not e.refetched:
                kept += 1
            e.refetched = False
        self.readmits_free += kept
        return kept

    # ------------------------------------------------------------------
    # release
    def free_session(self, sid: int) -> List[Any]:
        """Return a retired/cancelled session's pages to the free list.

        Returns the spill payloads of its non-resident positions so the
        caller can discard them (SpillTier budget).  Double-free safe:
        freeing an unknown sid is a no-op returning []."""
        payloads = []
        for e in self._entries.pop(sid, []):
            if e.resident:
                assert e.pid not in self._free, f"double free of page {e.pid}"
                self._owner.pop(e.pid)
                self._cold.pop(e.pid, None)
                self._free.append(e.pid)
            elif e.payload is not None:
                payloads.append(e.payload)
        return payloads

    # ------------------------------------------------------------------
    def check(self) -> None:
        """Internal-consistency audit (the property suite calls this after
        every step): no page aliased across sessions, free list duplicate-
        free and disjoint from owned pages, cold ⊆ owned."""
        assert len(set(self._free)) == len(self._free), "free-list duplicates"
        owned = set(self._owner)
        assert not (owned & set(self._free)), "page both free and owned"
        seen = {}
        for sid, entries in self._entries.items():
            for pos, e in enumerate(entries):
                if e.resident:
                    assert e.pid not in seen, \
                        f"page {e.pid} aliased: {seen[e.pid]} and {sid}"
                    seen[e.pid] = sid
                    assert self._owner.get(e.pid) == (sid, pos), \
                        (e.pid, self._owner.get(e.pid), sid, pos)
        assert seen.keys() == owned, "owner map out of sync"
        assert set(self._cold) <= owned, "cold page not owned"
        assert len(self._free) + len(owned) == self.num_pages, \
            "pages leaked or invented"

    def describe(self) -> str:
        return (f"pages[{self.num_pages}x{self.page_size} "
                f"free={self.num_free()} cold={self.num_cold()} "
                f"evict={self.evictions} refetch={self.refetches} "
                f"readmit_free={self.readmits_free} "
                f"adopt={self.adoptions}]")
