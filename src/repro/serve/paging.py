"""PageTable: free-list page allocation for the paged KV cache.

The paper's pooled-memory thesis says capacity management must be
transparent to the algorithm while the runtime decides placement; the page
is the unit of that placement for serving.  This module is the pure-Python
bookkeeping half (no jax): which sessions hold which fixed-size page, which
pages are *cold* (every holder paused) and therefore evictable, and which
logical positions of a session currently live in the spill tier.  The array
surgery — extracting/inserting page contents, codecs, the spill-tier
stash/fetch — stays in :class:`~repro.serve.cache_manager.PagedKVCacheManager`,
which drives this table and hands it an eviction callback.

Lifecycle of one page position of one session:

          alloc                    mark_cold        (demand) evict_cb
  FREE ─────────► RESIDENT+hot ───────────► RESIDENT+cold ───────────► SPILLED
                      ▲                          │ mark_hot                │
                      └──────────────────────────┘ (copy-free readmit)     │
                      ▲                                 set_resident       │
                      └────────────────────────────────────────────────────┘

Pausing a session costs nothing: its pages merely become eviction
candidates (LRU by pause order).  They are spilled *lazily*, one page at a
time, only when an allocation finds the free list empty — and a session
resumed before that happens re-binds with **zero copies** (the
Buddy-Compression cold-page pattern, arXiv:1903.02596).

**Prefix sharing** (copy-on-write): a physical page may back the same
logical position of many sessions — :meth:`share` binds an already
resident page read-only as another session's next logical page.  The
per-page refcount is the holder set in ``_owner``; the frame returns to
the free list only when the last holder releases it, a shared page is
evictable only once *every* holder is paused, and evicting it spills
**one** payload (a :class:`SharedPayload`) referenced by all holders —
N sessions sharing a cold prefix page cost one stash, not N.  Refetching
any holder re-homes every holder onto the one fresh frame.  Writers never
mutate a shared frame: the cache manager forks (copies) a page into a
private frame before any write (see ``PagedKVCacheManager.match_prefix``).
Every invariant the property suite drives is checked by :meth:`check`.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import (Any, Callable, Dict, List, Optional, Set, Tuple)


class PageError(RuntimeError):
    """Allocation failure: every page is hot (resident running sessions)."""


def pages_for(rows: int, page_size: int) -> int:
    """Pages needed to hold ``rows`` cache rows (always >= 1).

    Module-level so every party to the page contract rounds identically:
    the decode table's charge/claim (:meth:`PageTable.pages_for`), the
    prefill role's quota reservation and handoff chunk count
    (serve/engine.py) — a divergence would break the shared-ledger
    reservation that follows a session across the disaggregated split."""
    return max(1, -(-rows // page_size))


#: evict_cb(holder_sid, position, page_id) -> payload
#: Called while the page is still resident; must copy the page's contents
#: out (spill-tier stash) and return an opaque payload the table stores in
#: the holder's entry (for a shared page: one payload, wrapped in a
#: SharedPayload, stored in every holder's entry).  Raising aborts the
#: allocation.
EvictFn = Callable[[int, int, int], Any]


@dataclasses.dataclass
class SharedPayload:
    """One spill payload referenced by every holder of an evicted shared
    page.  ``holders`` shrinks as sessions release; the inner payload is
    surrendered for discard only by the last holder, and a refetch by any
    holder re-homes all of them onto the one fresh frame."""

    payload: Any
    holders: List[Tuple[int, int]]     # (sid, pos) still referencing it


@dataclasses.dataclass
class PageEntry:
    """One logical page position of one session."""

    pid: Optional[int] = None          # resident page id (None: spilled)
    payload: Any = None                # spill payload when not resident
    #                                    (SharedPayload if the page was
    #                                    shared at eviction time)
    refetched: bool = False            # copied back through the spill tier
    #                                    during the current pause/resume
    #                                    cycle (NOT a copy-free readmit)

    @property
    def resident(self) -> bool:
        return self.pid is not None


class PageTable:
    """Session → ordered pages over a fixed pool, with lazy cold eviction
    and refcounted prefix sharing (copy-on-write is the *caller's* duty:
    the table only tracks holders; it never copies frames)."""

    def __init__(self, num_pages: int, page_size: int):
        assert num_pages >= 1 and page_size >= 1, (num_pages, page_size)
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO free list: a just-freed (warm) page is reused first
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        # pid -> holders; len(holders) IS the page's refcount
        self._owner: Dict[int, Set[Tuple[int, int]]] = {}
        self._entries: Dict[int, List[PageEntry]] = {}
        self._cold: "OrderedDict[int, None]" = OrderedDict()  # pid, LRU order
        self._paused: Set[int] = set()  # sids marked cold (pause order lost,
        #                                 _cold keeps the LRU order per pid)
        #: called with the pid whenever a frame's contents die (evicted or
        #: freed) — the cache manager uses it to invalidate its prefix
        #: index before the frame id is reused
        self.on_release: Optional[Callable[[int], None]] = None
        # counters (the metering the property suite cross-checks)
        self.evictions = 0
        self.refetches = 0
        self.readmits_free = 0         # pages re-bound without a copy
        self.adoptions = 0             # sessions claimed from another role
        self.shared_binds = 0          # share() calls (prefix-cache hits)

    # ------------------------------------------------------------------
    # queries
    def pages_for(self, rows: int) -> int:
        """Pages needed to hold ``rows`` cache rows."""
        return pages_for(rows, self.page_size)

    def sessions(self) -> Tuple[int, ...]:
        return tuple(sorted(self._entries))

    def entries(self, sid: int) -> List[PageEntry]:
        return self._entries.get(sid, [])

    def resident_pids(self, sid: int) -> List[Optional[int]]:
        """Page ids in logical order (None where the position is spilled)."""
        return [e.pid for e in self.entries(sid)]

    def spilled_positions(self, sid: int) -> List[int]:
        return [i for i, e in enumerate(self.entries(sid)) if not e.resident]

    def num_free(self) -> int:
        return len(self._free)

    def num_cold(self) -> int:
        return len(self._cold)

    def holds(self, sid: int) -> int:
        """Total pages charged to a session (resident + spilled)."""
        return len(self.entries(sid))

    def refcount(self, pid: int) -> int:
        """How many (sid, pos) entries hold the resident page ``pid``."""
        return len(self._owner.get(pid, ()))

    def num_shared(self) -> int:
        return sum(1 for holders in self._owner.values() if len(holders) > 1)

    def is_resident_pid(self, pid: int) -> bool:
        return pid in self._owner

    # ------------------------------------------------------------------
    # allocation
    def _all_holders_paused(self, pid: int) -> bool:
        return all(s in self._paused for s, _ in self._owner[pid])

    def _released(self, pid: int) -> None:
        if self.on_release is not None:
            self.on_release(pid)

    def _take_page(self, evict: Optional[EvictFn]) -> int:
        if self._free:
            return self._free.pop()
        if not self._cold:
            raise PageError(f"page pool exhausted: all {self.num_pages} "
                            f"pages are hot")
        if evict is None:
            raise PageError("free list empty and no eviction callback "
                            "(cache manager built with spill=None?)")
        vpid = next(iter(self._cold))                  # LRU victim (peek)
        holders = sorted(self._owner[vpid])
        v_sid, v_pos = holders[0]          # representative for the stash
        payload = evict(v_sid, v_pos, vpid)   # may raise: table untouched
        self._cold.pop(vpid)
        self._owner.pop(vpid)
        if len(holders) > 1:
            payload = SharedPayload(payload, holders=list(holders))
        for sid, pos in holders:
            entry = self._entries[sid][pos]
            entry.pid, entry.payload = None, payload
        self.evictions += 1                # one spill, however many holders
        self._released(vpid)
        return vpid

    def alloc(self, sid: int, evict: Optional[EvictFn] = None) -> int:
        """Append one fresh *private* page to ``sid``'s logical sequence."""
        pid = self._take_page(evict)
        pos = len(self._entries.setdefault(sid, []))
        self._owner[pid] = {(sid, pos)}
        self._entries[sid].append(PageEntry(pid=pid))
        return pid

    def share(self, sid: int, pid: int) -> int:
        """Bind the already-resident page ``pid`` read-only as ``sid``'s
        next logical page (prefix-cache hit).  The refcount (holder set)
        grows by one; a hot holder pins the frame, so the bind pulls it
        off the eviction queue.  Returns the logical position bound."""
        holders = self._owner.get(pid)
        if holders is None:
            raise PageError(f"page {pid} is not resident; cannot share")
        pos = len(self._entries.setdefault(sid, []))
        if any(s == sid for s, _ in holders):
            raise ValueError(f"session {sid} already holds page {pid}")
        holders.add((sid, pos))
        self._entries[sid].append(PageEntry(pid=pid))
        if sid not in self._paused:
            self._cold.pop(pid, None)
        self.shared_binds += 1
        return pos

    def ensure(self, sid: int, rows: int,
               evict: Optional[EvictFn] = None) -> List[int]:
        """Grow ``sid`` to cover ``rows`` cache rows; returns new page ids."""
        new = []
        while self.holds(sid) < self.pages_for(rows):
            new.append(self.alloc(sid, evict))
        return new

    def claim(self, sid: int, n_pages: int,
              evict: Optional[EvictFn] = None) -> List[int]:
        """Allocate exactly ``n_pages`` fresh pages for an *adopted* session
        (disaggregated serving: the decode role takes ownership of KV pages
        prefilled by another runtime).

        Cross-role ownership handoff must never alias: ``sid`` has to be
        unknown to this table — the shipped pages become the one and only
        copy this role serves from.  All-or-nothing: a :class:`PageError`
        mid-claim (pool too hot) returns every page already taken and
        re-raises, so a backpressured adoption leaves no residue."""
        if sid in self._entries:
            # a real raise, not an assert: this is the invariant that keeps
            # cross-role handoffs un-aliased, and it must survive python -O
            raise ValueError(f"adoption would alias existing session {sid}")
        pids = []
        try:
            for _ in range(n_pages):
                pids.append(self.alloc(sid, evict))
        except PageError:
            self.free_session(sid)
            raise
        self.adoptions += 1
        return pids

    def set_resident(self, sid: int, pos: int,
                     evict: Optional[EvictFn] = None) -> int:
        """Give a *spilled* position a fresh page to be re-fetched into.

        If the position was evicted while shared, every holder of the one
        :class:`SharedPayload` is re-homed onto the fresh frame in this
        single call — the caller fetches the payload once and the other
        holders' positions are already resident when their resumes run."""
        entry = self._entries[sid][pos]
        if entry.resident:
            raise ValueError(f"position {(sid, pos)} is already resident "
                             f"on page {entry.pid}")
        parked = entry.payload
        pid = self._take_page(evict)
        if isinstance(parked, SharedPayload):
            holders = list(parked.holders)
        else:
            holders = [(sid, pos)]
        self._owner[pid] = set(holders)
        for s, p in holders:
            e = self._entries[s][p]
            e.pid, e.payload, e.refetched = pid, None, True
        if self._all_holders_paused(pid):
            self._cold[pid] = None
        self.refetches += 1            # one fetch, however many holders
        return pid

    def unset_resident(self, sid: int, pos: int, payload: Any) -> None:
        """Roll back a :meth:`set_resident` whose data fetch failed: the
        fresh frame returns to the free list and the position(s) spill
        again over the SAME (still intact) payload — a later resume
        retries the fetch instead of serving the unfilled frame."""
        entry = self._entries[sid][pos]
        if not entry.resident:
            raise ValueError(f"position {(sid, pos)} is not resident; "
                             "nothing to roll back")
        pid = entry.pid
        for s, p in self._owner.pop(pid):
            e = self._entries[s][p]
            e.pid, e.payload, e.refetched = None, payload, False
        self._cold.pop(pid, None)
        self._free.append(pid)
        self.refetches -= 1            # the metered fetch never happened
        self._released(pid)

    # ------------------------------------------------------------------
    # temperature (pause / resume)
    def mark_cold(self, sid: int) -> None:
        """Owner paused: its resident pages become eviction candidates —
        a shared page only once *every* holder is paused."""
        self._paused.add(sid)
        for e in self.entries(sid):
            if e.resident and e.pid not in self._cold \
                    and self._all_holders_paused(e.pid):
                self._cold[e.pid] = None

    def mark_hot(self, sid: int) -> int:
        """Owner resuming: pull surviving pages off the eviction queue.

        Returns how many pages are still resident.  Counting them as
        copy-free readmits is deferred to :meth:`note_resumed` — a resume
        attempt can still fail (pool too hot to re-home spilled pages),
        and pages refetched through the spill tier were copied, not kept."""
        self._paused.discard(sid)
        kept = 0
        for e in self.entries(sid):
            if e.resident:
                self._cold.pop(e.pid, None)
                kept += 1
        return kept

    def note_resumed(self, sid: int) -> int:
        """Commit a SUCCESSFUL resume: count (and return) the pages that
        survived the whole pause in place — resident and never refetched —
        and start a fresh cycle for the next pause."""
        kept = 0
        for e in self.entries(sid):
            if e.resident and not e.refetched:
                kept += 1
            e.refetched = False
        self.readmits_free += kept
        return kept

    # ------------------------------------------------------------------
    # release
    def free_session(self, sid: int) -> List[Any]:
        """Drop one session's hold on its pages.  A private frame returns
        to the free list; a shared frame merely loses one holder (and
        becomes evictable if every survivor is paused).

        Returns the spill payloads this release *orphaned* — private
        payloads, plus a shared payload whose last holder this was — so
        the caller can discard them (SpillTier budget).  Double-free safe:
        freeing an unknown sid is a no-op returning []."""
        payloads = []
        self._paused.discard(sid)
        for pos, e in enumerate(self._entries.pop(sid, [])):
            if e.resident:
                if e.pid in self._free:
                    # a real raise, not an assert: double frees must be
                    # caught under python -O too
                    raise ValueError(f"double free of page {e.pid}")
                holders = self._owner[e.pid]
                holders.discard((sid, pos))
                if not holders:
                    self._owner.pop(e.pid)
                    self._cold.pop(e.pid, None)
                    self._free.append(e.pid)
                    self._released(e.pid)
                elif e.pid not in self._cold \
                        and self._all_holders_paused(e.pid):
                    self._cold[e.pid] = None    # last hot holder left
            elif isinstance(e.payload, SharedPayload):
                try:
                    e.payload.holders.remove((sid, pos))
                except ValueError:
                    raise ValueError(
                        f"double free of shared payload at {(sid, pos)}")
                if not e.payload.holders:
                    payloads.append(e.payload.payload)
            elif e.payload is not None:
                payloads.append(e.payload)
        return payloads

    # ------------------------------------------------------------------
    def check(self) -> None:
        """Internal-consistency audit (the property suite calls this after
        every step): no *unintended* aliasing — a pid may appear in many
        sessions' entries iff its holder set (refcount) matches exactly;
        free list duplicate-free and disjoint from held pages; cold ⊆
        held, and only when every holder is paused; shared payloads'
        holder lists in sync; frames conserved."""
        assert len(set(self._free)) == len(self._free), "free-list duplicates"
        owned = set(self._owner)
        assert not (owned & set(self._free)), "page both free and owned"
        seen: Dict[int, Set[Tuple[int, int]]] = {}
        shared_payloads: Dict[int, SharedPayload] = {}
        referers: Dict[int, Set[Tuple[int, int]]] = {}
        for sid, entries in self._entries.items():
            for pos, e in enumerate(entries):
                if e.resident:
                    seen.setdefault(e.pid, set()).add((sid, pos))
                elif isinstance(e.payload, SharedPayload):
                    key = id(e.payload)
                    shared_payloads[key] = e.payload
                    referers.setdefault(key, set()).add((sid, pos))
        for pid, holders in seen.items():
            assert self._owner.get(pid) == holders, \
                f"page {pid} aliased: holders {self._owner.get(pid)} " \
                f"but referenced by {holders}"
        assert seen.keys() == owned, "owner map out of sync"
        assert set(self._cold) <= owned, "cold page not owned"
        for pid in self._cold:
            assert self._all_holders_paused(pid), \
                f"cold page {pid} has a hot holder: {self._owner[pid]}"
        for key, sp in shared_payloads.items():
            assert set(sp.holders) == referers[key], \
                f"shared payload holders {sp.holders} out of sync with " \
                f"referencing entries {referers[key]}"
        assert len(self._free) + len(owned) == self.num_pages, \
            "pages leaked or invented"

    def describe(self) -> str:
        return (f"pages[{self.num_pages}x{self.page_size} "
                f"free={self.num_free()} cold={self.num_cold()} "
                f"shared={self.num_shared()} "
                f"evict={self.evictions} refetch={self.refetches} "
                f"readmit_free={self.readmits_free} "
                f"adopt={self.adoptions}]")
