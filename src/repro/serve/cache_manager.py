"""KVCacheManager: decode-slot allocation over the memory-tier hierarchy.

One of the three serving APIs behind the ``Engine`` facade (DESIGN.md §6).
The manager owns the stacked KV cache tree and everything about where a
session's cache lives:

* **sizing** — when the caller leaves ``batch``/``max_len`` unspecified,
  :func:`~repro.serve.kv_cache.derive_cache_shape` sizes them from the
  serving tier's ``cache_tier_report`` (the paper's capacity contract
  answering "how much cache can one device address?").
* **slot lifecycle** — allocate / bind / release of the fixed decode slots
  (the hot, HBM-resident working set).
* **spill** — a paused (preempted / waiting) session's KV leaves HBM
  through a secondary :class:`~repro.core.runtime.MemoryRuntime` whose
  tier defaults to ``spill`` (pooled HBM overflowing to host DRAM — the
  Buddy-Compression cold-page pattern, arXiv:1903.02596) and is fetched
  back into a fresh slot on resume.  Every leg is metered: the runtime's
  ``traffic_report()`` shows ``kv_stash``/``kv_fetch`` byte counts.

Per-slot cache surgery uses the models/transformer helpers
(:func:`~repro.models.transformer.slot_cache` /
:func:`~repro.models.transformer.merge_slot_cache`), jitted once here.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Dict, List, Optional, Union

import jax

from repro.configs.base import MemoryPlan
from repro.core.runtime import MemoryRuntime, fmt_bytes
from repro.core.tiers import SpillTier, TransferHints
from repro.models import transformer as tfm
from repro.serve.kv_cache import (DEFAULT_HBM_FRAC, DEFAULT_MAX_BATCH,
                                  DEFAULT_MAX_LEN, derive_cache_shape)
from repro.serve.session import Session, SessionState

log = logging.getLogger(__name__)


@dataclasses.dataclass
class _SpilledSlot:
    """One paused session's cache, parked in the secondary tier."""

    session: Session                  # owner (for cancelled-entry sweeps)
    treedef: Any                      # cache tree structure
    payloads: List[Any]               # one tier payload per cache leaf
    dtypes: List[Any]                 # restore dtypes on fetch


class KVCacheManager:
    """Slot allocation + tier placement for the serving KV cache."""

    def __init__(self, model, batch: Optional[int] = None,
                 max_len: Optional[int] = None, *,
                 spill: Union[str, MemoryRuntime, None] = "spill",
                 hbm_frac: float = DEFAULT_HBM_FRAC,
                 max_batch: int = DEFAULT_MAX_BATCH,
                 default_max_len: int = DEFAULT_MAX_LEN,
                 dtype_bytes: int = 2):
        self.model = model
        sized = derive_cache_shape(
            model.cfg, model.runtime, batch, max_len, hbm_frac=hbm_frac,
            max_batch=max_batch, default_max_len=default_max_len,
            dtype_bytes=dtype_bytes)
        self.batch: int = sized["batch"]
        self.max_len: int = sized["max_len"]
        self.report: Dict[str, Any] = sized["report"]
        self.auto_sized = batch is None or max_len is None

        self.caches = model.init_cache(self.batch, self.max_len)
        self.slots: List[Optional[Session]] = [None] * self.batch
        self._spilled: Dict[int, _SpilledSlot] = {}

        # secondary tier for cold slots (None: preemption unsupported)
        if isinstance(spill, MemoryRuntime):
            self.spill_runtime: Optional[MemoryRuntime] = spill
        elif spill is None:
            self.spill_runtime = None
        else:
            self.spill_runtime = MemoryRuntime(
                model.plan,
                MemoryPlan(policy=spill, placement=model.memory.placement),
                model.mesh, planner=model.planner)

        self._slot_get = jax.jit(tfm.slot_cache)
        self._slot_put = jax.jit(tfm.merge_slot_cache)
        log.info("kv cache [%s]: batch=%d max_len=%d (%s/device, fits=%s)%s",
                 self.report["tier"], self.batch, self.max_len,
                 fmt_bytes(self.report["per_device_bytes"]),
                 self.report["fits"],
                 " [auto-sized]" if self.auto_sized else "")

    # ------------------------------------------------------------------
    # slot lifecycle
    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def num_free(self) -> int:
        return sum(1 for s in self.slots if s is None)

    def running(self) -> List[Session]:
        return [s for s in self.slots if s is not None]

    def fits_prompt(self, prompt_len: int) -> bool:
        """A prompt must leave at least one cache row for decode writes."""
        return prompt_len < self.max_len

    def bind(self, slot: int, sess: Session, length: int) -> None:
        assert self.slots[slot] is None, (slot, self.slots[slot])
        self.slots[slot] = sess
        sess.slot = slot
        sess.length = length
        sess.state = SessionState.RUNNING
        sess.steps_since_admit = 0

    def release(self, sess: Session) -> None:
        """Retire a session's slot (its cache rows are dead)."""
        if sess.slot is not None:
            self.slots[sess.slot] = None
            sess.slot = None
        self.drop_spilled(sess)

    # ------------------------------------------------------------------
    # spill / resume (cold slots through the secondary tier)
    def pause(self, sess: Session) -> None:
        """Preempt: move the session's KV out of HBM into the spill tier."""
        assert sess.slot is not None, sess
        assert self.spill_runtime is not None, \
            "KVCacheManager(spill=None) cannot preempt sessions"
        one = self._slot_get(self.caches, sess.slot)
        leaves, treedef = jax.tree_util.tree_flatten(one)
        payloads, dtypes = [], []
        for x in leaves:
            payloads.append(self.spill_runtime.stash(
                x, TransferHints(dtype=x.dtype, batch_dim=1,
                                 name="kv_spill"),
                direction="kv_stash"))
            dtypes.append(x.dtype)
        self._spilled[sess.uid] = _SpilledSlot(sess, treedef, payloads,
                                               dtypes)
        self.slots[sess.slot] = None
        sess.slot = None
        sess.state = SessionState.PAUSED
        sess.steps_since_admit = 0
        sess.preemptions += 1

    def resume(self, sess: Session, slot: int) -> None:
        """Fetch a paused session's KV back from the spill tier into
        ``slot`` and make it resident again."""
        entry = self._spilled.pop(sess.uid)
        leaves = []
        for payload, dt in zip(entry.payloads, entry.dtypes):
            leaves.append(self.spill_runtime.fetch(
                payload, TransferHints(dtype=dt, batch_dim=1,
                                       name="kv_spill"),
                direction="kv_fetch"))
            self._discard(payload)
        one = jax.tree_util.tree_unflatten(entry.treedef, leaves)
        length = sess.length
        self.caches = self._slot_put(self.caches, one, slot)
        self.bind(slot, sess, length)

    def drop_spilled(self, sess: Session) -> None:
        """Discard a paused session's parked cache (cancel/retire)."""
        entry = self._spilled.pop(sess.uid, None)
        if entry is not None:
            for payload in entry.payloads:
                self._discard(payload)

    def sweep_cancelled(self) -> None:
        """Drop parked caches whose owner was cancelled while paused —
        returns their SpillTier budget instead of leaking it."""
        for entry in list(self._spilled.values()):
            if entry.session.done:
                self.drop_spilled(entry.session)

    def _discard(self, payload) -> None:
        """Return capacity-contract budget to a SpillTier leg, if any."""
        tier = self.spill_runtime.tier if self.spill_runtime else None
        while tier is not None:
            if isinstance(tier, SpillTier):
                tier.discard(payload)
                return
            tier = getattr(tier, "inner", None)

    def spilled_uids(self) -> List[int]:
        return sorted(self._spilled)

    # ------------------------------------------------------------------
    def traffic_report(self) -> Dict[str, Any]:
        """Spill-tier byte accounting (kv_stash / kv_fetch directions)."""
        if self.spill_runtime is None:
            return {}
        return self.spill_runtime.traffic_report()

    def describe(self) -> str:
        spill = (self.spill_runtime.tier.describe()
                 if self.spill_runtime else "none")
        return (f"kv[batch={self.batch} max_len={self.max_len} "
                f"tier={self.report['tier']} spill={spill}]")
