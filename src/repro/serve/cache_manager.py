"""KVCacheManager: decode-slot / page allocation over the memory tiers.

One of the three serving APIs behind the ``Engine`` facade (DESIGN.md §6).
The manager owns the KV cache storage and everything about where a
session's cache lives:

* **sizing** — when the caller leaves ``batch``/``max_len`` unspecified,
  :func:`~repro.serve.kv_cache.derive_cache_shape` sizes them from the
  serving tier's ``cache_tier_report`` (the paper's capacity contract
  answering "how much cache can one device address?").
* **slot lifecycle** — allocate / bind / release of the fixed decode slots
  (the hot, HBM-resident working set).
* **spill** — a paused (preempted / waiting) session's KV leaves HBM
  through a secondary :class:`~repro.core.runtime.MemoryRuntime` whose
  tier defaults to ``spill`` (pooled HBM overflowing to host DRAM — the
  Buddy-Compression cold-page pattern, arXiv:1903.02596) and is fetched
  back on resume.  Every leg is metered: the runtime's
  ``traffic_report()`` shows ``kv_stash``/``kv_fetch`` byte counts.

Two storage models share that contract:

* :class:`KVCacheManager` — the monolithic slot: one contiguous
  ``max_len``-row region per session, spilled/fetched whole.
* :class:`PagedKVCacheManager` — the paper's pooled-memory model applied
  to serving: KV lives in a pool of fixed-size **pages**
  (``models/transformer.paged_pool``/``gather_pages``), a session holds a
  page list (:class:`~repro.serve.paging.PageTable`), pausing merely marks
  pages *cold*, and spill happens **lazily per page** — through the spill
  tier with a per-tenant codec from the ``core/compress.py`` registry —
  only when an allocation actually needs the frame.  A session resumed
  before its pages were reclaimed re-binds with zero copies.

Per-slot cache surgery uses the models/transformer helpers
(:func:`~repro.models.transformer.slot_cache` /
:func:`~repro.models.transformer.merge_slot_cache`), jitted once here.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import jax
import numpy as np

from repro.configs.base import MemoryPlan
from repro.core.compress import (Codec, decode_tensor, encode_tensor,
                                 get_codec)
from repro.core.runtime import MemoryRuntime, fmt_bytes
from repro.core.tiers import TransferHints
from repro.models import transformer as tfm
from repro.serve.kv_cache import (DEFAULT_HBM_FRAC, DEFAULT_MAX_BATCH,
                                  DEFAULT_MAX_LEN, derive_cache_shape)
from repro.serve.paging import PageError, PageTable, SharedPayload
from repro.serve.session import Session, SessionState

log = logging.getLogger(__name__)


@dataclasses.dataclass
class PrefixMatch:
    """Result of matching a new prompt against the prefix index.

    ``pids`` are fully-matched pages the admission binds **read-only**
    (refcount bump, no copy, no prefill compute for their rows);
    ``fork_pid`` is the donor frame whose first ``rows - len(pids) *
    page_size`` rows match — it is **copied** into a private frame before
    the prefill scatter (copy-on-write fork at the first divergent
    token).  ``rows`` is the total prompt rows covered; the suffix
    prefill starts there."""

    pids: List[int]
    fork_pid: Optional[int]
    rows: int

    @property
    def shared_pages(self) -> int:
        """Pages bound read-only — the quota charge excludes these."""
        return len(self.pids)

    @property
    def write_from(self) -> int:
        """First page column the prefill scatter may write (the forked
        page is private and writable; the shared ones route to scratch)."""
        return len(self.pids)


@dataclasses.dataclass
class _SpilledSlot:
    """One paused session's slot-shaped cache, parked in the secondary tier."""

    session: Session                  # owner (for cancelled-entry sweeps)
    treedef: Any                      # cache tree structure
    payloads: List[Any]               # one tier payload per cache leaf
    dtypes: List[Any]                 # restore dtypes on fetch


@dataclasses.dataclass
class _SpilledPage:
    """One evicted page, parked in the secondary tier (paged manager)."""

    treedef: Any                      # page tree structure
    items: List[Tuple[Any, Any, Any]]  # (tier payload, codec scale, dtype)
    codec: Optional[str]              # codec name ('' semantics: None=raw)


class KVCacheManager:
    """Slot allocation + tier placement for the serving KV cache."""

    #: storage model marker (the Engine branches its jitted paths on this)
    paged: bool = False
    #: page size in cache rows (None: monolithic slots)
    page_size: Optional[int] = None

    def __init__(self, model, batch: Optional[int] = None,
                 max_len: Optional[int] = None, *,
                 spill: Union[str, MemoryRuntime, None] = "spill",
                 hbm_frac: float = DEFAULT_HBM_FRAC,
                 max_batch: int = DEFAULT_MAX_BATCH,
                 default_max_len: int = DEFAULT_MAX_LEN,
                 dtype_bytes: int = 2):
        self.model = model
        sized = derive_cache_shape(
            model.cfg, model.runtime, batch, max_len,
            page_size=self.page_size, hbm_frac=hbm_frac,
            max_batch=max_batch, default_max_len=default_max_len,
            dtype_bytes=dtype_bytes)
        self.batch: int = sized["batch"]
        self.max_len: int = sized["max_len"]
        self.report: Dict[str, Any] = sized["report"]
        self.auto_sized = not batch or not max_len

        self.slots: List[Optional[Session]] = [None] * self.batch
        self._spilled: Dict[int, _SpilledSlot] = {}
        self._init_storage()

        # secondary tier for cold slots/pages (None: preemption unsupported)
        if isinstance(spill, MemoryRuntime):
            self.spill_runtime: Optional[MemoryRuntime] = spill
        elif spill is None:
            self.spill_runtime = None
        else:
            self.spill_runtime = MemoryRuntime(
                model.plan,
                MemoryPlan(policy=spill, placement=model.memory.placement),
                model.mesh, planner=model.planner)

        self._slot_get = jax.jit(tfm.slot_cache)
        self._slot_put = jax.jit(tfm.merge_slot_cache)
        log.info("kv cache [%s]: batch=%d max_len=%d (%s/device, fits=%s)%s%s",
                 self.report["tier"], self.batch, self.max_len,
                 fmt_bytes(self.report["per_device_bytes"]),
                 self.report["fits"],
                 " [auto-sized]" if self.auto_sized else "",
                 f" [pages={self.report['num_pages']}"
                 f"x{self.page_size}]" if self.paged else "")

    def _init_storage(self) -> None:
        self.caches = self.model.init_cache(self.batch, self.max_len)

    # ------------------------------------------------------------------
    # slot lifecycle
    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def num_free(self) -> int:
        return sum(1 for s in self.slots if s is None)

    def running(self) -> List[Session]:
        return [s for s in self.slots if s is not None]

    def fits_prompt(self, prompt_len: int) -> bool:
        """A prompt must leave at least one cache row for decode writes."""
        return prompt_len < self.max_len

    def session_pages(self, prompt_len: int, max_new: int) -> int:
        """Worst-case page reservation for one session (0: unpaged — page
        budgets only bind in paged mode)."""
        return 0

    def match_prefix(self, prompt) -> Optional[PrefixMatch]:
        """Hook: look the prompt up in the prefix index (paged manager
        with ``prefix_share=True`` only).  Read-only — admission calls it
        before the quota check so shared pages are not charged."""
        return None

    def note_prefilled(self, sess: Session, prompt,
                       match: Optional[PrefixMatch] = None) -> None:
        """Hook: an admission finished its prefill — register its full
        prompt pages in the prefix index (paged manager only)."""

    def prepare_slot(self, slot: int, sess: Session, rows: int,
                     match: Optional[PrefixMatch] = None) -> None:
        """Hook: back ``rows`` cache rows for a fresh admission (paged:
        allocate the prompt's pages before the prefill gather, binding
        ``match``'s shared pages read-only first)."""

    def abort_prepare(self, sess: Session) -> None:
        """Hook: undo a failed :meth:`prepare_slot` (paged: return the
        partially-allocated pages — a deferred queued session must not
        pin hot, unevictable pages while it waits)."""

    def ensure_rows(self, sess: Session, rows: int) -> None:
        """Hook: grow a resident session to ``rows`` cache rows (paged:
        demand page allocation, evicting cold pages as needed)."""

    def bind(self, slot: int, sess: Session, length: int) -> None:
        assert self.slots[slot] is None, (slot, self.slots[slot])
        self.slots[slot] = sess
        sess.slot = slot
        sess.length = length
        sess.state = SessionState.RUNNING
        sess.steps_since_admit = 0

    def release(self, sess: Session) -> None:
        """Retire a session's slot (its cache rows are dead)."""
        if sess.slot is not None:
            self.slots[sess.slot] = None
            sess.slot = None
        self.drop_spilled(sess)

    @property
    def can_preempt(self) -> bool:
        return self.spill_runtime is not None

    # ------------------------------------------------------------------
    # disaggregated handoff (prefill role: ship a finished prompt's KV)
    def export_slot(self, sess: Session):
        """Copy one resident session's single-slot cache tree out of the
        batched storage — the prefill-role handoff unit, chunked into
        page-shaped trees by :func:`repro.models.transformer.slot_pages`."""
        assert sess.slot is not None, sess
        return self._slot_get(self.caches, sess.slot)

    # ------------------------------------------------------------------
    # spill / resume (cold slots through the secondary tier)
    def pause(self, sess: Session) -> None:
        """Preempt: move the session's KV out of HBM into the spill tier."""
        assert sess.slot is not None, sess
        assert self.spill_runtime is not None, \
            "KVCacheManager(spill=None) cannot preempt sessions"
        self._park_slot(self.caches, sess)
        self._clear_slot(sess)

    def resume(self, sess: Session, slot: int) -> None:
        """Fetch a paused session's KV back from the spill tier into
        ``slot`` and make it resident again."""
        one = self._unpark_slot(sess)
        self.caches = self._slot_put(self.caches, one, slot)
        self.bind(slot, sess, sess.length)

    def _park_slot(self, tree, sess: Session) -> None:
        """Stash one slot of ``tree`` (leaf-wise) into the spill tier."""
        one = self._slot_get(tree, sess.slot)
        leaves, treedef = jax.tree_util.tree_flatten(one)
        payloads, dtypes = [], []
        for x in leaves:
            payloads.append(self.spill_runtime.stash(
                x, TransferHints(dtype=x.dtype, batch_dim=1,
                                 name="kv_spill"),
                direction="kv_stash"))
            dtypes.append(x.dtype)
        self._spilled[sess.uid] = _SpilledSlot(sess, treedef, payloads,
                                               dtypes)

    def _unpark_slot(self, sess: Session):
        entry = self._spilled.pop(sess.uid)
        leaves = []
        for payload, dt in zip(entry.payloads, entry.dtypes):
            leaves.append(self.spill_runtime.fetch(
                payload, TransferHints(dtype=dt, batch_dim=1,
                                       name="kv_spill"),
                direction="kv_fetch"))
            self._discard(payload)
        return jax.tree_util.tree_unflatten(entry.treedef, leaves)

    def _clear_slot(self, sess: Session) -> None:
        self.slots[sess.slot] = None
        sess.slot = None
        sess.state = SessionState.PAUSED
        sess.steps_since_admit = 0
        sess.preemptions += 1

    def drop_spilled(self, sess: Session) -> None:
        """Discard a paused session's parked cache (cancel/retire)."""
        entry = self._spilled.pop(sess.uid, None)
        if entry is not None:
            for payload in entry.payloads:
                self._discard(payload)

    def sweep_cancelled(self) -> None:
        """Drop parked caches whose owner was cancelled while paused —
        returns their SpillTier budget instead of leaking it."""
        for entry in list(self._spilled.values()):
            if entry.session.done:
                self.drop_spilled(entry.session)

    def _discard(self, payload) -> None:
        """Return capacity-contract budget to a SpillTier leg, if any."""
        if self.spill_runtime is not None:
            self.spill_runtime.discard(payload)

    def spilled_uids(self) -> List[int]:
        return sorted(self._spilled)

    # ------------------------------------------------------------------
    def traffic_report(self) -> Dict[str, Any]:
        """Spill-tier byte accounting (kv_stash / kv_fetch directions)."""
        if self.spill_runtime is None:
            return {}
        return self.spill_runtime.traffic_report()

    def describe(self) -> str:
        spill = (self.spill_runtime.tier.describe()
                 if self.spill_runtime else "none")
        return (f"kv[batch={self.batch} max_len={self.max_len} "
                f"tier={self.report['tier']} spill={spill}]")


# ---------------------------------------------------------------------------
class PagedKVCacheManager(KVCacheManager):
    """Paged KV: sessions hold page lists over a shared pool.

    Storage is the (pool, slot_tree) pair from
    :func:`~repro.models.transformer.paged_pool`: self-attention K/V rows
    live in ``num_pages`` fixed-size pages (+1 scratch page absorbing
    masked writes); SSM / cross-attention state stays slot-shaped and is
    parked whole on preemption, exactly like the base manager.

    * ``pages`` < batch x pages_per_slot **overcommits** the pool —
      admission is funded by typical usage instead of the worst case,
      which is the paper's pooled-capacity argument; pool pressure then
      evicts cold pages or, at the limit, preempts (Engine policy).
    * ``codec_for(tenant)`` picks the spill codec per tenant from the
      ``core/compress.py`` registry (None: raw pages).  ``codec_kernel``
      routes the quantize/pack through the Pallas kernel twin
      (``kernels/offload_pack.py``) instead of the jnp reference.
    * ``prefix_share=True`` turns on the radix prefix index: admission
      matches a new prompt against cached prefixes page-by-page
      (:meth:`match_prefix`), binds fully-matched pages read-only
      (refcount bump in the :class:`~repro.serve.paging.PageTable`), and
      forks — copies into a private frame — the page holding the first
      divergent token.  Only models whose serving state is pure KV can
      share (recurrent SSM/conv slot state is a running summary of the
      whole prefix and cannot be grafted mid-sequence); the flag
      self-disables otherwise.
    """

    paged = True

    def __init__(self, model, batch: Optional[int] = None,
                 max_len: Optional[int] = None, *,
                 page_size: int = 64,
                 pages: Optional[int] = None,
                 codec_for: Optional[Callable[[str], Optional[str]]] = None,
                 codec_kernel: bool = False,
                 decode_kernel: bool = False,
                 prefix_share: bool = False,
                 **kwargs):
        self.page_size = int(page_size)
        self._pages_override = pages
        self.codec_for = codec_for or (lambda tenant: None)
        self.codec_kernel = codec_kernel
        self.decode_kernel = bool(decode_kernel)
        self._sessions: Dict[int, Session] = {}       # uid -> owner
        self._codec_by_uid: Dict[int, Optional[str]] = {}
        self.prefix_share = bool(prefix_share)
        # radix index over page-sized token chunks: node maps a page's
        # token tuple -> [pid, child_node]; a page's KV depends only on
        # the token chain up to its last row (causal attention), so the
        # chain IS the cache key
        self._prefix_root: Dict[Tuple[int, ...], List[Any]] = {}
        self._pid_nodes: Dict[int, Tuple[Dict, Tuple[int, ...]]] = {}
        self.prefix_hits = 0           # pages bound read-only
        self.prefix_forks = 0          # COW page copies
        self.prefix_rows_reused = 0    # prompt rows skipped at prefill
        self.prefix_rows_prompted = 0  # prompt rows seen (hit-rate denom)
        super().__init__(model, batch, max_len, **kwargs)
        cfg = model.cfg
        if self.prefix_share and (
                self._has_slot_leaves or cfg.is_encoder_decoder
                or getattr(cfg, "mrope_sections", None)):
            log.warning("prefix sharing disabled: model carries recurrent "
                        "slot state (or enc-dec/mrope positions) that "
                        "cannot be grafted mid-sequence")
            self.prefix_share = False

    def _init_storage(self) -> None:
        caches = self.model.init_cache(self.batch, self.max_len)
        self.pool, self.slot_tree = tfm.paged_pool(caches, self.page_size)
        self.pages_per_slot = self.max_len // self.page_size
        full = self.batch * self.pages_per_slot
        num = self._pages_override if self._pages_override else full
        if not 1 <= num <= full:
            raise ValueError(f"pages must be in [1, {full}]: {num}")
        if num < full:
            # overcommit REALLY shrinks the resident pool: keep num frames
            # plus the trailing scratch frame — the capacity saving is
            # physical, not just simulated eviction pressure
            import jax.numpy as jnp
            self.pool = jax.tree.map(
                lambda c: jnp.concatenate([c[:, :num], c[:, -1:]], axis=1),
                self.pool)
        self.table = PageTable(num, self.page_size)
        # frames die (evicted / freed) -> the prefix index must forget
        # them — and a compressed side-pool frame must be returned —
        # before the frame id is reused for different contents
        self.table.on_release = self._on_pid_release
        self.scratch_id = num                     # pool holds num+1 frames
        self._pmap_cache = None
        self._pmap_np: Optional[np.ndarray] = None
        self.report["num_pages"] = num
        self._has_slot_leaves = bool(jax.tree_util.tree_leaves(self.slot_tree))
        # ---- in-kernel decode state (decode_kernel=True) -------------
        # compressed side pool: int8 payload frames + one scale per
        # (group-stack row, frame); page-map ids >= num+1 address frame
        # ``id - (num+1)`` here and the paged-attention kernel dequants
        # them in the K/V load (fused codec decode).  Only codecs whose
        # payload is int8 (int8 / blocksparse) are residency-eligible.
        import jax.numpy as jnp
        self._cframe_by_pid: Dict[int, Tuple] = {}   # pid -> (ci, codec,
        self._cframe_free: List[int] = []            #   treedef, scales,
        if self.decode_kernel:                       #   dtypes)
            self.cpool = jax.tree.map(
                lambda c: jnp.zeros(c.shape[:1] + (num,) + c.shape[2:],
                                    jnp.int8), self.pool)
            self.cscale = jax.tree.map(
                lambda c: jnp.zeros(c.shape[:1] + (num, 1), jnp.float32),
                self.pool)
            self._cframe_free = list(range(num))
        self._cframe_adopts = 0
        # decode-io metering: pages the attention actually reads per step
        # (the paper's claim is that this scales with rows held, not pool
        # size — gather_equiv is what the legacy materialize-all path reads)
        cfg = self.model.cfg
        self._decode_window = cfg.window if cfg.attention == "swa" else 0
        self._decode_steps = 0
        self._decode_pages_touched = 0
        self._decode_pages_gather = 0
        self._page_frame_bytes = sum(
            int(np.prod(c.shape[:1] + c.shape[2:])) * c.dtype.itemsize
            for c in jax.tree_util.tree_leaves(self.pool))

    # ------------------------------------------------------------------
    # page-backed rows
    def session_pages(self, prompt_len: int, max_new: int) -> int:
        """Worst-case reservation: rows the session can ever occupy."""
        return self.table.pages_for(min(self.max_len, prompt_len + max_new))

    # ------------------------------------------------------------------
    # prefix sharing: radix index over page-sized token chunks
    def _on_pid_release(self, pid: int) -> None:
        """A frame id died (evicted / freed): forget its prefix-index
        chain and return its compressed side-pool frame, if any.  Runs
        AFTER the evict callback, so an eviction stashes the compressed
        payload before the side frame is recycled."""
        self._drop_prefix_pid(pid)
        entry = self._cframe_by_pid.pop(pid, None)
        if entry is not None:
            self._cframe_free.append(entry[0])
            self._pmap_cache = None

    def _drop_prefix_pid(self, pid: int) -> None:
        entry = self._pid_nodes.pop(pid, None)
        if entry is None:
            return
        parent, key = entry
        child = parent.get(key)
        if child is not None and child[0] == pid:
            # drops the whole subtree with it: a child chain without its
            # parent chain is unreachable by construction
            del parent[key]

    def match_prefix(self, prompt) -> Optional[PrefixMatch]:
        """Walk the radix index page-by-page along the prompt.

        Fully-matched pages are returned for read-only binding; at the
        first divergence the best partially-matching sibling becomes the
        COW fork donor.  At least one prompt token is always left to the
        suffix prefill (its logits sample the first new token), so a
        fully-cached prompt still matches only ``len(prompt) - 1``
        rows.  Read-only: admission calls this *before* the quota check
        (shared pages are not charged) and nothing mutates the table
        between the match and :meth:`prepare_slot`."""
        if not self.prefix_share:
            return None
        toks = [int(t) for t in np.asarray(prompt).reshape(-1)]
        ps = self.page_size
        limit = len(toks) - 1
        node = self._prefix_root
        pids: List[int] = []
        i = 0
        while i + ps <= limit:
            child = node.get(tuple(toks[i:i + ps]))
            if child is None or not self.table.is_resident_pid(child[0]):
                break
            pids.append(child[0])
            node = child[1]
            i += ps
        fork_pid, fork_rows = None, 0
        for key, (pid, _child) in node.items():
            if not self.table.is_resident_pid(pid):
                continue
            depth, cap = 0, min(len(key), limit - i)
            while depth < cap and key[depth] == toks[i + depth]:
                depth += 1
            if depth > fork_rows:
                fork_rows, fork_pid = depth, pid
        if not pids and not fork_rows:
            return None
        if not fork_rows:
            fork_pid = None
        return PrefixMatch(pids=pids, fork_pid=fork_pid, rows=i + fork_rows)

    def note_prefilled(self, sess: Session, prompt,
                       match: Optional[PrefixMatch] = None) -> None:
        """Register the admission's full prompt pages in the prefix index
        (shared pages are already there under the donor's pid; a forked
        page registers as a sibling chain) and tally the hit-rate."""
        if not self.prefix_share:
            return
        toks = [int(t) for t in np.asarray(prompt).reshape(-1)]
        self.prefix_rows_prompted += len(toks)
        if match is not None:
            self.prefix_rows_reused += match.rows
        ps = self.page_size
        pids = self.table.resident_pids(sess.uid)
        node = self._prefix_root
        for p in range(len(toks) // ps):
            key = tuple(toks[p * ps:(p + 1) * ps])
            child = node.get(key)
            if child is None:
                pid = pids[p]
                if pid is None:
                    break
                child = [pid, {}]
                node[key] = child
                self._pid_nodes[pid] = (node, key)
            node = child[1]

    def prepare_slot(self, slot: int, sess: Session, rows: int,
                     match: Optional[PrefixMatch] = None) -> None:
        """Back the prompt's rows with pages before the prefill gather.

        With a prefix ``match``: the fully-matched pages bind read-only
        (refcount bump, logical positions 0..n-1), the fork donor — if
        any — is copied into a fresh private frame (copy-on-write,
        *before* the prefill scatter ever runs), and only the remaining
        positions allocate fresh frames.  Raises
        :class:`~repro.serve.paging.PageError` when the pool cannot
        cover them (every page hot) — the Engine then aborts (undoing
        the shared binds) and defers admission."""
        self._sessions[sess.uid] = sess
        self._codec_by_uid[sess.uid] = self.codec_for(sess.tenant)
        if match is not None:
            for pid in match.pids:
                self.table.share(sess.uid, pid)
            if match.fork_pid is not None:
                new_pid = self.table.alloc(sess.uid, self._evict_cb)
                # COW fork: the donor's rows up to the divergence are
                # valid as-is; the suffix prefill overwrites the tail.
                # (If the alloc just evicted the donor itself, the frame
                # still holds the donor's bytes — the copy is the
                # identity and stays correct.)
                self.pool = tfm.page_insert(
                    self.pool, tfm.page_slice(self.pool, match.fork_pid),
                    new_pid)
                self.prefix_forks += 1
            self.prefix_hits += len(match.pids)
        self.table.ensure(sess.uid, rows, self._evict_cb)

    def abort_prepare(self, sess: Session) -> None:
        for entry in self.table.free_session(sess.uid):
            self._discard_page(entry)
        self._sessions.pop(sess.uid, None)
        self._codec_by_uid.pop(sess.uid, None)

    def bind(self, slot: int, sess: Session, length: int) -> None:
        # a session entering a slot changes the gather map — a stale cache
        # here silently routes its decode through the scratch page
        super().bind(slot, sess, length)
        self._pmap_cache = None

    def ensure_rows(self, sess: Session, rows: int) -> None:
        """Demand paging for decode growth (may evict cold pages)."""
        if self.table.ensure(sess.uid, rows, self._evict_cb):
            self._pmap_cache = None

    def page_map(self) -> jax.Array:
        """(batch, pages_per_slot) int32 pool indices for the decode
        gather; unowned positions point at the scratch page.  Cached on
        device — the map only changes on admission/growth/preemption, not
        per decode step — and invalidated by every mutating path.

        With ``decode_kernel`` the map is *translated*: a page resident
        in the compressed side pool emits ``scratch_id + 1 + ci`` (ids
        past the raw pool address side-pool frames; the kernel dequants
        them in the K/V load)."""
        if self._pmap_cache is None:
            self._pmap_np = self._build_map(translate=self.decode_kernel)
            self._pmap_cache = jax.numpy.asarray(self._pmap_np)
        return self._pmap_cache

    def page_map_host(self) -> np.ndarray:
        """Host copy of :meth:`page_map` (same translation) — the Engine
        derives each step's write frame from it without a device sync."""
        self.page_map()
        return self._pmap_np

    def _build_map(self, translate: bool = False) -> np.ndarray:
        m = np.full((self.batch, self.pages_per_slot), self.scratch_id,
                    np.int32)
        for slot, sess in enumerate(self.slots):
            if sess is not None:
                self._fill_row(m, slot, sess, translate)
        return m

    def page_map_for(self, slot: int, sess: Session) -> np.ndarray:
        """Page map with a *pending* admission's pages already in ``slot``
        (the prefill gather runs before :meth:`bind`).  Untranslated: the
        prefill path gathers the raw pool, and an admission's own pages
        are always raw (fresh frames or raw prefix pages)."""
        m = self._build_map()
        self._fill_row(m, slot, sess, False)
        return m

    def _fill_row(self, m: np.ndarray, slot: int, sess: Session,
                  translate: bool = False) -> None:
        for pos, pid in enumerate(self.table.resident_pids(sess.uid)):
            assert pid is not None, \
                f"resident session {sess.uid} has a spilled page {pos}"
            if translate and pid in self._cframe_by_pid:
                m[slot, pos] = self.scratch_id + 1 + self._cframe_by_pid[pid][0]
            else:
                m[slot, pos] = pid

    # ------------------------------------------------------------------
    # per-page spill path (lazy: only on real pool pressure)
    def _evict_cb(self, uid: int, pos: int, pid: int):
        assert self.spill_runtime is not None, \
            "page eviction needs a spill tier " \
            "(PagedKVCacheManager(spill=None) cannot overcommit)"
        centry = self._cframe_by_pid.get(pid)
        if centry is not None:
            # the page's live bytes sit in the compressed side pool (the
            # raw frame is stale): stash the already-quantized payloads
            # as-is — no re-encode, and the recorded per-leaf scales /
            # dtypes ride along so a later resume round-trips exactly
            ci, codec_name, treedef, scales, dtypes = centry
            qleaves = jax.tree_util.tree_leaves(
                tfm.page_slice(self.cpool, ci))
            items = []
            for q, scale, dtype in zip(qleaves, scales, dtypes):
                payload = self.spill_runtime.stash(
                    q, TransferHints(dtype=q.dtype, batch_dim=0,
                                     allow_compress=False, name="kv_page"),
                    direction="kv_stash")
                items.append((payload, scale, dtype))
            return _SpilledPage(treedef, items, codec_name)
        page = tfm.page_slice(self.pool, pid)
        leaves, treedef = jax.tree_util.tree_flatten(page)
        codec_name = self._codec_by_uid.get(uid)
        codec = get_codec(codec_name) if codec_name else None
        interpret = jax.default_backend() != "tpu"
        items = []
        for x in leaves:
            dtype = x.dtype
            if codec is not None and codec.applies_to(x):
                q, scale = encode_tensor(codec, x, kernel=self.codec_kernel,
                                         interpret=interpret)
            else:
                q, scale = x, None
            payload = self.spill_runtime.stash(
                q, TransferHints(dtype=q.dtype, batch_dim=0,
                                 allow_compress=False, name="kv_page"),
                direction="kv_stash")
            items.append((payload, scale, dtype))
        return _SpilledPage(treedef, items, codec_name)

    def _unstash_page(self, entry: _SpilledPage):
        """Fetch + decode a spilled page, all-or-nothing: the stashed
        payloads are only discarded after EVERY leaf fetched and decoded,
        so a mid-tree failure leaves the payload intact and the caller
        can re-park the position for a later retry."""
        codec = get_codec(entry.codec) if entry.codec else None
        interpret = jax.default_backend() != "tpu"
        leaves = []
        for payload, scale, dtype in entry.items:
            q = self.spill_runtime.fetch(
                payload, TransferHints(dtype=dtype, batch_dim=0,
                                       allow_compress=False, name="kv_page"),
                direction="kv_fetch")
            if scale is not None:
                q = decode_tensor(codec, q, scale, dtype,
                                  kernel=self.codec_kernel,
                                  interpret=interpret)
            leaves.append(q)
        for payload, _, _ in entry.items:
            self._discard(payload)
        return jax.tree_util.tree_unflatten(entry.treedef, leaves)

    def _discard_page(self, entry: _SpilledPage) -> None:
        for payload, _, _ in entry.items:
            self._discard(payload)

    # ------------------------------------------------------------------
    # compressed residency (decode_kernel=True): a resumed cold page may
    # stay quantized in the int8 side pool and be dequanted inside the
    # paged-attention kernel instead of inflating into a raw frame
    def _compressible_resume(self, sess: Session, pos: int, parked,
                             entry: _SpilledPage) -> bool:
        """Eligibility for fused-decode residency.  The tail page (the
        one the next decode step writes into) must resume raw; shared
        (prefix) pages stay raw so COW forks always copy live pool
        bytes; only int8-payload codecs fit the side pool."""
        return (self.decode_kernel
                and entry.codec in ("int8", "blocksparse")
                and bool(self._cframe_free)
                and not isinstance(parked, SharedPayload)
                and pos < sess.length // self.page_size
                and all(s is not None for _, s, _ in entry.items))

    def _adopt_compressed(self, entry: _SpilledPage, pid: int) -> None:
        """Fetch a quantized page into side-pool frame ``ci`` verbatim
        (no decode) and record the pid -> ci mapping the page-map
        translation and a later re-evict both key off."""
        import jax.numpy as jnp
        ci = self._cframe_free[-1]          # popped only after all fetches
        qleaves, scales, dtypes = [], [], []
        for payload, scale, dtype in entry.items:
            q = self.spill_runtime.fetch(
                payload, TransferHints(dtype=dtype, batch_dim=0,
                                       allow_compress=False, name="kv_page"),
                direction="kv_fetch")
            qleaves.append(q)
            scales.append(scale)
            dtypes.append(dtype)
        for payload, _, _ in entry.items:
            self._discard(payload)
        self._cframe_free.pop()
        qpage = jax.tree_util.tree_unflatten(entry.treedef, qleaves)
        self.cpool = tfm.page_insert(self.cpool, qpage, ci)
        self.cscale = jax.tree.map(
            lambda s, sc: s.at[:, ci].set(
                jnp.asarray(sc, jnp.float32).reshape(())),
            self.cscale, jax.tree_util.tree_unflatten(entry.treedef, scales))
        self._cframe_by_pid[pid] = (ci, entry.codec, entry.treedef,
                                    scales, dtypes)
        self._cframe_adopts += 1
        self._pmap_cache = None

    # ------------------------------------------------------------------
    # pause / resume: pages go cold in place; slot-shaped leaves park whole
    def pause(self, sess: Session) -> None:
        assert sess.slot is not None, sess
        assert self.spill_runtime is not None, \
            "PagedKVCacheManager(spill=None) cannot preempt sessions"
        if self._has_slot_leaves:
            self._park_slot(self.slot_tree, sess)
        self.table.mark_cold(sess.uid)
        self._clear_slot(sess)
        self._pmap_cache = None

    def resume(self, sess: Session, slot: int) -> None:
        """Re-bind a paused session: surviving pages readmit copy-free,
        evicted ones are fetched (and decoded) into fresh frames.  A
        page evicted while *shared* carries one payload for all holders:
        the single fetch re-homes every holder onto the fresh frame."""
        uid = sess.uid
        self.table.mark_hot(uid)
        try:
            while True:
                spilled = self.table.spilled_positions(uid)
                if not spilled:
                    break
                pos = spilled[0]
                parked = self.table.entries(uid)[pos].payload
                inner = parked.payload \
                    if isinstance(parked, SharedPayload) else parked
                pid = self.table.set_resident(uid, pos, self._evict_cb)
                try:
                    if self._compressible_resume(sess, pos, parked, inner):
                        # fused-decode residency: the quantized payload
                        # lands in the compressed side pool as-is and the
                        # decode kernel dequants it per attention read —
                        # no inflate pass, no raw-pool frame bytes
                        self._adopt_compressed(inner, pid)
                        continue
                    page = self._unstash_page(inner)
                except Exception:
                    # the fetch failed AFTER the position went resident:
                    # roll it back to spilled over the (still intact)
                    # payload — leaving it resident would park garbage
                    # in the pool and a later resume would serve it
                    self.table.unset_resident(uid, pos, parked)
                    raise
                self.pool = tfm.page_insert(self.pool, page, pid)
        except Exception:
            # pool too hot to re-home every page: stay paused, pages
            # return to the eviction queue, the Engine retries later
            # (readmits are only counted by note_resumed on success)
            self.table.mark_cold(uid)
            raise
        if uid in self._spilled:
            one = self._unpark_slot(sess)
            self.slot_tree = self._slot_put(self.slot_tree, one, slot)
        self.table.note_resumed(uid)
        self.bind(slot, sess, sess.length)

    # ------------------------------------------------------------------
    # disaggregated adoption (decode role: take ownership of shipped pages)
    def adopt(self, slot: int, sess: Session, handoff, queue) -> None:
        """Install a transferred session into ``slot`` (cross-role handoff).

        Ownership passes whole: the decode table *claims* fresh frames
        (never aliasing an existing owner — the shipped pages become the
        only copy this role serves from), the transfer queue's payloads
        are fetched into them, slot-shaped leaves merge into the slot row,
        and the session binds at its prefill length.  A
        :class:`~repro.serve.paging.PageError` (pool too hot, nothing
        cold to evict) rolls the claim back BEFORE any page bytes are
        fetched — backpressure leaves the pages parked in the transfer
        tier, not re-prefilled."""
        uid = sess.uid
        self._sessions[uid] = sess
        self._codec_by_uid[uid] = self.codec_for(sess.tenant)
        try:
            pids = self.table.claim(uid, handoff.num_pages, self._evict_cb)
        except PageError:
            self._sessions.pop(uid, None)
            self._codec_by_uid.pop(uid, None)
            raise
        for pid, page in zip(pids, queue.fetch_pages(handoff)):
            self.pool = tfm.page_insert(self.pool, page, pid)
        slot_one = queue.fetch_slot_leaves(handoff)
        if slot_one is not None:
            self.slot_tree = self._slot_put(self.slot_tree, slot_one, slot)
        self.bind(slot, sess, handoff.length)

    def release(self, sess: Session) -> None:
        super().release(sess)          # slot + parked slot-shaped leaves
        self._pmap_cache = None
        for entry in self.table.free_session(sess.uid):
            self._discard_page(entry)
        self._sessions.pop(sess.uid, None)
        self._codec_by_uid.pop(sess.uid, None)

    def sweep_cancelled(self) -> None:
        super().sweep_cancelled()
        for uid in list(self.table.sessions()):
            sess = self._sessions.get(uid)
            if sess is not None and sess.done and sess.slot is None:
                self.release(sess)

    @property
    def caches(self):
        """Debug/legacy view: the contiguous cache tree gathered from the
        page pool at the current page map (a copy, not the storage).
        Compressed-resident frames are inflated into a pool *copy* first
        so the gather always reads live bytes (the storage itself stays
        quantized)."""
        import jax.numpy as jnp
        pool = self.pool
        for pid, (ci, codec_name, treedef, scales, dtypes) \
                in self._cframe_by_pid.items():
            codec = get_codec(codec_name)
            qleaves = jax.tree_util.tree_leaves(
                tfm.page_slice(self.cpool, ci))
            leaves = [decode_tensor(codec, q, s, d)
                      for q, s, d in zip(qleaves, scales, dtypes)]
            pool = tfm.page_insert(
                pool, jax.tree_util.tree_unflatten(treedef, leaves), pid)
        pm = jnp.asarray(self._build_map())
        return tfm.gather_pages(pool, self.slot_tree, pm)

    # ------------------------------------------------------------------
    # decode-io metering: what the attention read this step
    def note_decode(self, length: int, n_active: int) -> None:
        """Record one decode step for ``n_active`` sessions at ``length``
        rows.  In-place decode touches only the pages covering the rows
        the query can see (sliding window excluded); the gather path
        reads the whole ``batch x pages_per_slot`` view regardless."""
        lo = 0
        if self._decode_window > 0:
            lo = max(0, length - self._decode_window + 1) // self.page_size
        touched = self.table.pages_for(length + 1) - lo
        gather = self.batch * self.pages_per_slot
        self._decode_steps += 1
        self._decode_pages_touched += \
            touched * n_active if self.decode_kernel else gather
        self._decode_pages_gather += gather

    # ------------------------------------------------------------------
    def traffic_report(self) -> Dict[str, Any]:
        report = dict(super().traffic_report())
        report["pages"] = {
            "page_size": self.page_size,
            "num_pages": self.table.num_pages,
            "evictions": self.table.evictions,
            "refetches": self.table.refetches,
            "readmits_free": self.table.readmits_free,
            "adoptions": self.table.adoptions,
            "shared_binds": self.table.shared_binds,
        }
        report["decode_io"] = {
            "in_place": self.decode_kernel,
            "steps": self._decode_steps,
            "pages_touched": self._decode_pages_touched,
            "pages_gather_equiv": self._decode_pages_gather,
            "bytes_touched":
                self._decode_pages_touched * self._page_frame_bytes,
            "bytes_gather_equiv":
                self._decode_pages_gather * self._page_frame_bytes,
            "compressed_resident": len(self._cframe_by_pid),
            "compressed_adopts": self._cframe_adopts,
        }
        prompted = self.prefix_rows_prompted
        report["prefix"] = {
            "enabled": self.prefix_share,
            "hits": self.prefix_hits,
            "forks": self.prefix_forks,
            "rows_reused": self.prefix_rows_reused,
            "rows_prompted": prompted,
            "hit_rate": (self.prefix_rows_reused / prompted
                         if prompted else 0.0),
        }
        return report

    def describe(self) -> str:
        return (f"{super().describe()[:-1]} "
                f"{self.table.describe()}]")
