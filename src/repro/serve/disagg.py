"""Disaggregated prefill/decode over the memory-tier API (DESIGN.md §6).

The paper's pooled, device-side memory fabric lets accelerators
*specialize* while state moves between them transparently: prefill is
compute-bound (one big matmul-heavy pass per prompt), decode is
memory-bound (one cache-wide read per token), and a pooled memory system
lets each run on the mesh slice shaped for it.  This module is that split
for the serving stack:

* a **prefill-role Engine** runs prompt prefill in plain contiguous slots
  (no pool, no page table), samples the first token, chops the finished
  KV into page-shaped chunks (``models/transformer.slot_pages``) and
  publishes them;
* the :class:`TransferQueue` parks the pages in a shared *transfer tier*
  — a :class:`~repro.core.runtime.MemoryRuntime` over ``PooledHbm`` /
  ``SpillTier`` — with every leg metered (``kv_publish`` / ``kv_adopt``
  directions in ``traffic_report()``: wire bytes are exactly
  page-bytes x shipped pages);
* a **decode-role Engine** adopts the pages through its
  :class:`~repro.serve.paging.PageTable` (``claim``: fresh frames, never
  aliasing an existing owner) and continues decode — the token stream is
  bit-identical to the colocated paged engine's, which the cross-role
  trace-equivalence suite (tests/test_disagg.py) pins.

Backpressure is survivable by construction at both ends: the prefill
engine stops admitting prompts while the queue is at ``max_depth``
(prompts wait in the prefill scheduler), and a decode-side adoption that
finds every pool frame hot rolls back *before* fetching any bytes and
requeues the handoff at the BACK of the queue — the pages stay parked in
the transfer tier (never re-prefilled) and later handoffs get their turn
first (no starvation).  Within one session, pages always move in logical
position order (FIFO per session).

Quota reservations follow the session: prefill and decode engines share
one :class:`~repro.serve.quota.QuotaManager`, whose per-uid ledger keeps
the worst-case page charge alive while the KV is in flight and releases
it on the side that retires (or sweeps the cancellation of) the session.

This is the in-process ("loopback") realization — both roles in one
interpreter, which is what ``--role both`` serves and what the
equivalence suite drives.  The handoff unit (page-shaped arrays + a
pickleable header) is exactly what ``serve/transport.py`` serializes for
cross-host pairs — one TCP stream, N striped streams, or a same-host
shared-memory arena all carry this same unit, so everything above this
paragraph holds unchanged over the wire (the bit-identity suites in
tests/test_transport.py and tests/test_wire_scaleout.py pin it).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple, Union

import jax

from repro.configs.base import MemoryPlan
from repro.core.runtime import MemoryRuntime
from repro.core.tiers import TransferHints
from repro.serve.quota import QuotaManager, TenantQuota
from repro.serve.session import Session


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class KVHandoff:
    """One prefilled session in flight from the prefill to the decode role.

    ``page_payloads`` holds, per logical page position (ascending — the
    per-session FIFO order), the transfer tier's opaque payloads for that
    page's cache leaves; ``slot_payloads`` the slot-shaped leaves (SSM /
    cross-attention state) shipped whole.  Payloads are consumed (fetched
    and their tier budget discarded) exactly once, at adoption.
    """

    session: Session
    length: int                            # cached rows (== prompt length)
    #: per page: (treedef, leaf payloads, leaf dtypes)
    page_payloads: List[Tuple[Any, List[Any], List[Any]]] = \
        dataclasses.field(default_factory=list)
    slot_payloads: Optional[Tuple[Any, List[Any], List[Any]]] = None
    requeues: int = 0                      # decode-side backpressure count

    @property
    def uid(self) -> int:
        return self.session.uid

    @property
    def num_pages(self) -> int:
        return len(self.page_payloads)


# ---------------------------------------------------------------------------
class TransferQueue:
    """KV handoffs parked in a shared transfer tier, arrival-ordered.

    Ordering contract (pinned by the property suite):

    * **FIFO per session** — a session's pages are stashed, fetched and
      landed in logical position order; a handoff is delivered at most
      once (requeues re-deliver the same object, payloads intact).
    * **No starvation across sessions** — ``next_ready`` pops the head,
      ``requeue`` appends at the *back*: between two offers of the same
      backpressured handoff every other parked handoff is offered once.

    ``max_depth`` bounds the parked handoffs; the prefill engine checks
    :meth:`has_room` before admitting fresh prompts, so queue pressure
    propagates backwards into the prefill scheduler instead of growing
    the transfer tier without bound.
    """

    def __init__(self, runtime: MemoryRuntime,
                 max_depth: Optional[int] = None):
        if max_depth is not None and max_depth < 1:
            raise ValueError(f"max_depth must be >= 1: {max_depth}")
        self.runtime = runtime
        self.max_depth = max_depth
        self._parked: Deque[KVHandoff] = deque()
        # counters (cross-checked by the trace-equivalence suite)
        self.published = 0
        self.delivered = 0
        self.requeued = 0
        self.swept = 0
        self.shipped_pages = 0
        self.adopted_pages = 0

    # ------------------------------------------------------------------
    def depth(self) -> int:
        return len(self._parked)

    def has_room(self, pending: int = 0) -> bool:
        """Whether one more handoff fits under ``max_depth``.  ``pending``
        counts sessions already admitted to prefill slots but not yet
        published — the prefill engine passes it so a multi-slot admission
        burst cannot overshoot the bound (publish is unconditional)."""
        return self.max_depth is None or \
            len(self._parked) + pending < self.max_depth

    def parked_uids(self) -> Tuple[int, ...]:
        return tuple(h.uid for h in self._parked)

    # ------------------------------------------------------------------
    # prefill side
    def publish(self, handoff: KVHandoff, pages: List[Any],
                slot_one: Any = None) -> None:
        """Stash a prefilled session's KV into the transfer tier.

        ``pages``: page-shaped trees in logical position order (from
        :func:`repro.models.transformer.slot_pages`); ``slot_one``: the
        slot-shaped leaves, or None when the architecture has none.
        """
        assert not handoff.page_payloads, "handoff already published"
        for page in pages:
            leaves, treedef = jax.tree_util.tree_flatten(page)
            payloads, dtypes = [], []
            for x in leaves:
                payloads.append(self.runtime.stash(
                    x, TransferHints(dtype=x.dtype, batch_dim=0,
                                     allow_compress=False, name="kv_page"),
                    direction="kv_publish"))
                dtypes.append(x.dtype)
            handoff.page_payloads.append((treedef, payloads, dtypes))
        if slot_one is not None:
            leaves, treedef = jax.tree_util.tree_flatten(slot_one)
            payloads = [self.runtime.stash(
                x, TransferHints(dtype=x.dtype, batch_dim=1,
                                 allow_compress=False, name="kv_slot"),
                direction="kv_publish") for x in leaves]
            handoff.slot_payloads = (treedef, payloads,
                                     [x.dtype for x in leaves])
        self._parked.append(handoff)
        self.published += 1
        self.shipped_pages += handoff.num_pages

    # ------------------------------------------------------------------
    # decode side
    def next_ready(self) -> Optional[KVHandoff]:
        """Pop the oldest parked handoff (None when the queue is empty)."""
        if not self._parked:
            return None
        self.delivered += 1
        return self._parked.popleft()

    def requeue(self, handoff: KVHandoff) -> None:
        """Decode-side backpressure: park the handoff again, at the BACK —
        its pages stay in the transfer tier (they are never re-prefilled)
        and every other parked session gets its turn first."""
        handoff.requeues += 1
        self.requeued += 1
        self._parked.append(handoff)

    def fetch_pages(self, handoff: KVHandoff) -> List[Any]:
        """Materialize the handoff's pages, in logical position order,
        consuming the payloads (their transfer-tier budget is returned)."""
        pages = []
        for treedef, payloads, dtypes in handoff.page_payloads:
            leaves = []
            for payload, dt in zip(payloads, dtypes):
                leaves.append(self.runtime.fetch(
                    payload, TransferHints(dtype=dt, batch_dim=0,
                                           allow_compress=False,
                                           name="kv_page"),
                    direction="kv_adopt"))
                self.runtime.discard(payload)
            pages.append(jax.tree_util.tree_unflatten(treedef, leaves))
        self.adopted_pages += len(pages)
        handoff.page_payloads = []
        return pages

    def fetch_slot_leaves(self, handoff: KVHandoff) -> Any:
        if handoff.slot_payloads is None:
            return None
        treedef, payloads, dtypes = handoff.slot_payloads
        leaves = []
        for payload, dt in zip(payloads, dtypes):
            leaves.append(self.runtime.fetch(
                payload, TransferHints(dtype=dt, batch_dim=1,
                                       allow_compress=False, name="kv_slot"),
                direction="kv_adopt"))
            self.runtime.discard(payload)
        handoff.slot_payloads = None
        return jax.tree_util.tree_unflatten(treedef, leaves)

    # ------------------------------------------------------------------
    # lifecycle
    def discard(self, handoff: KVHandoff) -> None:
        """Drop an unconsumed handoff's payloads (cancelled in transit),
        returning their transfer-tier budget instead of leaking it."""
        for _, payloads, _ in handoff.page_payloads:
            for payload in payloads:
                self.runtime.discard(payload)
        handoff.page_payloads = []
        if handoff.slot_payloads is not None:
            for payload in handoff.slot_payloads[1]:
                self.runtime.discard(payload)
            handoff.slot_payloads = None

    def sweep_cancelled(self) -> List[Session]:
        """Drop parked handoffs whose session was cancelled in transit.
        Returns the swept sessions so the caller can release their quota
        reservations (both engines may sweep: release is idempotent)."""
        swept = []
        for handoff in [h for h in self._parked if h.session.done]:
            self._parked.remove(handoff)
            self.discard(handoff)
            self.swept += 1
            swept.append(handoff.session)
        return swept

    # ------------------------------------------------------------------
    def traffic_report(self) -> Dict[str, Any]:
        """Transfer-tier byte accounting (kv_publish / kv_adopt) plus the
        queue's own handoff counters."""
        report = dict(self.runtime.traffic_report())
        report["transfer"] = {
            "published": self.published,
            "delivered": self.delivered,
            "requeued": self.requeued,
            "swept": self.swept,
            "depth": self.depth(),
            "shipped_pages": self.shipped_pages,
            "adopted_pages": self.adopted_pages,
        }
        return report

    def describe(self) -> str:
        cap = "" if self.max_depth is None else f"/{self.max_depth}"
        return (f"transfer[{self.runtime.tier.describe()} "
                f"depth={self.depth()}{cap} shipped={self.shipped_pages}p "
                f"requeued={self.requeued}]")


# ---------------------------------------------------------------------------
class DisaggPair:
    """Two cooperating engines + the transfer queue, stepped in lockstep.

    The in-process loopback of the disaggregated deployment: ``submit``
    goes to the prefill engine, ``step`` advances prefill (admission +
    publish) then decode (adoption + decode), ``run`` drains everything —
    prompts waiting, pages in flight, and decode residents alike.
    """

    def __init__(self, prefill, decode, transfer: TransferQueue):
        if prefill.role != "prefill" or decode.role != "decode":
            raise ValueError(f"need (prefill, decode) roles, got "
                             f"({prefill.role!r}, {decode.role!r})")
        if prefill.transfer is not transfer or decode.transfer is not transfer:
            raise ValueError("both engines must share THIS transfer queue")
        if prefill._page_size != decode.cache.page_size:
            raise ValueError(
                f"page_size mismatch: prefill ships {prefill._page_size}-row "
                f"pages, decode pools {decode.cache.page_size}-row frames")
        if prefill.max_len != decode.max_len:
            raise ValueError(f"max_len mismatch: {prefill.max_len} vs "
                             f"{decode.max_len} (trace equivalence needs "
                             f"identical cache geometry)")
        if (prefill.quota is not None or decode.quota is not None) \
                and prefill.quota is not decode.quota:
            raise ValueError("prefill and decode must share one QuotaManager "
                             "(reservations follow the session)")
        self.prefill = prefill
        self.decode = decode
        self.transfer = transfer

    # ------------------------------------------------------------------
    def submit(self, req=None, on_token=None, session=None) -> Session:
        return self.prefill.submit(req, on_token=on_token, session=session)

    def step(self) -> int:
        """One lockstep round: prefill publishes, decode adopts + decodes.
        Returns shipped handoffs + resident decode sessions this round."""
        shipped = self.prefill.step()
        active = self.decode.step()
        return shipped + active

    def has_work(self) -> bool:
        return (self.prefill.scheduler.has_waiting()
                or bool(self.prefill.cache.running())
                or self.transfer.depth() > 0
                or self.decode.scheduler.has_waiting()
                or bool(self.decode.cache.running()))

    def run(self, max_steps: int = 10_000) -> List[Any]:
        """Drain the pair; returns finished Requests (prefill-side
        rejections/instant-finishes first, then decode completions)."""
        for _ in range(max_steps):
            self.step()
            if not self.has_work():
                break
        return self.prefill.finished + self.decode.finished

    # ------------------------------------------------------------------
    def traffic_report(self) -> Dict[str, Any]:
        return {"transfer": self.transfer.traffic_report(),
                "decode": self.decode.traffic_report(),
                "prefill": self.prefill.traffic_report()}

    def quota_report(self) -> Dict[str, Any]:
        return self.decode.quota_report()

    def describe(self) -> str:
        return (f"disagg[{self.prefill.describe()} -> "
                f"{self.transfer.describe()} -> {self.decode.describe()}]")


# ---------------------------------------------------------------------------
def build_disagg(model, params, *,
                 batch: Optional[int] = None,
                 max_len: Optional[int] = None,
                 page_size: int = 16,
                 pages: Optional[int] = None,
                 prefill_batch: int = 1,
                 transfer: Union[str, MemoryRuntime] = "spill",
                 max_depth: Optional[int] = None,
                 scheduler: Union[str, Any] = "fcfs",
                 decode_scheduler: Union[str, Any, None] = None,
                 spill: Union[str, Any, None] = "spill",
                 quota: Union[QuotaManager, TenantQuota,
                              Dict[str, TenantQuota], None] = None,
                 temperature: float = 0.0, seed: int = 0,
                 **cache_kwargs) -> DisaggPair:
    """Wire a loopback prefill/decode pair over one transfer tier.

    ``transfer`` names the tier policy backing the in-flight KV pages
    (``"spill"``: pooled HBM overflowing to host — the paper's pooled
    fabric; ``"host"``: PCIe-attached DRAM) or passes a ready
    :class:`MemoryRuntime`.  ``scheduler`` orders the prefill queue,
    ``decode_scheduler`` (default: same policy string, or fcfs for
    non-string schedulers) the decode side's resume queue.  A single
    shared :class:`QuotaManager` is built from ``quota`` so reservations
    follow sessions across the split.
    """
    from repro.serve.engine import Engine   # circular-at-import avoidance

    if isinstance(transfer, MemoryRuntime):
        runtime = transfer
    else:
        runtime = MemoryRuntime(
            model.plan,
            MemoryPlan(policy=transfer, placement=model.memory.placement),
            model.mesh, planner=model.planner)
    queue = TransferQueue(runtime, max_depth=max_depth)

    if quota is None or isinstance(quota, QuotaManager):
        shared_quota = quota
    elif isinstance(quota, TenantQuota):
        shared_quota = QuotaManager(default_quota=quota)
    else:
        shared_quota = QuotaManager(dict(quota))

    if decode_scheduler is None:
        decode_scheduler = scheduler if isinstance(scheduler, str) else "fcfs"

    # decode first: when sizes are auto-derived, the prefill side adopts
    # the decode side's (page-aligned) geometry — trace equivalence needs
    # the two roles to agree on cache rows per session
    # decode draws from a different PRNG stream: the two engines sample
    # independently, and at temperature>0 sharing `seed` would correlate
    # the prefill-sampled first token with the first decode draw
    decode = Engine(model, params, batch=batch, max_len=max_len,
                    temperature=temperature, seed=seed + 1,
                    scheduler=decode_scheduler, spill=spill,
                    page_size=page_size, pages=pages, quota=shared_quota,
                    role="decode", transfer=queue, **cache_kwargs)
    prefill = Engine(model, params, batch=prefill_batch,
                     max_len=decode.max_len,
                     temperature=temperature, seed=seed,
                     scheduler=scheduler, spill=None,
                     page_size=page_size, quota=shared_quota,
                     role="prefill", transfer=queue)
    return DisaggPair(prefill, decode, queue)
