"""Serving: Scheduler / KVCacheManager / Session behind the Engine facade,
over pooled (optionally paged) KV caches, colocated or disaggregated
across prefill/decode roles (DESIGN.md §6)."""
from repro.serve.cache_manager import (KVCacheManager,      # noqa: F401
                                       PagedKVCacheManager)
from repro.serve.disagg import (DisaggPair, KVHandoff,      # noqa: F401
                                TransferQueue, build_disagg)
from repro.serve.engine import Engine, Request              # noqa: F401
from repro.serve.paging import PageError, PageTable         # noqa: F401
from repro.serve.quota import (QuotaManager, TenantQuota,   # noqa: F401
                               parse_quota_spec, quota_from_cli)
from repro.serve.scheduler import (DeadlineScheduler,       # noqa: F401
                                   FairScheduler, FCFSScheduler,
                                   PriorityScheduler, Scheduler,
                                   SRPTScheduler, build_scheduler,
                                   register_scheduler)
from repro.serve.session import Session, SessionState       # noqa: F401
