"""Serving: batched engine over pooled KV caches."""
