"""Serving: Scheduler / KVCacheManager / Session behind the Engine facade,
over pooled KV caches (DESIGN.md §6)."""
from repro.serve.cache_manager import KVCacheManager        # noqa: F401
from repro.serve.engine import Engine, Request              # noqa: F401
from repro.serve.scheduler import (FairScheduler,           # noqa: F401
                                   FCFSScheduler, PriorityScheduler,
                                   Scheduler, build_scheduler,
                                   register_scheduler)
from repro.serve.session import Session, SessionState       # noqa: F401
