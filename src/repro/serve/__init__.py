"""Serving: Scheduler / KVCacheManager / Session behind the Engine facade,
over pooled (optionally paged) KV caches, colocated or disaggregated
across prefill/decode roles, clustered behind the Router over loopback or
byte-framed wire transports (DESIGN.md §6)."""
from repro.serve.cache_manager import (KVCacheManager,      # noqa: F401
                                       PagedKVCacheManager)
from repro.serve.disagg import (DisaggPair, KVHandoff,      # noqa: F401
                                TransferQueue, build_disagg)
from repro.serve.engine import Engine, Request              # noqa: F401
from repro.serve.paging import PageError, PageTable         # noqa: F401
from repro.serve.quota import (QuotaManager, TenantQuota,   # noqa: F401
                               parse_quota_spec, quota_from_cli)
from repro.serve.scheduler import (DeadlineScheduler,       # noqa: F401
                                   FairScheduler, FCFSScheduler,
                                   PriorityScheduler, Scheduler,
                                   SRPTScheduler, build_scheduler,
                                   register_scheduler)
from repro.serve.session import Session, SessionState       # noqa: F401
# transport/router import the engine layer above; order matters here
from repro.serve.transport import (Channel,                 # noqa: F401
                                   InMemoryChannel, TcpChannel,
                                   TransportError, WireFormatError,
                                   WirePair, WirePrefill, WireReceiver,
                                   WireSender, build_transport,
                                   build_wire_pair, build_wire_prefill,
                                   register_transport, run_decode_worker)
from repro.serve.router import (EngineView,                 # noqa: F401
                                PlacementPolicy, Router, RouterEngine,
                                build_placement, build_router,
                                register_placement, replay_trace)
