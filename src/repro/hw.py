"""Hardware constants.

Two hardware models coexist in this repo:

* :data:`TPU_V5E` — the TARGET hardware for the JAX/Pallas implementation.
  All roofline terms in EXPERIMENTS.md §Roofline are computed against these
  numbers (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI, 16 GB HBM).

* :data:`PAPER_DEVICE` / :data:`PAPER_MEMNODE` — the paper's Table II
  configuration (1024 PEs x 125 MACs @ 1 GHz = 256 TFLOP/s, 900 GB/s HBM,
  N=6 links x 25 GB/s).  The ``sim/`` package reproduces the paper's
  evaluation against these numbers, so the faithful-reproduction figures are
  comparable with the paper's own.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Chip:
    """One accelerator chip (device-node in the paper's vocabulary)."""

    name: str
    peak_flops: float          # FLOP/s (bf16 MXU for TPU, MAC*2 for paper dev)
    hbm_bw: float              # bytes/s local memory bandwidth
    hbm_bytes: float           # local memory capacity
    num_links: int             # device-side interconnect links (N)
    link_bw: float             # bytes/s per link, per direction (B)
    mem_latency_s: float = 1e-7


@dataclasses.dataclass(frozen=True)
class MemNode:
    """The paper's capacity-optimized memory-node (Fig. 6)."""

    mem_bw: float              # bytes/s of the DIMM array
    capacity_bytes: float
    num_links: int
    link_bw: float


GB = 1e9
TB = 1e12

TPU_V5E = Chip(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    hbm_bytes=16 * GB,
    num_links=4,               # v5e: 4 ICI links per chip (2D torus)
    link_bw=50e9,
)

# Paper Table II device-node: 1024 PEs x 125 MACs x 1 GHz -> 128 TMAC/s
# = 256 TFLOP/s (1 MAC = 2 FLOPs); 900 GB/s HBM; N=6 links x 25 GB/s.
PAPER_DEVICE = Chip(
    name="paper-device",
    peak_flops=1024 * 125 * 1e9 * 2.0,
    hbm_bw=900e9,
    hbm_bytes=16 * GB,
    num_links=6,
    link_bw=25e9,
)

# Paper Table II memory-node: 256 GB/s DIMM bandwidth; 10 DIMMs/node;
# capacity 80 GB (8 GB RDIMM) .. 1.3 TB (128 GB LRDIMM).
PAPER_MEMNODE = MemNode(
    mem_bw=256e9,
    capacity_bytes=1.3 * TB,
    num_links=6,
    link_bw=25e9,
)

# inter-pod data-center network: the pod-axis pipeline's per-stage hop
# (parallel/pipeline.py ppermute).  Per-transfer latency matters for the
# bubble-vs-stall planner: many small microbatches pay it per transfer.
DCN_BW = 25e9                  # bytes/s per device across pods
DCN_LATENCY_S = 5e-6           # per-transfer latency of one DCN hop

PCIE_GEN3_BW = 16e9            # x16 per direction (DC-DLA host link)
PCIE_GEN4_BW = 32e9            # sensitivity study (paper §V-B)

# DGX-1-style PCIe tree: 4 GPUs share one CPU socket's root complex
# (~2 x16 uplinks worth).  Paper §I: per-device host bandwidth divides by
# the number of intra-node devices streaming concurrently.
PCIE_ROOT_PER_SOCKET = 32e9
DEVICES_PER_HOST = 8           # intra-node devices sharing the host links

# host DRAM visible to one device's virtualization (DC-DLA backing store)
HOST_DRAM_BYTES = 512 * GB

# host CPU socket memory bandwidth (paper §II-C): Xeon 80 GB/s, Power9 120;
# the hypothetical HC-DLA CPU is overprovisioned to 300 GB/s (paper §IV).
XEON_SOCKET_BW = 80e9
HCDLA_SOCKET_BW = 300e9

BYTES_BF16 = 2
BYTES_FP32 = 4
BYTES_FP8 = 1
