# model facade re-exported lazily to keep submodule imports light
def __getattr__(name):
    if name in ("Model", "build_model"):
        from repro.models import model as _m
        return getattr(_m, name)
    raise AttributeError(name)
