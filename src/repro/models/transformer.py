"""Layer stacks for all 10 assigned architectures.

One scan-over-layers code path serves every family.  Each architecture is
described by its *group*: the repeating unit the scan iterates over.

  dense LM            group = ("dense",)                x L
  mixtral             group = ("moe",)                  x L
  llama4 (moe_every=2) group = ("dense","moe")          x L/2
  mamba2              group = ("ssm",)                  x L
  zamba2              group = ("ssm",)*6 + ("shared",)  x L/6   (shared-weight
                      attention block: params unstacked, one copy reused)
  whisper             encoder stack + decoder stack (self + cross attention)

Training wraps every sub-layer in the vDNN offload unit (core.offload): the
layer input is the stash unit, intermediates are recomputed — paper §III-B +
footnote 4.  Serving runs the raw sub-layers against (possibly pooled) KV /
SSM caches.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import frontends, moe as moe_mod, ssm as ssm_mod
from repro.models.attention import (attn_init, attn_specs, attention_block,
                                    cross_attention_block, encode_cross_kv,
                                    init_kv_cache)
from repro.models.layers import (ModelContext, activation_fn, apply_norm,
                                 dense_init, embed_init, norm_init,
                                 sinusoidal_pos)

Params = Dict[str, Any]

# Full-unroll switch for the dry-run FLOPs probes: XLA's cost_analysis
# counts while-loop bodies ONCE (not x trip count), so the roofline probes
# lower small unrolled stacks and extrapolate (launch/dryrun.py).
SCAN_UNROLL = False


def _unroll():
    return True if SCAN_UNROLL else 1


# ---------------------------------------------------------------------------
def arch_group(cfg: ModelConfig) -> Tuple[Tuple[str, ...], int]:
    """(group kinds, n_groups)."""
    if cfg.is_hybrid:
        k = cfg.hybrid_attn_every
        assert cfg.num_layers % k == 0
        return ("ssm",) * k + ("shared",), cfg.num_layers // k
    if cfg.is_ssm:
        return ("ssm",), cfg.num_layers
    if cfg.is_moe:
        if cfg.moe_every > 1:
            assert cfg.num_layers % cfg.moe_every == 0
            return ("dense",) * (cfg.moe_every - 1) + ("moe",), \
                cfg.num_layers // cfg.moe_every
        return ("moe",), cfg.num_layers
    return ("dense",), cfg.num_layers


# ---------------------------------------------------------------------------
# MLP
def mlp_init(key, cfg: ModelConfig, dtype) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    gated = cfg.act == "silu"
    ks = jax.random.split(key, 3)
    p = {"w1": dense_init(ks[0], D, F, dtype),
         "w2": dense_init(ks[1], F, D, dtype)}
    if gated:
        p["w3"] = dense_init(ks[2], D, F, dtype)
    return p


def mlp_specs(cfg: ModelConfig, planner) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    fs, tp = planner.axes.fsdp, planner.axes.tensor
    s = {"w1": planner.spec((D, F), [fs, tp], "w1"),
         "w2": planner.spec((F, D), [tp, fs], "w2")}
    if cfg.act == "silu":
        s["w3"] = planner.spec((D, F), [fs, tp], "w3")
    return s


def mlp_block(params: dict, ctx: ModelContext, x: jax.Array) -> jax.Array:
    act = activation_fn(ctx.cfg.act)
    h = jnp.einsum("bsd,df->bsf", x, params["w1"])
    h = ctx.act(h, "batch", None, "tensor")
    h = act(h)
    if "w3" in params:
        h = h * jnp.einsum("bsd,df->bsf", x, params["w3"])
    return jnp.einsum("bsf,fd->bsd", h, params["w2"])


# ---------------------------------------------------------------------------
# sub-layer init / specs
def sublayer_init(key, cfg: ModelConfig, dtype, kind: str) -> dict:
    ks = jax.random.split(key, 4)
    if kind == "ssm":
        return {"ln1": norm_init(cfg, cfg.d_model),
                "ssm": ssm_mod.mamba_init(ks[0], cfg, dtype)}
    if kind == "dec":    # whisper decoder layer (self + cross + mlp)
        return {"ln1": norm_init(cfg, cfg.d_model),
                "attn": attn_init(ks[0], cfg, dtype),
                "ln_x": norm_init(cfg, cfg.d_model),
                "cross": attn_init(ks[1], cfg, dtype),
                "ln2": norm_init(cfg, cfg.d_model),
                "mlp": mlp_init(ks[2], cfg, dtype)}
    p = {"ln1": norm_init(cfg, cfg.d_model),
         "attn": attn_init(ks[0], cfg, dtype)}
    if kind == "moe":
        p["ln2"] = norm_init(cfg, cfg.d_model)
        p["moe"] = moe_mod.moe_init(ks[1], cfg, dtype)
    else:                # dense / shared / enc
        if not cfg.parallel_block:
            p["ln2"] = norm_init(cfg, cfg.d_model)
        p["mlp"] = mlp_init(ks[1], cfg, dtype)
    return p


def sublayer_specs(cfg: ModelConfig, planner, kind: str) -> dict:
    def nspec(_):
        return {"scale": P()} if cfg.norm == "rmsnorm" else \
            {"scale": P(), "bias": P()}
    if kind == "ssm":
        return {"ln1": nspec(0), "ssm": ssm_mod.mamba_specs(cfg, planner)}
    if kind == "dec":
        return {"ln1": nspec(0), "attn": attn_specs(cfg, planner),
                "ln_x": nspec(0), "cross": attn_specs(cfg, planner),
                "ln2": nspec(0), "mlp": mlp_specs(cfg, planner)}
    s = {"ln1": nspec(0), "attn": attn_specs(cfg, planner)}
    if kind == "moe":
        s["ln2"] = nspec(0)
        s["moe"] = moe_mod.moe_specs(cfg, planner)
    else:
        if not cfg.parallel_block:
            s["ln2"] = nspec(0)
        s["mlp"] = mlp_specs(cfg, planner)
    return s


# ---------------------------------------------------------------------------
# sub-layer forward (train path; cache handled in serve path below)
def run_sublayer(kind: str, params: dict, ctx: ModelContext, x: jax.Array,
                 positions: jax.Array, enc_out: Optional[jax.Array] = None,
                 cache: Optional[dict] = None,
                 cache_index: Optional[jax.Array] = None,
                 causal: bool = True, use_rope: bool = True,
                 prefix_attend: bool = False,
                 paged: Optional[dict] = None
                 ) -> Tuple[jax.Array, jax.Array, Optional[dict]]:
    """Returns (x_out, aux_loss, new_cache)."""
    cfg = ctx.cfg
    zero = jnp.zeros((), jnp.float32)
    if kind == "ssm":
        h = apply_norm(cfg, params["ln1"], x)
        y, new_cache = ssm_mod.mamba_block(params["ssm"], ctx, h, cache)
        if ctx.mode == "train":
            y = ctx.resid(y)
        return x + y, zero, new_cache
    if kind == "dec":
        h = apply_norm(cfg, params["ln1"], x)
        a, new_cache = attention_block(
            params["attn"], ctx, h, positions, causal=True, cache=cache,
            cache_index=cache_index, use_rope=False)
        x = x + a
        h = apply_norm(cfg, params["ln_x"], x)
        if cache is not None and "ck" in cache:
            kv = {"k": cache["ck"], "v": cache["cv"]}
        else:
            kv = encode_cross_kv(params["cross"], cfg, enc_out)
        c = cross_attention_block(params["cross"], ctx, h, enc_kv=kv)
        x = x + c
        h = apply_norm(cfg, params["ln2"], x)
        x = x + mlp_block(params["mlp"], ctx, h)
        if new_cache is not None:
            new_cache = dict(new_cache, ck=kv["k"], cv=kv["v"])
        return x, zero, new_cache
    # dense / moe / shared / enc
    sp = ctx.resid if ctx.mode == "train" else (lambda t: t)
    h = apply_norm(cfg, params["ln1"], x)
    a, new_cache = attention_block(
        params["attn"], ctx, h, positions, causal=causal, cache=cache,
        cache_index=cache_index, use_rope=use_rope,
        prefix_attend=prefix_attend, paged=paged)
    # constrain TP-contraction outputs to the sequence-parallel layout at
    # the point of production: GSPMD then emits reduce-scatter (+ the
    # all-gather already inside the next layer's projections) instead of a
    # full all-reduce — half the wire bytes per sub-layer (§Perf).
    a = sp(a)
    if kind == "moe":
        x = x + a
        h = apply_norm(cfg, params["ln2"], x)
        m, aux = moe_mod.moe_block(params["moe"], ctx, h)
        return x + sp(m), aux, new_cache
    if cfg.parallel_block and kind in ("dense", "shared"):
        m = sp(mlp_block(params["mlp"], ctx, h))  # same ln1 input (cohere)
        return x + a + m, zero, new_cache
    x = x + a
    h = apply_norm(cfg, params["ln2"], x)
    x = x + sp(mlp_block(params["mlp"], ctx, h))
    return x, zero, new_cache


# ---------------------------------------------------------------------------
# parameter tree
def init_params(key, cfg: ModelConfig, dtype) -> Params:
    group, n_groups = arch_group(cfg)
    ks = jax.random.split(key, 8)
    p: Params = {"embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model,
                                     dtype),
                 "final_norm": norm_init(cfg, cfg.d_model)}
    if not cfg.tie_embeddings:
        p["unembed"] = embed_init(ks[1], cfg.padded_vocab, cfg.d_model, dtype)
    if cfg.frontend != "none":
        p["frontend"] = frontends.frontend_init(ks[2], cfg, dtype)

    def stack_init(subkey, kind, n):
        keys = jax.random.split(subkey, n)
        return jax.vmap(lambda k: sublayer_init(k, cfg, dtype, kind))(keys)

    groups: Params = {}
    gk = jax.random.split(ks[3], len(group))
    for j, kind in enumerate(group):
        if kind == "shared":
            continue
        groups[f"sub_{j}"] = stack_init(gk[j], kind, n_groups)
    p["groups"] = groups
    if "shared" in group:
        p["shared"] = sublayer_init(ks[4], cfg, dtype, "shared")
    if cfg.is_encoder_decoder:
        enc_keys = jax.random.split(ks[5], cfg.encoder_layers)
        p["encoder"] = {
            "layers": jax.vmap(
                lambda k: sublayer_init(k, cfg, dtype, "enc"))(enc_keys),
            "final_norm": norm_init(cfg, cfg.d_model),
        }
        # decoder layers are the scanned groups but of kind "dec"
        p["groups"] = {"sub_0": stack_init(gk[0], "dec", cfg.num_layers)}
    return p


def param_specs(cfg: ModelConfig, planner) -> Params:
    group, n_groups = arch_group(cfg)
    fs, tp = planner.axes.fsdp, planner.axes.tensor
    V, D = cfg.padded_vocab, cfg.d_model
    nspec = {"scale": P()} if cfg.norm == "rmsnorm" else \
        {"scale": P(), "bias": P()}
    s: Params = {"embed": planner.spec((V, D), [tp, fs], "embed"),
                 "final_norm": dict(nspec)}
    if not cfg.tie_embeddings:
        s["unembed"] = planner.spec((V, D), [tp, fs], "unembed")
    if cfg.frontend != "none":
        s["frontend"] = frontends.frontend_specs(cfg, planner)

    def stacked(spec_tree):
        return jax.tree.map(lambda sp: P(*((None,) + tuple(sp))), spec_tree,
                            is_leaf=lambda v: isinstance(v, P))

    groups: Params = {}
    for j, kind in enumerate(group):
        if kind == "shared":
            continue
        groups[f"sub_{j}"] = stacked(sublayer_specs(cfg, planner, kind))
    s["groups"] = groups
    if "shared" in group:
        s["shared"] = sublayer_specs(cfg, planner, "shared")
    if cfg.is_encoder_decoder:
        s["encoder"] = {
            "layers": stacked(sublayer_specs(cfg, planner, "enc")),
            "final_norm": dict(nspec),
        }
        s["groups"] = {"sub_0": stacked(sublayer_specs(cfg, planner, "dec"))}
    return s


# ---------------------------------------------------------------------------
# embedding / head
def embed_tokens(params: Params, ctx: ModelContext, tokens: jax.Array,
                 frames: Optional[jax.Array] = None,
                 patches: Optional[jax.Array] = None) -> jax.Array:
    cfg = ctx.cfg
    x = params["embed"][tokens]
    if cfg.frontend == "vision_stub" and patches is not None:
        x = frontends.merge_patches(params["frontend"], cfg, x, patches)
    if cfg.is_encoder_decoder:
        x = x + sinusoidal_pos(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    if ctx.mode == "train":
        return ctx.resid(x)
    return ctx.act(x, "batch", None, None)


def unembed(params: Params, ctx: ModelContext, h: jax.Array) -> jax.Array:
    table = params["embed"] if ctx.cfg.tie_embeddings else params["unembed"]
    return jnp.einsum("bsd,vd->bsv", h, table)


# ---------------------------------------------------------------------------
# encoder (whisper)
def encode(params: Params, ctx: ModelContext, frames: jax.Array) -> jax.Array:
    cfg = ctx.cfg
    x = frontends.embed_frames(params["frontend"], cfg, frames)
    x = ctx.act(x, "batch", None, None)
    enc = params["encoder"]
    wrapped = ctx.wrap("enc_layer", functools.partial(_enc_layer, ctx))

    def body(carry, lp):
        return wrapped(lp, carry, jnp.zeros((), jnp.int32)), None

    x, _ = jax.lax.scan(body, x, enc["layers"], unroll=_unroll())
    return apply_norm(cfg, enc["final_norm"], x)


def _enc_layer(ctx, lp, x, _pos):
    y, _, _ = run_sublayer("enc", lp, ctx, x,
                           positions=jnp.zeros((x.shape[0], x.shape[1]),
                                               jnp.int32),
                           causal=False, use_rope=False)
    return y


# ---------------------------------------------------------------------------
# train forward
def forward_train(params: Params, ctx: ModelContext, tokens: jax.Array,
                  positions: jax.Array,
                  frames: Optional[jax.Array] = None,
                  patches: Optional[jax.Array] = None,
                  stash_groups: Optional[int] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Returns (hidden (B,S,D), aux_loss)."""
    cfg = ctx.cfg
    group, n_groups = arch_group(cfg)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = encode(params, ctx, frames)
        group = ("dec",)

    x = embed_tokens(params, ctx, tokens, frames, patches)
    use_rope = not cfg.is_encoder_decoder

    wrapped = {k: ctx.wrap(f"{k}_layer",
                           functools.partial(_train_sublayer, ctx, k,
                                             use_rope))
               for k in set(group)}

    def make_body(wrap: bool):
        def body(carry, gp):
            x, aux = carry
            for j, kind in enumerate(group):
                p = params["shared"] if kind == "shared" else gp[f"sub_{j}"]
                fn = wrapped[kind] if wrap else \
                    functools.partial(_train_sublayer, ctx, kind, use_rope)
                if cfg.is_encoder_decoder:
                    y, a = fn(p, x, positions, enc_out)
                else:
                    y, a = fn(p, x, positions)
                x, aux = ctx.resid(y), aux + a
            return (x, aux), None
        return body

    stacked = params["groups"]
    if stash_groups is None:
        stash_groups = n_groups
    g1 = max(0, min(n_groups, stash_groups))
    aux = jnp.zeros((), jnp.float32)
    if g1 > 0:
        p1 = jax.tree.map(lambda l: l[:g1], stacked)
        (x, aux), _ = jax.lax.scan(make_body(True), (x, aux), p1,
                                   unroll=_unroll())
    if g1 < n_groups:
        p2 = jax.tree.map(lambda l: l[g1:], stacked)
        (x, aux), _ = jax.lax.scan(make_body(False), (x, aux), p2,
                                   unroll=_unroll())
    x = apply_norm(cfg, params["final_norm"], x)
    return x, aux


def _train_sublayer(ctx, kind, use_rope, p, x, positions, enc_out=None):
    y, aux, _ = run_sublayer(kind, p, ctx, x, positions, enc_out=enc_out,
                             use_rope=use_rope)
    return y, aux


# ---------------------------------------------------------------------------
# pipelined train forward: the scanned decoder stack split into contiguous
# stages over a dedicated pipe mesh axis (parallel/pipeline.py schedules).
def forward_train_pipelined(params: Params, ctx: ModelContext,
                            tokens: jax.Array, positions: jax.Array,
                            pipeline, pipe_mesh,
                            stage_runtime=None
                            ) -> Tuple[jax.Array, jax.Array]:
    """Returns (hidden (B,S,D), aux_loss).

    Each pipe-mesh member owns ``n_groups / n_stages`` contiguous layer
    groups; embed / final-norm / CE run replicated on every member (the
    pipeline output is broadcast).  The stage's saved activations are
    placed by the schedule: 1F1B routes them through ``stage_runtime``'s
    :class:`~repro.core.tiers.PipelineStageTier` (metered as
    ``act_stash``/``act_fetch``), GPipe keeps them implicitly live.  MoE
    aux losses are computed per microbatch (like gradient accumulation).
    """
    from repro.parallel.pipeline import get_schedule, make_pipelined

    cfg = ctx.cfg
    group, n_groups = arch_group(cfg)
    if cfg.is_encoder_decoder or cfg.frontend != "none" or \
            cfg.mrope_sections:
        raise ValueError("pipeline schedules support decoder-only stacks "
                         f"with batch-leading positions (got {cfg.name})")
    S = pipeline.n_stages or (pipe_mesh.shape[pipeline.axis_name]
                              if pipe_mesh is not None else 1)
    if n_groups % max(S, 1) != 0:
        raise ValueError(f"{n_groups} layer groups do not split into "
                         f"{S} stages")
    M = max(1, pipeline.n_micro)
    B = tokens.shape[0]
    if B % M != 0:
        raise ValueError(f"batch {B} not divisible by n_micro {M}")

    x = embed_tokens(params, ctx, tokens)

    def stage_fn(gp, tree):
        h, pos = tree["h"], tree["positions"]

        def body(carry, g1):
            h, aux = carry
            for j, kind in enumerate(group):
                p = params["shared"] if kind == "shared" else g1[f"sub_{j}"]
                y, a = _train_sublayer(ctx, kind, True, p, h, pos)
                # spread the scalar aux over the GLOBAL batch rows so it
                # rides the pipeline as ordinary activation data and the
                # final sum is the microbatch MEAN (matching grad-accum
                # semantics; a load-balance aux is batch-size-invariant,
                # so summing raw per-microbatch auxes would inflate it M x)
                h, aux = y, aux + a / B
            return (h, aux), None

        (h, aux), _ = jax.lax.scan(body, (h, tree["aux"]), gp,
                                   unroll=_unroll())
        return {"h": h, "positions": pos, "aux": aux}

    tree = {"h": x, "positions": positions,
            "aux": jnp.zeros((B,), jnp.float32)}
    schedule = get_schedule(pipeline.schedule, runtime=stage_runtime)
    if S <= 1 or pipe_mesh is None:
        out = schedule.run_local(stage_fn, params["groups"], tree, M)
    else:
        stage_params = jax.tree.map(
            lambda l: l.reshape((S, n_groups // S) + l.shape[1:]),
            params["groups"])
        pipe = make_pipelined(pipe_mesh, stage_fn, n_micro=M,
                              axis_name=pipeline.axis_name,
                              schedule=schedule)
        out = pipe(stage_params, tree)
    h = apply_norm(cfg, params["final_norm"], out["h"])
    return h, jnp.sum(out["aux"])


# ---------------------------------------------------------------------------
# serve forward (prefill S>1 / decode S==1) against stacked caches
def forward_serve(params: Params, ctx: ModelContext, tokens: jax.Array,
                  positions: jax.Array, caches: Params,
                  cache_index: jax.Array,
                  frames: Optional[jax.Array] = None,
                  patches: Optional[jax.Array] = None,
                  enc_out: Optional[jax.Array] = None,
                  prefix_attend: bool = False,
                  paged: Optional[dict] = None
                  ) -> Tuple[jax.Array, Params]:
    """``prefix_attend=True`` (static) runs the prefix-sharing *suffix*
    prefill: the S>1 tokens are the prompt's tail, written into the cache
    at ``cache_index`` with attention over the cache rows (the grafted
    shared-prefix pages included) instead of only the in-flight tokens —
    see attention.prefix_prefill_attention."""
    cfg = ctx.cfg
    group, n_groups = arch_group(cfg)
    if cfg.is_encoder_decoder:
        group = ("dec",)
        if enc_out is None and frames is not None:
            enc_out = encode_infer(params, ctx, frames)

    x = embed_tokens(params, ctx, tokens, frames, patches)
    if cfg.is_encoder_decoder and tokens.shape[1] == 1:
        # decode: positional encoding at the current index
        x = (params["embed"][tokens] +
             sinusoidal_pos(1, cfg.d_model, offset=cache_index
                            ).astype(x.dtype)[None])
        x = ctx.act(x, "batch", None, None)
    use_rope = not cfg.is_encoder_decoder

    def body(x, xs):
        gp, cache_g = xs
        new_g = {}
        for j, kind in enumerate(group):
            p = params["shared"] if kind == "shared" else gp[f"sub_{j}"]
            c = cache_g.get(f"sub_{j}")
            x, _, nc = run_sublayer(kind, p, ctx, x, positions,
                                    enc_out=enc_out, cache=c,
                                    cache_index=cache_index,
                                    use_rope=use_rope,
                                    prefix_attend=prefix_attend,
                                    paged=paged)
            if nc is not None:
                new_g[f"sub_{j}"] = nc
        return x, new_g

    x, new_caches = jax.lax.scan(body, x, (params["groups"], caches),
                                 unroll=_unroll())
    x = apply_norm(cfg, params["final_norm"], x)
    return x, new_caches


def encode_infer(params: Params, ctx: ModelContext, frames: jax.Array
                 ) -> jax.Array:
    cfg = ctx.cfg
    x = frontends.embed_frames(params["frontend"], cfg, frames)
    x = ctx.act(x, "batch", None, None)
    enc = params["encoder"]

    def body(carry, lp):
        y = _enc_layer(ctx, lp, carry, None)
        return y, None

    x, _ = jax.lax.scan(body, x, enc["layers"], unroll=_unroll())
    return apply_norm(cfg, enc["final_norm"], x)


# ---------------------------------------------------------------------------
# caches
def init_caches(cfg: ModelConfig, batch: int, seq: int, dtype) -> Params:
    """Stacked (n_groups, ...) caches matching forward_serve's scan."""
    group, n_groups = arch_group(cfg)
    if cfg.is_encoder_decoder:
        group = ("dec",)

    def one(kind):
        if kind == "ssm":
            return ssm_mod.init_ssm_cache(cfg, batch, dtype)
        c = init_kv_cache(cfg, batch, seq, dtype)
        if kind == "dec":
            K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
            c["ck"] = jnp.zeros((batch, cfg.frontend_tokens, K, hd), dtype)
            c["cv"] = jnp.zeros((batch, cfg.frontend_tokens, K, hd), dtype)
        return c

    def stack(tree):
        return jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (n_groups,) + l.shape), tree)

    return {f"sub_{j}": stack(one(kind))
            for j, kind in enumerate(group) if kind != "none"}


def cache_specs(cfg: ModelConfig, planner, batch: int, seq: int) -> Params:
    """Pooled-KV sharding for serve caches: batch over data, sequence over
    'model' (the paper's technique applied to inference: the KV cache lives
    striped across the pooled HBM)."""
    group, n_groups = arch_group(cfg)
    if cfg.is_encoder_decoder:
        group = ("dec",)
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    b, tp = planner.axes.batch, planner.axes.tensor

    def one(kind):
        if kind == "ssm":
            conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
            return {
                "conv": planner.spec(
                    (n_groups, batch, cfg.ssm_conv_width - 1, conv_dim),
                    [None, b, None, tp], "conv_cache"),
                "ssm": planner.spec(
                    (n_groups, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                     cfg.ssm_state), [None, b, tp, None, None], "ssm_cache"),
            }
        kv = planner.spec((n_groups, batch, seq, K, hd),
                          [None, b, tp, None, None], "kv_cache")
        c = {"k": kv, "v": kv}
        if kind == "dec":
            ckv = planner.spec((n_groups, batch, cfg.frontend_tokens, K, hd),
                               [None, b, None, None, None], "cross_cache")
            c["ck"] = ckv
            c["cv"] = ckv
        return c

    return {f"sub_{j}": one(kind)
            for j, kind in enumerate(group) if kind != "none"}


def slot_cache(caches: Params, slot) -> Params:
    """Extract one batch slot of the stacked caches (batch dim kept at 1).

    Cache leaves are (n_groups, B, ...): batch is dim 1.  ``slot`` may be a
    Python int or a traced scalar — the serving KVCacheManager jits this for
    per-slot prefill and cold-slot spill."""
    return jax.tree.map(
        lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1), caches)


def merge_slot_cache(caches: Params, one_cache: Params, slot) -> Params:
    """Insert a single-slot cache (from :func:`slot_cache` or a spill-tier
    fetch) back into batch position ``slot`` of the stacked caches."""
    return jax.tree.map(
        lambda c, n: jax.lax.dynamic_update_slice_in_dim(
            c, n.astype(c.dtype), slot, axis=1),
        caches, one_cache)


# ---------------------------------------------------------------------------
# paged KV: the contiguous per-slot sequence axis becomes a pool of
# fixed-size pages — the paper's unit of pool placement applied to serving.
# Self-attention K/V leaves (batch dim 1, sequence dim 2) are paged; SSM
# state and cross-attention caches have no growing sequence axis and stay
# slot-shaped (one row per decode slot, spilled whole on preemption).
PAGED_KEYS = ("k", "v")


def split_paged(caches: Params) -> Tuple[Params, Params]:
    """Split a stacked cache tree into (paged kv leaves, slot-shaped rest).

    Both halves keep the ``sub_j`` group structure so
    :func:`gather_pages` can zip them back into the exact tree
    ``forward_serve`` scans over."""
    paged = {g: {k: v for k, v in sub.items() if k in PAGED_KEYS}
             for g, sub in caches.items()}
    rest = {g: {k: v for k, v in sub.items() if k not in PAGED_KEYS}
            for g, sub in caches.items()}
    return paged, rest


def paged_pool(caches: Params, page_size: int) -> Tuple[Params, Params]:
    """Rebuild the kv leaves of a freshly-initialised stacked cache as a
    page pool.

    Each (n_groups, B, S, K, hd) kv leaf becomes
    (n_groups, B*S/page_size + 1, page_size, K, hd): ``B * pages_per_slot``
    real pages plus ONE trailing scratch page (id ``num_pages``) that
    absorbs writes routed away by the slot mask — unowned page-map entries
    point there, so a scatter never needs dynamic shapes.

    Returns ``(pool_tree, slot_tree)``; raises ``ValueError`` when the
    architecture has no pageable KV (pure-SSM caches are O(1)/session and
    gain nothing from paging).
    """
    paged, rest = split_paged(caches)
    leaves = jax.tree_util.tree_leaves(paged)
    if not leaves:
        raise ValueError("paged KV needs attention k/v caches; this "
                         "architecture's cache has none (pure SSM?)")
    S = leaves[0].shape[2]
    if page_size < 1 or S % page_size != 0:
        raise ValueError(f"page_size {page_size} must divide max_len {S}")

    def to_pool(c):
        G, B, S_, K, hd = c.shape
        pages = c.reshape(G, B * (S_ // page_size), page_size, K, hd)
        scratch = jnp.zeros((G, 1, page_size, K, hd), c.dtype)
        return jnp.concatenate([pages, scratch], axis=1)

    return jax.tree.map(to_pool, paged), rest


def gather_pages(pool: Params, slot_tree: Params,
                 page_map: jax.Array) -> Params:
    """Materialise the contiguous decode view from the page pool.

    ``page_map``: (B, pages_per_slot) int32 page ids, logical page order
    per slot; unowned positions point at the scratch page (their rows are
    garbage, masked out of attention by ``cache_index``).  The result
    merges back with the slot-shaped leaves into the stacked tree shape
    ``forward_serve`` expects.
    """
    B, pp = page_map.shape
    flat = page_map.reshape(-1)

    def one(c):
        g = jnp.take(c, flat, axis=1)            # (G, B*pp, page, K, hd)
        G, _, page, K, hd = g.shape
        return g.reshape(G, B, pp * page, K, hd)

    gathered = jax.tree.map(one, pool)
    return {g: {**slot_tree.get(g, {}), **gathered.get(g, {})}
            for g in set(pool) | set(slot_tree)}


def scatter_pages(pool: Params, caches: Params,
                  page_map: jax.Array) -> Params:
    """Write a decode view's kv rows back into the pool.

    The caller routes every non-writable position of ``page_map`` (unowned
    pages, slots outside the current decode group) to the scratch page id —
    duplicate scratch indices overwrite each other, which is exactly the
    masked-dummy-write semantics of the unpaged merge."""
    paged, _ = split_paged(caches)
    B, pp = page_map.shape
    flat = page_map.reshape(-1)

    def one(p, c):
        G, B_, S, K, hd = c.shape
        pages = c.reshape(G, B_ * pp, S // pp, K, hd).astype(p.dtype)
        return p.at[:, flat].set(pages)

    return jax.tree.map(one, pool, paged)


def scatter_one_page(pool: Params, caches: Params, target: jax.Array,
                     row_start, page_size: int) -> Params:
    """Write back only the page a decode step touched.

    A decode step writes exactly one cache row (at ``cache_index``), so
    per slot only the page containing it changes: ``target`` is its (B,)
    pool ids (scratch for slots outside the decode group) and
    ``row_start`` the page-aligned row offset — shared by the whole
    length group.  A pages_per_slot-times smaller writeback than
    :func:`scatter_pages` (which prefill still uses: it fills many pages).
    """
    paged, _ = split_paged(caches)

    def one(p, c):
        w = jax.lax.dynamic_slice_in_dim(c, row_start, page_size, axis=2)
        return p.at[:, target].set(w.astype(p.dtype))

    return jax.tree.map(one, pool, paged)


def slot_pages(one_cache: Params, page_size: int, num_pages: int
               ) -> Tuple[List[Params], Params]:
    """Chop a single-slot cache into page-shaped KV chunks (prefill handoff).

    A prefill-only worker (serve/disagg.py) computes a prompt's KV in a
    plain contiguous slot — no pool, no page table — and ships the result
    to a decode runtime that *is* paged.  This helper is the boundary: the
    slot's paged leaves (``(G, 1, S, K, hd)``) become ``num_pages`` page
    trees shaped exactly like :func:`page_slice` output (``(G, page, K,
    hd)``), so the decode side lands them with :func:`page_insert`
    unchanged.  Returns ``(pages, rest)`` where ``rest`` holds the
    slot-shaped leaves (SSM / cross-attention state) that ship whole.
    """
    if page_size < 1 or num_pages < 1:
        raise ValueError(f"bad page chunking: {num_pages}x{page_size}")
    paged, rest = split_paged(one_cache)
    leaves = jax.tree_util.tree_leaves(paged)
    if leaves and num_pages * page_size > leaves[0].shape[2]:
        raise ValueError(f"{num_pages} pages of {page_size} rows exceed the "
                         f"slot's {leaves[0].shape[2]} cache rows")
    pages = [
        jax.tree.map(
            lambda c: jax.lax.dynamic_slice_in_dim(
                c, p * page_size, page_size, axis=2)[:, 0],
            paged)
        for p in range(num_pages)
    ]
    return pages, rest


def page_slice(pool: Params, pid) -> Params:
    """Extract one page (all groups) from the pool — the spill unit."""
    return jax.tree.map(
        lambda c: jax.lax.dynamic_slice_in_dim(c, pid, 1, axis=1)[:, 0],
        pool)


def page_insert(pool: Params, page: Params, pid) -> Params:
    """Write a fetched page back into pool position ``pid``."""
    return jax.tree.map(
        lambda c, n: jax.lax.dynamic_update_slice_in_dim(
            c, n.astype(c.dtype)[:, None], pid, axis=1),
        pool, page)
