"""Mamba2 / SSD (state-space duality) blocks — mamba2-370m and the zamba2
hybrid backbone.

The chunked SSD algorithm (Dao & Gu, 2024) splits the sequence into chunks
of length ``c``: a quadratic *intra-chunk* term (a (c x c) masked matmul —
MXU friendly) plus a linear *inter-chunk* state recurrence carried by
``lax.scan``.  ``ssd_chunked`` here is the pure-jnp implementation used by
the models and as the oracle for the Pallas kernel twin
(``kernels/ssd_scan.py``); ``ssd_recurrent`` is the step-by-step recurrence
used for decode and as the ground-truth in tests.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ModelContext, dense_init, rmsnorm

Cache = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# SSD core
def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.

    x:  (b, S, H, P)   per-head inputs
    dt: (b, S, H)      positive step sizes (softplus'd)
    A:  (H,)           negative per-head decay
    B:  (b, S, G, N)   input projections (G groups broadcast over H)
    C:  (b, S, G, N)   output projections
    init_state: (b, H, P, N) or None
    Returns (y: (b, S, H, P), final_state: (b, H, P, N)).
    """
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    assert H % G == 0
    c = min(chunk, S)
    assert S % c == 0, (S, c)
    n_chunks = S // c
    rep = H // G

    # decay log a_t = dt_t * A  (negative);  shapes -> (b, n, c, H)
    a = dt * A[None, None, :]
    xc = x.reshape(b, n_chunks, c, H, P)
    ac = a.reshape(b, n_chunks, c, H)
    dtc = dt.reshape(b, n_chunks, c, H)
    Bc = jnp.repeat(B.reshape(b, n_chunks, c, G, N), rep, axis=3)
    Cc = jnp.repeat(C.reshape(b, n_chunks, c, G, N), rep, axis=3)

    cum = jnp.cumsum(ac, axis=2)                       # (b,n,c,H) inclusive
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,n,ci,cj,H)
    idx = jnp.arange(c)
    causal = (idx[:, None] >= idx[None, :])[None, None, :, :, None]
    # mask BEFORE the exp: exp of the (positive, unbounded) anti-causal
    # entries overflows and 0*inf poisons the backward pass otherwise
    L = jnp.exp(jnp.where(causal, seg, -jnp.inf))

    # intra-chunk: y_intra[i] = sum_j L[i,j] (C_i . B_j) dt_j x_j
    scores = jnp.einsum("bnihd,bnjhd->bnijh", Cc, Bc,
                        preferred_element_type=jnp.float32)
    scores = scores * L
    xdt = xc * dtc[..., None]
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", scores.astype(x.dtype), xdt)

    # chunk-final partial states: S_n = sum_t exp(cum[-1]-cum[t]) B_t (dt_t x_t)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)    # (b,n,c,H)
    states = jnp.einsum("bnchd,bnchp->bnhpd",
                        (Bc * decay_to_end[..., None]).astype(x.dtype), xdt)

    # inter-chunk recurrence over n: S <- exp(sum a) S + states_n
    # (carried in fp32 for stability regardless of the compute dtype)
    chunk_decay = jnp.exp(cum[:, :, -1, :])            # (b,n,H)

    def step(carry, xs):
        st_in = carry                                   # (b,H,P,N) fp32
        s_n, d_n = xs                                   # (b,H,P,N), (b,H)
        out = st_in                                     # state BEFORE chunk n
        new = st_in * d_n[:, :, None, None] + s_n.astype(jnp.float32)
        return new, out

    s0 = (jnp.zeros((b, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    final, prev_states = jax.lax.scan(
        step, s0,
        (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1).astype(x.dtype)  # (b,n,H,P,N)
    final = final.astype(x.dtype)

    # inter-chunk output: y_inter[t] = exp(cum[t]) C_t . S_prev
    in_decay = jnp.exp(cum)                            # (b,n,c,H)
    y_inter = jnp.einsum("bnchd,bnhpd->bnchp",
                         (Cc * in_decay[..., None]).astype(x.dtype),
                         prev_states)
    y = (y_intra + y_inter).reshape(b, S, H, P)
    return y, final


def ssd_recurrent(x, dt, A, B, C, init_state=None):
    """Ground-truth stepwise recurrence (tests + decode).

    Same shapes as ssd_chunked; O(S) sequential — only for small S or S=1.
    """
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bf = jnp.repeat(B, rep, axis=2)
    Cf = jnp.repeat(C, rep, axis=2)
    s0 = (jnp.zeros((b, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(state, t):
        xt, dtt, Bt, Ct = t
        decay = jnp.exp(dtt * A[None, :])              # (b,H)
        upd = jnp.einsum("bhn,bhp->bhpn", Bt, xt * dtt[..., None])
        state = state * decay[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bhn->bhp", state, Ct)
        return state, y

    xs = (x.swapaxes(0, 1).astype(jnp.float32),
          dt.swapaxes(0, 1).astype(jnp.float32),
          Bf.swapaxes(0, 1).astype(jnp.float32),
          Cf.swapaxes(0, 1).astype(jnp.float32))
    final, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype), final.astype(x.dtype)


# ---------------------------------------------------------------------------
# Mamba2 block
def mamba_init(key, cfg: ModelConfig, dtype) -> dict:
    D, di = cfg.d_model, cfg.d_inner
    H, N, G = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups
    W = cfg.ssm_conv_width
    conv_dim = di + 2 * G * N
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], D, 2 * di + 2 * G * N + H, dtype),
        "conv_w": (jax.random.normal(ks[1], (W, conv_dim), jnp.float32)
                   * (W ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[2], di, D, dtype),
    }


def mamba_specs(cfg: ModelConfig, planner) -> dict:
    D, di = cfg.d_model, cfg.d_inner
    H, N, G = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups
    W = cfg.ssm_conv_width
    conv_dim = di + 2 * G * N
    fs, tp = planner.axes.fsdp, planner.axes.tensor
    s = planner.spec
    return {
        "in_proj": s((D, 2 * di + 2 * G * N + H), [fs, tp], "ssm_in"),
        "conv_w": s((W, conv_dim), [None, tp], "conv_w"),
        "conv_b": s((conv_dim,), [tp], "conv_b"),
        "A_log": s((H,), [None], "A_log"),
        "D": s((H,), [None], "ssm_D"),
        "dt_bias": s((H,), [None], "dt_bias"),
        "norm_scale": s((di,), [None], "ssm_norm"),
        "out_proj": s((di, D), [tp, fs], "ssm_out"),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d.  x: (b,S,C); w: (W,C).  Returns (y, new
    state (b,W-1,C)) for incremental decode."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    new_state = xp[:, -(W - 1):, :] if W > 1 else state
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(W))
    return y + b[None, None, :], new_state


def mamba_block(params: dict, ctx: ModelContext, x: jax.Array,
                cache: Optional[Cache] = None
                ) -> Tuple[jax.Array, Optional[Cache]]:
    """x: (B,S,D) -> (B,S,D).  cache: {"conv": (B,W-1,conv_dim),
    "ssm": (B,H,P,N)} for decode."""
    cfg = ctx.cfg
    di, H, N, G = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups
    P = cfg.ssm_head_dim
    Bsz, S, D = x.shape

    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)
    xbc = ctx.act(xbc, "batch", None, "tensor")
    conv_state = cache.get("conv") if cache else None
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_state)
    xbc = jax.nn.silu(xbc)
    xs, B_, C_ = jnp.split(xbc, [di, di + G * N], axis=-1)
    xs = xs.reshape(Bsz, S, H, P)
    B_ = B_.reshape(Bsz, S, G, N)
    C_ = C_.reshape(Bsz, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    ssm_state = cache.get("ssm") if cache else None
    if S == 1 and cache is not None:
        y, new_state = ssd_recurrent(xs, dt, A, B_, C_, init_state=ssm_state)
    else:
        pad = (-S) % cfg.ssm_chunk
        if pad:
            xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
            C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, new_state = ssd_chunked(xs, dt, A, B_, C_, cfg.ssm_chunk,
                                   init_state=ssm_state)
        if pad:
            y = y[:, :S]
    y = y + xs[:, :S] * params["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(Bsz, S, di)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = rmsnorm({"scale": params["norm_scale"]}, y * jax.nn.silu(z))
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), params["out_proj"])
    out = out.astype(x.dtype)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "ssm": new_state}
    return out, new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> Cache:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                          cfg.ssm_state), dtype),
    }
