"""Attention: GQA/MHA/SWA with a blockwise (flash-style) XLA implementation.

Two execution paths:

* ``blockwise_attention`` — training / prefill.  Unrolled python loop over
  query chunks gives each chunk a *static* causal / sliding-window KV span
  (no wasted FLOPs on fully-masked blocks), and an inner ``lax.scan`` with an
  online softmax keeps the score tensor at (chunk × chunk) instead of S×S.
  This is the pure-XLA twin of kernels/flash_attention.py (the Pallas TPU
  kernel) — both are validated against kernels/ref.py.

* ``decode_attention`` — single-token decode against a KV cache.  The cache
  is sharded over the sequence axis (the paper's pooled memory applied to
  inference: KV lives striped across the mesh's HBM pool) and the softmax
  reductions run distributed over that axis.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ModelContext, apply_rope, dense_init

Cache = Dict[str, jax.Array]

# Dry-run probe switches (launch/dryrun.py): the online-softmax kv scan is a
# while loop, which XLA cost_analysis counts once — probes unroll it (and
# use bigger chunks to bound the unrolled body count).
UNROLL_INNER = False
Q_CHUNK = 1024
KV_CHUNK = 1024


def _unroll():
    return True if UNROLL_INNER else 1


# ---------------------------------------------------------------------------
def attn_init(key, cfg: ModelConfig, dtype) -> dict:
    H, K, hd, D = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim, cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], D, H * hd, dtype),
        "wk": dense_init(ks[1], D, K * hd, dtype),
        "wv": dense_init(ks[2], D, K * hd, dtype),
        "wo": dense_init(ks[3], H * hd, D, dtype),
    }
    if cfg.use_qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((K * hd,), dtype)
        p["bv"] = jnp.zeros((K * hd,), dtype)
    return p


def attn_specs(cfg: ModelConfig, planner) -> dict:
    H, K, hd, D = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim, cfg.d_model
    fs, tp = planner.axes.fsdp, planner.axes.tensor
    s = {
        "wq": planner.spec((D, H * hd), [fs, tp], "wq"),
        "wk": planner.spec((D, K * hd), [fs, tp], "wk"),
        "wv": planner.spec((D, K * hd), [fs, tp], "wv"),
        "wo": planner.spec((H * hd, D), [tp, fs], "wo"),
    }
    if cfg.use_qkv_bias:
        s["bq"] = planner.spec((H * hd,), [tp], "bq")
        s["bk"] = planner.spec((K * hd,), [tp], "bk")
        s["bv"] = planner.spec((K * hd,), [tp], "bv")
    return s


# ---------------------------------------------------------------------------
def _span_for_chunk(qi: int, q_chunk: int, kv_len: int, causal: bool,
                    window: int, kv_chunk: int) -> Tuple[int, int]:
    """Static [start, end) KV span a query chunk may attend to."""
    q_end = (qi + 1) * q_chunk
    end = min(kv_len, q_end) if causal else kv_len
    start = 0
    if causal and window > 0:
        start = max(0, qi * q_chunk - window)
    start = (start // kv_chunk) * kv_chunk           # align to kv chunks
    return start, end


def _online_softmax_span(q, k_span, v_span, *, scale, q0, k0, causal, window,
                         kv_chunk, softcap):
    """q: (B, Cq, K, G, hd); span: (B, T, K, hd).  Online softmax over kv
    chunks.  Returns (B, Cq, K, G, hd)."""
    B, Cq, K, G, hd = q.shape
    T = k_span.shape[1]
    n_kv = -(-T // kv_chunk)
    pad = n_kv * kv_chunk - T
    if pad:
        k_span = jnp.pad(k_span, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_span = jnp.pad(v_span, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k_span.reshape(B, n_kv, kv_chunk, K, hd).swapaxes(0, 1)
    vc = v_span.reshape(B, n_kv, kv_chunk, K, hd).swapaxes(0, 1)
    kidx = jnp.arange(n_kv)

    q_pos = q0 + jnp.arange(Cq)

    def step(carry, xs):
        m, l, acc = carry
        kk, vv, ki = xs
        s = jnp.einsum("bqkgd,btkd->bkgqt", q, kk,
                       preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        k_pos = k0 + ki * kv_chunk + jnp.arange(kv_chunk)
        mask = jnp.ones((Cq, kv_chunk), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
            if window > 0:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
        if pad:
            mask &= (k_pos < k0 + T)[None, :]
        s = jnp.where(mask, s, -1e30)    # finite NEG: a fully-masked
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))   # chunk must not NaN
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqt,btkd->bkgqd", p.astype(vv.dtype), vv,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, Cq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, K, G, Cq), jnp.float32)
    a0 = jnp.zeros((B, K, G, Cq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, kidx),
                                  unroll=_unroll())
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)   # (B, Cq, K, G, hd)


def blockwise_attention(q, k, v, *, causal: bool, window: int = 0,
                        q_chunk: int = 0, kv_chunk: int = 0,
                        softcap: float = 0.0) -> jax.Array:
    """q: (B, S, H, hd); k/v: (B, T, K, hd) with H = K*G (GQA).

    Unrolled query chunks -> exact causal/window FLOPs with static shapes.
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    q = q.reshape(B, S, K, G, hd)
    q_chunk = q_chunk or Q_CHUNK
    kv_chunk = kv_chunk or KV_CHUNK
    q_chunk = min(q_chunk, S)
    n_q = -(-S // q_chunk)
    pad_q = n_q * q_chunk - S
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    outs = []
    for qi in range(n_q):
        qc = jax.lax.slice_in_dim(q, qi * q_chunk, (qi + 1) * q_chunk, axis=1)
        start, end = _span_for_chunk(qi, q_chunk, T, causal, window, kv_chunk)
        ks = jax.lax.slice_in_dim(k, start, end, axis=1)
        vs = jax.lax.slice_in_dim(v, start, end, axis=1)
        outs.append(_online_softmax_span(
            qc, ks, vs, scale=scale, q0=qi * q_chunk, k0=start, causal=causal,
            window=window, kv_chunk=min(kv_chunk, end - start), softcap=softcap))
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    if pad_q:
        out = jax.lax.slice_in_dim(out, 0, S, axis=1)
    return out.reshape(B, S, H, hd)


def decode_attention(q, k_cache, v_cache, cache_index, *, window: int = 0,
                     softcap: float = 0.0) -> jax.Array:
    """Single-token attention over a (possibly mesh-pooled) KV cache.

    q: (B, 1, H, hd); caches: (B, S, K, hd); cache_index: scalar int32 —
    number of valid cache positions (the new token attends to [0, index]).
    """
    B, _, H, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    qq = q.reshape(B, K, G, hd)
    s = jnp.einsum("bkgd,btkd->bkgt", qq, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    pos = jnp.arange(S)
    mask = pos <= cache_index
    if window > 0:
        mask &= pos > cache_index - window
    # finite NEG, not -inf: an inactive slot (cache_index < 0, mask all
    # false) must yield a finite (discarded) row, not NaN-poison the
    # batched einsum — same contract as _online_softmax_span.  For any
    # row with >=1 valid position the result is bit-identical (exp of
    # -1e30 - m underflows to exactly 0).
    s = jnp.where(mask[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def prefix_prefill_attention(q, k_cache, v_cache, positions, *,
                             window: int = 0,
                             softcap: float = 0.0) -> jax.Array:
    """Multi-token attention over a cache holding a reused prefix.

    The suffix-prefill twin of :func:`decode_attention`: ``q`` holds the
    S2 suffix tokens of a prompt whose first rows were grafted from the
    prefix cache (prefix-sharing admission), the K/V caches hold the
    grafted rows plus the just-written suffix rows, and each query at
    absolute position ``positions[b, i]`` attends causally to cache rows
    ``[0, positions[b, i]]`` — rows beyond are masked (stale frames).

    q: (B, S2, H, hd); caches: (B, T, K, hd); positions: (B, S2) int32.
    """
    B, S2, H, hd = q.shape
    T, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    qq = q.reshape(B, S2, K, G, hd)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qq, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    t = jnp.arange(T)
    # (B, 1, 1, S2, T): row t visible to query at absolute position p
    # iff t <= p (and within the sliding window when one is set)
    mask = t[None, None, None, None, :] <= \
        positions[:, None, None, :, None]
    if window > 0:
        mask &= t[None, None, None, None, :] > \
            positions[:, None, None, :, None] - window
    # finite NEG (see decode_attention): a padded query row whose
    # position masks every cache row must not softmax over all -inf
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bkgqd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S2, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
def attention_block(params: dict, ctx: ModelContext, x: jax.Array,
                    positions: jax.Array, *, causal: bool = True,
                    cache: Optional[Cache] = None,
                    cache_index: Optional[jax.Array] = None,
                    kv_x: Optional[jax.Array] = None,
                    use_rope: bool = True,
                    prefix_attend: bool = False,
                    paged: Optional[dict] = None
                    ) -> Tuple[jax.Array, Optional[Cache]]:
    """Full attention sub-block: projections + rope + attend + output proj.

    kv_x: source of K/V for cross-attention (encoder states); when given with
    a cache, the cache holds the projected cross K/V and is reused as-is.
    """
    cfg = ctx.cfg
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    B, S, D = x.shape
    window = cfg.window if cfg.attention == "swa" else 0

    q = jnp.einsum("bsd,dh->bsh", x, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    q = q.reshape(B, S, H, hd)

    src = kv_x if kv_x is not None else x
    k = jnp.einsum("bsd,dh->bsh", src, params["wk"])
    v = jnp.einsum("bsd,dh->bsh", src, params["wv"])
    if "bk" in params:
        k, v = k + params["bk"], v + params["bv"]
    Tkv = src.shape[1]
    k = k.reshape(B, Tkv, K, hd)
    v = v.reshape(B, Tkv, K, hd)

    if use_rope and kv_x is None:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)

    # heads over 'model' when divisible; else sequence-parallel (smollm 9H,
    # starcoder2 36H) so the score buffers still split across the mesh
    tp = ctx.planner.axes.size(ctx.planner.axes.tensor)
    if tp > 1 and H % tp != 0 and S > 1:
        q = ctx.act(q, "batch", "seq", None, None)
        k = ctx.act(k, "batch", None, None, None)
        v = ctx.act(v, "batch", None, None, None)
    else:
        q = ctx.act(q, "batch", None, "heads", None)
        # pinning K/V to the batch shard prevents GSPMD's full-batch K/V
        # gather when K < tp and the (K,G) reshape defeats head sharding
        # (§Perf H3: measured 2x9.7 GB/dev/layer on command-r; an explicit
        # repeat-to-MHA variant was tried and REFUTED — it added wire on
        # danube/mixtral where no pathology existed)
        k = ctx.act(k, "batch", None, None, None)
        v = ctx.act(v, "batch", None, None, None)

    new_cache = cache
    if cache is not None and paged is not None:
        # in-place paged decode: the cache leaves ARE the page pool
        # (P, page, K, hd) — no batch dim, no gathered view.  The step's
        # K/V row lands directly in its page frame (write_pid routes
        # masked slots to the scratch frame) and attention dereferences
        # the block table inside the kernel, touching only the pages each
        # session holds.  Compressed side-pool leaves (kq/vq/ks/vs) ride
        # along read-only; new_cache returns only the mutated raw pool.
        assert S == 1, "paged decode is single-token"
        from repro.kernels import ops as kops
        kc, vc = cache["k"], cache["v"]
        row = paged["row_off"]
        kc = kc.at[paged["write_pid"], row].set(k[:, 0].astype(kc.dtype))
        vc = vc.at[paged["write_pid"], row].set(v[:, 0].astype(vc.dtype))
        o = kops.paged_attention(
            q, kc, vc, paged["page_map"], cache_index, window=window,
            softcap=cfg.logit_softcap, kq_pool=cache.get("kq"),
            vq_pool=cache.get("vq"), k_scale=cache.get("ks"),
            v_scale=cache.get("vs"))
        new_cache = {"k": kc, "v": vc}
    elif cache is not None:
        # self-attention with cache: decode (S==1) writes one slot; prefill
        # writes the whole prefix at 0 — except a prefix-sharing suffix
        # prefill (prefix_attend), which writes the S suffix rows at
        # cache_index and attends over the cache (grafted prefix rows
        # included) instead of only the in-flight tokens.
        kc, vc = cache["k"], cache["v"]
        idx = cache_index if (cache_index is not None
                              and (S == 1 or prefix_attend)) else 0
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), idx, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), idx, 1)
        kc = ctx.act(kc, "batch", "seq", None, None)   # pooled KV (MC-DLA)
        vc = ctx.act(vc, "batch", "seq", None, None)
        new_cache = dict(cache, k=kc, v=vc)
        if S == 1:
            o = decode_attention(q, kc, vc, cache_index, window=window,
                                 softcap=cfg.logit_softcap)
        elif prefix_attend:
            o = prefix_prefill_attention(q, kc, vc, positions, window=window,
                                         softcap=cfg.logit_softcap)
        else:
            o = blockwise_attention(q, k, v, causal=causal, window=window,
                                    softcap=cfg.logit_softcap)
    else:
        o = blockwise_attention(q, k, v, causal=causal, window=window,
                                softcap=cfg.logit_softcap)

    if tp > 1 and H % tp != 0 and S > 1:
        o = ctx.act(o, "batch", "seq", None, None)
    else:
        o = ctx.act(o, "batch", None, "heads", None)
    out = jnp.einsum("bsh,hd->bsd", o.reshape(B, S, H * hd), params["wo"])
    return out, new_cache


def cross_attention_block(params: dict, ctx: ModelContext, x: jax.Array,
                          *, enc_kv: Cache) -> jax.Array:
    """Cross-attention against precomputed encoder K/V (whisper decoder).

    enc_kv: {"k": (B, T_enc, K, hd), "v": ...} — projected once at prefill
    (see transformer.encode_cross_kv) and reused for every decode step.
    """
    cfg = ctx.cfg
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    B, S, D = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    q = q.reshape(B, S, H, hd)
    q = ctx.act(q, "batch", None, "heads", None)
    kc, vc = enc_kv["k"], enc_kv["v"]
    if S == 1:
        o = decode_attention(q, kc, vc, jnp.int32(kc.shape[1] - 1),
                             softcap=cfg.logit_softcap)
    else:
        o = blockwise_attention(q, kc, vc, causal=False,
                                softcap=cfg.logit_softcap)
    o = ctx.act(o, "batch", None, "heads", None)
    return jnp.einsum("bsh,hd->bsd", o.reshape(B, S, H * hd), params["wo"])


def encode_cross_kv(params: dict, cfg: ModelConfig, enc_out: jax.Array) -> Cache:
    """Project encoder states to cross K/V once (reused across decode steps)."""
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    B, T, _ = enc_out.shape
    k = jnp.einsum("bsd,dh->bsh", enc_out, params["wk"])
    v = jnp.einsum("bsd,dh->bsh", enc_out, params["wv"])
    if "bk" in params:
        k, v = k + params["bk"], v + params["bv"]
    return {"k": k.reshape(B, T, K, hd), "v": v.reshape(B, T, K, hd)}


def init_kv_cache(cfg: ModelConfig, batch: int, seq: int, dtype) -> Cache:
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {"k": jnp.zeros((batch, seq, K, hd), dtype),
            "v": jnp.zeros((batch, seq, K, hd), dtype)}
