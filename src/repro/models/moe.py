"""Mixture-of-Experts with expert parallelism over the 'model' mesh axis.

Two sharding regimes, selected by divisibility (DESIGN.md §5):

* **EP** (``E % model_size == 0``, e.g. llama4's 128 experts on 16-way TP):
  each model-column owns E/model_size experts; tokens are replicated over
  the model axis (they already are, under DP+TP), each column gathers only
  the tokens routed to *its* experts, and one ``psum`` over 'model' combines
  the expert outputs — no all-to-all required.

* **TP-in-expert** (``E % model_size != 0``, e.g. mixtral's 8 experts on a
  16-way axis): every column processes all experts with the FFN hidden dim
  sharded, and the same ``psum`` completes the row-parallel matmul.

Routing is capacity-based top-k (sort by expert id -> position-in-expert ->
drop overflow), the standard dense-shardable formulation.  The whole layer
runs under ``shard_map`` so the collective schedule is explicit and
deterministic; gradients flow through ``psum``/gather/scatter natively.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.configs.base import ModelConfig
from repro.models.layers import ModelContext, dense_init

def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
def moe_init(key, cfg: ModelConfig, dtype) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], D, E, jnp.float32),
        "w1": (jax.random.normal(ks[1], (E, D, F), jnp.float32)
               * (D ** -0.5)).astype(dtype),
        "w3": (jax.random.normal(ks[2], (E, D, F), jnp.float32)
               * (D ** -0.5)).astype(dtype),
        "w2": (jax.random.normal(ks[3], (E, F, D), jnp.float32)
               * (F ** -0.5)).astype(dtype),
    }
    if cfg.shared_experts:
        Fs = cfg.d_ff * cfg.shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared_w1"] = dense_init(kk[0], D, Fs, dtype)
        p["shared_w3"] = dense_init(kk[1], D, Fs, dtype)
        p["shared_w2"] = dense_init(kk[2], Fs, D, dtype)
    return p


def use_ep(cfg: ModelConfig, planner) -> bool:
    tp = planner.axes.size(planner.axes.tensor)
    return cfg.num_experts % max(tp, 1) == 0 and tp > 1


def moe_specs(cfg: ModelConfig, planner) -> dict:
    E, D, F = cfg.num_experts, cfg.d_model, cfg.d_ff
    fs, tp = planner.axes.fsdp, planner.axes.tensor
    s = planner.spec
    if use_ep(cfg, planner):
        sp = {
            "router": s((D, E), [None, None], "router"),
            "w1": s((E, D, F), [tp, fs, None], "moe_w1"),
            "w3": s((E, D, F), [tp, fs, None], "moe_w3"),
            "w2": s((E, F, D), [tp, None, fs], "moe_w2"),
        }
    else:
        sp = {
            "router": s((D, E), [None, None], "router"),
            "w1": s((E, D, F), [None, fs, tp], "moe_w1"),
            "w3": s((E, D, F), [None, fs, tp], "moe_w3"),
            "w2": s((E, F, D), [None, tp, fs], "moe_w2"),
        }
    if cfg.shared_experts:
        Fs = F * cfg.shared_experts
        sp["shared_w1"] = s((D, Fs), [fs, tp], "shared_w1")
        sp["shared_w3"] = s((D, Fs), [fs, tp], "shared_w3")
        sp["shared_w2"] = s((Fs, D), [tp, fs], "shared_w2")
    return sp


# ---------------------------------------------------------------------------
def _route(x2d: jax.Array, router: jax.Array, top_k: int, capacity: int,
           num_experts: int):
    """Capacity-based top-k routing.

    x2d: (T, D).  Returns (gather_idx (E, C) into [0, T] with T = dropped
    sentinel, combine_w (E, C), router_probs (T, E) for the aux loss).
    """
    T = x2d.shape[0]
    logits = x2d.astype(jnp.float32) @ router              # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)             # (T, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    flat_e = top_i.reshape(-1)                             # (T*k,)
    flat_w = top_p.reshape(-1)
    tok = jnp.arange(T * top_k, dtype=jnp.int32) // top_k
    order = jnp.argsort(flat_e)                            # stable
    e_sorted = flat_e[order]
    t_sorted = tok[order]
    w_sorted = flat_w[order]
    # rank within each expert group
    first = jnp.searchsorted(e_sorted, e_sorted, side="left")
    rank = jnp.arange(T * top_k, dtype=jnp.int32) - first.astype(jnp.int32)
    valid = rank < capacity
    gather_idx = jnp.full((num_experts, capacity), T, jnp.int32)
    combine_w = jnp.zeros((num_experts, capacity), jnp.float32)
    e_dst = jnp.where(valid, e_sorted, num_experts)        # overflow -> drop
    gather_idx = gather_idx.at[e_dst, rank].set(t_sorted, mode="drop")
    combine_w = combine_w.at[e_dst, rank].set(w_sorted, mode="drop")
    return gather_idx, combine_w, probs


def _expert_ffn(xe: jax.Array, w1, w3, w2) -> jax.Array:
    """xe: (e, C, D) -> (e, C, D), gated-SiLU experts."""
    h = jnp.einsum("ecd,edf->ecf", xe, w1)
    g = jnp.einsum("ecd,edf->ecf", xe, w3)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * g, w2)


def _moe_local(params: dict, x2d: jax.Array, cfg: ModelConfig,
               capacity: int, ep: bool, e_local: int,
               axis_name: Optional[str]) -> Tuple[jax.Array, jax.Array]:
    """Per-device MoE body.  x2d: (T, D) local tokens (replicated over the
    model axis).  Returns (out (T, D), aux load-balance loss)."""
    T, D = x2d.shape
    E, k = cfg.num_experts, cfg.top_k
    gather_idx, combine_w, probs = _route(
        x2d, params["router"], k, capacity, E)

    if ep and axis_name is not None:
        col = jax.lax.axis_index(axis_name)
        e0 = col * e_local
        gi = jax.lax.dynamic_slice_in_dim(gather_idx, e0, e_local, axis=0)
        cw = jax.lax.dynamic_slice_in_dim(combine_w, e0, e_local, axis=0)
    else:
        gi, cw = gather_idx, combine_w

    x_pad = jnp.concatenate([x2d, jnp.zeros((1, D), x2d.dtype)], axis=0)
    xe = x_pad[gi]                                         # (e, C, D)
    ye = _expert_ffn(xe, params["w1"], params["w3"], params["w2"])
    ye = ye * cw[..., None].astype(ye.dtype)
    out = jnp.zeros((T + 1, D), ye.dtype).at[gi].add(
        ye, mode="drop")[:T]

    if cfg.shared_experts:
        h = jax.nn.silu(x2d @ params["shared_w1"]) * (x2d @ params["shared_w3"])
        out = out + h @ params["shared_w2"]

    if axis_name is not None:
        out = jax.lax.psum(out, axis_name)

    # Switch-style load-balance loss: E * sum_e f_e * p_e
    top1 = jnp.argmax(probs, axis=-1)
    f = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=0)
    p = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * p)
    return out, aux


# ---------------------------------------------------------------------------
def moe_block(params: dict, ctx: ModelContext, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,D) -> ((B,S,D), aux loss).  Dispatches to shard_map on a real
    mesh, plain local computation otherwise (smoke tests)."""
    cfg, planner, mesh = ctx.cfg, ctx.planner, ctx.mesh
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.top_k
    ep = use_ep(cfg, planner) and mesh is not None and mesh.size > 1

    if mesh is None or mesh.size <= 1:
        x2d = x.reshape(-1, D)
        cap = _round_up(int(cfg.capacity_factor * x2d.shape[0] * k / E) or 1, 8)
        out, aux = _moe_local(params, x2d, cfg, cap, False, E, None)
        return out.reshape(B, S, D).astype(x.dtype), aux

    batch_axes = planner.axes.batch
    fsdp_axes = planner.axes.fsdp
    tp_axes = planner.axes.tensor
    tp_name = tp_axes[0] if tp_axes else None
    tp_size = planner.axes.size(tp_axes)
    dp_size = planner.axes.size(batch_axes)
    dp_eff = dp_size if B % max(dp_size, 1) == 0 else 1
    t_local = (B // dp_eff) * S
    cap = _round_up(int(cfg.capacity_factor * t_local * k / E) or 1, 8)
    e_local = E // tp_size if ep else E

    pspecs = moe_specs(cfg, planner)
    x_spec = planner.spec((B, S, D), [batch_axes, None, None], "moe_x")

    def body(params, xb):
        # ZeRO-3: transiently all-gather the FSDP ('data') shard of each
        # expert weight; the pooled copy stays resident.
        p = dict(params)
        if fsdp_axes:
            def ag(w, spec):
                for dim, part in enumerate(spec):
                    if part and set(_as_tuple(part)) & set(fsdp_axes):
                        return jax.lax.all_gather(w, fsdp_axes, axis=dim,
                                                  tiled=True)
                return w
            for key in p:
                p[key] = ag(p[key], pspecs[key])
        x2d = xb.reshape(-1, D)
        out, aux = _moe_local(p, x2d, cfg, cap, ep, e_local, tp_name)
        aux = jax.lax.pmean(aux, batch_axes) if batch_axes else aux
        return out.reshape(xb.shape).astype(xb.dtype), aux

    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(pspecs, x_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(params, x)
    return out, aux


def _as_tuple(part):
    return (part,) if isinstance(part, str) else tuple(part)
