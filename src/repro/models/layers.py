"""Shared layer primitives: norms, activations, RoPE/M-RoPE, embeddings,
chunked cross-entropy.  Everything is a pure function over explicit param
dicts — no framework magic, fully pjit/shard_map compatible."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import MemoryPlan, ModelConfig
from repro.parallel.sharding import ShardingPlanner, constrain


@dataclasses.dataclass
class ModelContext:
    """Everything the pure model functions need besides params/inputs."""

    cfg: ModelConfig
    planner: ShardingPlanner
    memory: MemoryPlan
    mesh: Optional[Mesh] = None
    mode: str = "train"                  # train | prefill | decode
    runtime: Optional["MemoryRuntime"] = None

    def __post_init__(self):
        if self.runtime is None:
            from repro.core.runtime import MemoryRuntime
            self.runtime = MemoryRuntime(self.planner.plan, self.memory,
                                         self.mesh, planner=self.planner)

    def constrain(self, x: jax.Array, assignment) -> jax.Array:
        if self.mesh is None or self.mesh.size == 1:
            return x
        spec = self.planner.spec(x.shape, assignment, name="act")
        return constrain(x, self.mesh, spec)

    def act(self, x: jax.Array, *roles: Optional[str]) -> jax.Array:
        """Constrain an activation by logical dim roles.

        Roles: "batch", "seq" (sequence-parallel over the model axis),
        "heads", "tensor", None.
        """
        ax = self.planner.axes
        table = {None: None, "batch": ax.batch, "seq": ax.tensor,
                 "heads": ax.tensor, "tensor": ax.tensor,
                 "pool": ("data", "model")}
        return self.constrain(x, [table[r] for r in roles])

    def resid(self, x: jax.Array) -> jax.Array:
        """Residual-stream layout between layers: sequence-parallel over the
        'model' axis (Megatron-SP) when enabled — a (B,S,D) copy costs
        1/tp per device; layer-internal einsums gather/reduce-scatter S as
        part of their collectives."""
        if self.memory.seq_parallel:
            return self.act(x, "batch", "seq", None)
        return self.act(x, "batch", None, None)

    def wrap(self, name: str, fn):
        """vDNN-wrap a sub-layer for training (MemoryRuntime.wrap_layer):
        the layer's input feature map is stashed to the configured memory
        tier, intermediates are recomputed in backward.  No-op for serving /
        a non-offloading tier / no mesh."""
        if (self.mode != "train" or self.mesh is None
                or self.mesh.size <= 1):
            return fn
        return self.runtime.wrap_layer(fn, batch_dim=0, name=name)


# ---------------------------------------------------------------------------
# dtype helpers
def param_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def activation_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# norms
def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype=jnp.float32)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * params["scale"]
    return y.astype(dt)


def layernorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(dt)


def norm_init(cfg: ModelConfig, d: int) -> dict:
    return layernorm_init(d, None) if cfg.norm == "layernorm" else rmsnorm_init(d, None)


def apply_norm(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    return layernorm(params, x) if cfg.norm == "layernorm" else rmsnorm(params, x)


# ---------------------------------------------------------------------------
# dense / embedding init
def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE (+ M-RoPE for qwen2-vl)
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mrope_sections: Tuple[int, ...] = ()) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) or (3, B, S) for M-RoPE."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    if mrope_sections:
        assert positions.ndim == 3, "M-RoPE needs (3, B, S) positions"
        secs = list(mrope_sections)
        assert sum(secs) == hd // 2, (secs, hd)
        pos_parts = []
        start = 0
        for axis_i, sec in enumerate(secs):
            pos_parts.append(jnp.broadcast_to(
                positions[axis_i][..., None], positions.shape[1:] + (sec,)))
            start += sec
        pos = jnp.concatenate(pos_parts, axis=-1)       # (B, S, hd/2)
        ang = pos.astype(jnp.float32) * freqs           # (B, S, hd/2)
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq: int, d: int, offset=0) -> jax.Array:
    """Sinusoidal positional encoding (whisper enc/dec; any length)."""
    pos = jnp.arange(seq, dtype=jnp.float32) + offset
    half = d // 2
    freq = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                   / max(half - 1, 1))
    ang = pos[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# chunked cross-entropy (keeps (B,S,V) logits out of live memory)
def chunked_cross_entropy(h: jax.Array, embed: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None,
                          chunk: int = 512,
                          constrain_logits=None) -> Tuple[jax.Array, jax.Array]:
    """h: (B, S, D); embed: (V, D) (tied head) — returns (mean loss, n_tokens).

    Scans over S in chunks so the full logits tensor is never resident.
    constrain_logits: optional fn applied to each (B, chunk, V) logits block
    — vocab-parallel sharding (V over 'model') keeps the block at V/tp per
    device; the logsumexp reductions become cheap psums.
    """
    B, S, D = h.shape
    V = embed.shape[0]
    chunk = max(chunk, -(-S // 8))     # <=8 chunks (the scan is unrolled)
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad))) if mask is not None else \
            jnp.pad(jnp.ones((B, S), jnp.float32), ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    n_chunks = h.shape[1] // chunk
    hc = h.reshape(B, n_chunks, chunk, D).swapaxes(0, 1)
    lc = labels.reshape(B, n_chunks, chunk).swapaxes(0, 1)
    mc = mask.reshape(B, n_chunks, chunk).swapaxes(0, 1)

    @jax.checkpoint          # recompute the chunk logits in backward — the
    def body(carry, xs):     # scan must NOT save (B,chunk,V) per step
        tot, cnt = carry
        hh, ll, mm = xs
        logits = jnp.einsum("bsd,vd->bsv", hh, embed).astype(jnp.float32)
        if constrain_logits is not None:
            logits = constrain_logits(logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        nll = (lse - picked) * mm
        return (tot + jnp.sum(nll), cnt + jnp.sum(mm)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (hc, lc, mc), unroll=True)
    return tot / jnp.maximum(cnt, 1.0), cnt
