"""Modality frontends — STUBS per the assignment.

The [audio]/[vlm] cells specify the transformer BACKBONE only; the conv /
patchification frontends are stubs: ``input_specs()`` provides *precomputed*
frame / patch embeddings and these modules only project + merge them into
the token stream.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

# raw embedding widths delivered by the (stubbed) frontends
AUDIO_FRAME_DIM = 128          # log-mel x conv-stub output per frame
VISION_PATCH_DIM = 1176        # 14x14x3x2 qwen2-vl patch (2-frame merge)


def frontend_dim(cfg: ModelConfig) -> int:
    return {"audio_stub": AUDIO_FRAME_DIM,
            "vision_stub": VISION_PATCH_DIM}.get(cfg.frontend, 0)


def frontend_init(key, cfg: ModelConfig, dtype) -> dict:
    d_in = frontend_dim(cfg)
    if not d_in:
        return {}
    ks = jax.random.split(key, 2)
    return {
        "proj": dense_init(ks[0], d_in, cfg.d_model, dtype),
        # learned positions for the encoder/patch stream
        "pos": (jax.random.normal(ks[1], (cfg.frontend_tokens, cfg.d_model),
                                  jnp.float32) * 0.02).astype(dtype),
    }


def frontend_specs(cfg: ModelConfig, planner) -> dict:
    d_in = frontend_dim(cfg)
    if not d_in:
        return {}
    fs, tp = planner.axes.fsdp, planner.axes.tensor
    return {
        "proj": planner.spec((d_in, cfg.d_model), [None, fs], "fe_proj"),
        "pos": planner.spec((cfg.frontend_tokens, cfg.d_model), [None, fs],
                            "fe_pos"),
    }


def embed_frames(params: dict, cfg: ModelConfig, frames: jax.Array
                 ) -> jax.Array:
    """frames: (B, T, frontend_dim) precomputed embeddings -> (B, T, D)."""
    x = jnp.einsum("btf,fd->btd", frames.astype(params["proj"].dtype),
                   params["proj"])
    return x + params["pos"][None, :x.shape[1], :]


def merge_patches(params: dict, cfg: ModelConfig, tok_emb: jax.Array,
                  patches: jax.Array) -> jax.Array:
    """VLM early fusion: the first ``frontend_tokens`` positions of the
    sequence carry image patches; the rest are text embeddings.

    tok_emb: (B, S, D); patches: (B, P, patch_dim) with P <= S.
    """
    pe = jnp.einsum("bpf,fd->bpd", patches.astype(params["proj"].dtype),
                    params["proj"])
    pe = pe + params["pos"][None, :pe.shape[1], :]
    P_ = pe.shape[1]
    return jnp.concatenate([pe, tok_emb[:, P_:, :]], axis=1)
