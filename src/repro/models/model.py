"""Model facade — one object per (architecture x mesh x memory plan).

Wraps the transformer stacks with: parameter init + sharding specs, the
training loss (chunked CE + MoE aux), serving entry points (prefill /
decode), cache construction with pooled-KV sharding, and the
ShapeDtypeStruct input specs the multi-pod dry-run lowers against.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (MemoryPlan, MeshPlan, ModelConfig,
                                PipelinePlan, RunConfig, ShapeConfig)
from repro.core.runtime import MemoryRuntime
from repro.models import frontends, transformer as tfm
from repro.models.layers import ModelContext, chunked_cross_entropy
from repro.parallel.sharding import ShardingPlanner

Params = Dict[str, Any]
AUX_WEIGHT = 0.01


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    plan: MeshPlan
    memory: MemoryPlan
    mesh: Optional[Mesh] = None
    stash_groups: Optional[int] = None     # None -> stash all (mcdla)
    pipeline: Optional[PipelinePlan] = None
    pipe_mesh: Optional[Mesh] = None       # dedicated stage-axis mesh

    def __post_init__(self):
        self.planner = ShardingPlanner(self.plan)
        self.dtype = jnp.dtype(self.cfg.dtype)
        self.runtime = MemoryRuntime(self.plan, self.memory, self.mesh,
                                     planner=self.planner)
        # pipeline runs get a second runtime whose tier is the stage tier:
        # the schedule's stash/fetch hooks meter act_stash/act_fetch there,
        # so training traffic shows up in a traffic_report like serving's.
        self.stage_runtime: Optional[MemoryRuntime] = None
        self.pipeline_report = None
        if self.pipeline is not None and self.pipeline.enabled:
            n_stages = self.pipeline.n_stages or (
                self.pipe_mesh.shape[self.pipeline.axis_name]
                if self.pipe_mesh is not None else 1)
            self.pipeline = dataclasses.replace(self.pipeline,
                                                n_stages=n_stages)
            from repro.core.tiers import build_stage_tier
            tier = build_stage_tier(self.memory, self.planner, None,
                                    n_stages=n_stages)
            self.stage_runtime = MemoryRuntime(self.plan, self.memory, None,
                                               planner=self.planner,
                                               tier=tier)

    # ------------------------------------------------------------------
    def ctx(self, mode: str) -> ModelContext:
        return ModelContext(cfg=self.cfg, planner=self.planner,
                            memory=self.memory, mesh=self.mesh, mode=mode,
                            runtime=self.runtime)

    def init(self, key) -> Params:
        return tfm.init_params(key, self.cfg, self.dtype)

    def abstract_params(self) -> Params:
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    def param_specs(self) -> Params:
        return tfm.param_specs(self.cfg, self.planner)

    def param_shardings(self) -> Params:
        assert self.mesh is not None
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.param_specs(),
                            is_leaf=lambda v: isinstance(v, P))

    # ------------------------------------------------------------------
    # training
    def loss_fn(self, params: Params, batch: Dict[str, jax.Array]
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        ctx = self.ctx("train")
        if self.pipeline is not None and self.pipeline.enabled:
            h, aux = tfm.forward_train_pipelined(
                params, ctx, batch["tokens"], batch["positions"],
                pipeline=self.pipeline, pipe_mesh=self.pipe_mesh,
                stage_runtime=self.stage_runtime)
        else:
            h, aux = tfm.forward_train(
                params, ctx, batch["tokens"], batch["positions"],
                frames=batch.get("frames"), patches=batch.get("patches"),
                stash_groups=self.stash_groups)
        table = params["embed"] if cfg.tie_embeddings else params["unembed"]
        # hoist the FSDP (data-axis) gather of the table out of the chunk
        # scan: vocab stays model-sharded, D gathered ONCE (§Perf: was
        # re-gathered per chunk, 8x the wire)
        table = ctx.act(table, "tensor", None)
        h = ctx.act(h, "batch", None, None)   # gather S once for the CE scan
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        loss, n_tok = chunked_cross_entropy(
            h, table, jnp.maximum(labels, 0), mask,
            constrain_logits=lambda lg: ctx.act(lg, "batch", None, "tensor"))
        total = loss + AUX_WEIGHT * aux
        return total, {"loss": loss, "aux_loss": aux, "tokens": n_tok}

    # ------------------------------------------------------------------
    # serving
    def init_cache(self, batch: int, seq: int) -> Params:
        return tfm.init_caches(self.cfg, batch, seq, self.dtype)

    def cache_specs(self, batch: int, seq: int) -> Params:
        return tfm.cache_specs(self.cfg, self.planner, batch, seq)

    def cache_shardings(self, batch: int, seq: int) -> Params:
        assert self.mesh is not None
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.cache_specs(batch, seq),
                            is_leaf=lambda v: isinstance(v, P))

    def prefill(self, params: Params, batch: Dict[str, jax.Array],
                caches: Params) -> Tuple[jax.Array, Params]:
        """Process the prompt; returns (last-token logits (B,V), caches)."""
        ctx = self.ctx("prefill")
        h, caches = tfm.forward_serve(
            params, ctx, batch["tokens"], batch["positions"], caches,
            cache_index=jnp.zeros((), jnp.int32),
            frames=batch.get("frames"), patches=batch.get("patches"))
        logits = tfm.unembed(params, ctx, h[:, -1:, :])[:, 0, :]
        return logits, caches

    def decode_step(self, params: Params, token: jax.Array,
                    positions: jax.Array, caches: Params,
                    index: jax.Array) -> Tuple[jax.Array, Params]:
        """One decode step.  token: (B,1) int32; index: scalar int32 (number
        of tokens already in the cache); positions: (B,1) or (3,B,1)."""
        ctx = self.ctx("decode")
        h, caches = tfm.forward_serve(params, ctx, token, positions, caches,
                                      cache_index=index)
        logits = tfm.unembed(params, ctx, h[:, 0:1, :])[:, 0, :]
        return logits, caches

    # ------------------------------------------------------------------
    # dry-run input specs (ShapeDtypeStructs; no allocation)
    def input_specs(self, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32, dt = jnp.int32, self.dtype
        sd = jax.ShapeDtypeStruct
        if shape.mode in ("train", "prefill"):
            d: Dict[str, jax.ShapeDtypeStruct] = {
                "tokens": sd((B, S), i32),
                "positions": (sd((3, B, S), i32) if cfg.mrope_sections
                              else sd((B, S), i32)),
            }
            if shape.mode == "train":
                d["labels"] = sd((B, S), i32)
            if cfg.frontend == "audio_stub":
                d["frames"] = sd((B, cfg.frontend_tokens,
                                  frontends.AUDIO_FRAME_DIM), dt)
            if cfg.frontend == "vision_stub":
                d["patches"] = sd((B, cfg.frontend_tokens,
                                   frontends.VISION_PATCH_DIM), dt)
            return d
        # decode: one new token against a seq_len cache
        return {
            "token": sd((B, 1), i32),
            "positions": (sd((3, B, 1), i32) if cfg.mrope_sections
                          else sd((B, 1), i32)),
            "index": sd((), i32),
        }

    def batch_specs(self, shape: ShapeConfig) -> Dict[str, P]:
        """PartitionSpecs for input_specs entries."""
        b = self.planner.axes.batch
        specs = {}
        for name, s in self.input_specs(shape).items():
            if name == "index":
                specs[name] = P()
            elif name == "positions" and len(s.shape) == 3:
                specs[name] = self.planner.spec(s.shape, [None, b, None], name)
            else:
                specs[name] = self.planner.spec(
                    s.shape, [b] + [None] * (len(s.shape) - 1), name)
        return specs


# ---------------------------------------------------------------------------
def build_model(run: RunConfig, mesh: Optional[Mesh] = None,
                pipe_mesh: Optional[Mesh] = None) -> Model:
    """Construct the Model for a run, resolving the memory tier's stash
    split through the MemoryRuntime (cost model for non-stash-all tiers).

    Pipeline runs (``run.pipeline.enabled``) additionally resolve
    ``n_micro`` when it is 0: the planner sweeps the feasible microbatch
    counts and trades the schedule bubble against predicted stage-tier
    stalls (``core.policy.plan_memory``); the full verdict is kept on
    ``model.pipeline_report``.
    """
    cfg, memory, plan = run.model, run.memory, run.mesh
    _, n_groups = tfm.arch_group(cfg)
    pipeline = run.pipeline if run.pipeline.enabled else None
    model = Model(cfg=cfg, plan=plan, memory=memory, mesh=mesh,
                  pipeline=pipeline, pipe_mesh=pipe_mesh)
    model.stash_groups = model.runtime.resolve_stash_groups(
        cfg, run.shape, n_groups)
    if model.pipeline is not None:
        from repro.core.dag import build_dag
        from repro.core.policy import micro_candidates
        opt_bytes = 2 + (8 if memory.opt_state_bits == 32 else 2) + 4
        report = model.stage_runtime.plan_report(
            build_dag(cfg, run.shape),
            model_state_bytes=cfg.param_count() * opt_bytes,
            pipeline=model.pipeline,
            n_micro_candidates=micro_candidates(
                run.shape.global_batch, model.pipeline.n_stages))
        model.pipeline_report = report
        if model.pipeline.n_micro == 0:
            model.pipeline = dataclasses.replace(
                model.pipeline, n_micro=report.pipeline.n_micro)
    return model
