"""Sharded, atomic checkpoints with reshard-on-load.

No orbax offline — implemented directly on numpy + manifest json:

* **atomic**: written to ``<dir>/tmp.<step>`` then ``os.replace``d into
  ``<dir>/step_<n>`` — a crash mid-save never corrupts the latest.
* **keep-K** garbage collection.
* **reshard-on-load** (elastic scaling): leaves are stored as full arrays;
  ``to_device`` re-places them under the *current* model's shardings, so a
  run checkpointed on a (16,16) mesh restarts on (2,16,16) or on a single
  CPU device unchanged.
* data-pipeline state rides along in the manifest (deterministic resume).

Multi-host note: in this single-process environment leaves are gathered to
host before writing.  On a real multi-pod deployment the same layout is
written per-process for the process-local shards (addressable_shards), with
the manifest recording the global sharding — the restore path is identical
because to_device re-shards whatever was read.
"""
from __future__ import annotations

import json
import logging
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

log = logging.getLogger(__name__)
Pytree = Any

_SEP = "::"


def _flatten(tree: Pytree) -> Dict[str, Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, payload: Pytree) -> str:
        tmp = os.path.join(self.dir, f"tmp.{step}")
        final = os.path.join(self.dir, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        state = payload.get("state")
        flat = _flatten(state)
        arrays = {}
        meta = {"step": step, "keys": [], "data": payload.get("data")}
        for key, leaf in flat.items():
            if leaf is None:
                continue
            arr = np.asarray(jax.device_get(leaf))
            arrays[key] = arr
            meta["keys"].append({"key": key, "dtype": str(arr.dtype),
                                 "shape": list(arr.shape)})
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: v for k, v in arrays.items()})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        log.info("checkpoint written: %s", final)
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    # ------------------------------------------------------------------
    def restore(self, step: int) -> Tuple[int, Dict[str, Any]]:
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            meta = json.load(f)
        dtypes = {e["key"]: e["dtype"] for e in meta["keys"]}
        z = np.load(os.path.join(path, "arrays.npz"))
        flat = {}
        for k in z.files:
            arr = z[k]
            if arr.dtype.kind == "V":    # ml_dtypes (bfloat16/fp8) round-trip
                arr = arr.view(np.dtype(dtypes[k]))
            flat[k] = arr
        return step, {"state": flat, "data": meta.get("data")}

    def restore_latest(self) -> Optional[Tuple[int, Dict[str, Any]]]:
        steps = self.all_steps()
        if not steps:
            return None
        return self.restore(steps[-1])


# ---------------------------------------------------------------------------
def to_device(flat: Dict[str, np.ndarray], template: Pytree, model=None,
              tc=None) -> Pytree:
    """Rebuild the state pytree from flat arrays, re-sharding onto the
    current mesh (elastic restart: the stored mesh is irrelevant)."""
    shardings = None
    if model is not None and model.mesh is not None and tc is not None:
        from repro.train.train_state import state_shardings
        shardings = _flatten(state_shardings(model, tc))

    flat_template = _flatten(template)
    rebuilt = {}
    for key, leaf in flat_template.items():
        if leaf is None:
            rebuilt[key] = None
            continue
        arr = flat[key]
        want = jnp.dtype(leaf.dtype)
        x = jnp.asarray(arr).astype(want)
        if shardings is not None and key in shardings:
            x = jax.device_put(x, shardings[key])
        rebuilt[key] = x
    return _unflatten_like(template, rebuilt)


def _unflatten_like(template: Pytree, flat: Dict[str, Any]) -> Pytree:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, _ in paths:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)
