"""Sharded, atomic, tier-backed checkpoints with reshard-on-load.

No orbax offline — implemented directly on numpy + manifest json:

* **checkpoint-as-a-tier**: snapshots flow *through* a
  :class:`~repro.core.runtime.MemoryRuntime` whose tier stack is the
  :class:`~repro.core.tiers.CheckpointTier` (host or pooled backing store,
  optional codec), metered as ``ckpt_save``/``ckpt_load`` in
  ``traffic_report`` — a checkpoint is cold pooled state, not a
  side-channel write (ISSUE 6).  The manifest accounts the same raw/wire
  bytes the meter counts, so the report is checkable against disk truth.
* **atomic**: written to ``<dir>/tmp.<step>`` then ``os.replace``d into
  ``<dir>/step_<n>`` — a crash mid-save never corrupts the latest; stale
  ``tmp.*`` orphans from a crashed save are swept on the next save.
* **sharded + CRC-validated**: leaves are packed into ``shards`` npz files
  balanced by bytes; the manifest records a crc32 per shard, and
  :meth:`restore` raises :class:`CheckpointError` (``restore_latest``
  skips + warns) on a missing/corrupt manifest or shard.
* **async double-buffered saves**: the device→host gather is synchronous
  (the train step donates its input buffers), the encode+write+commit
  overlaps the next train steps in a background thread; at most one save
  is in flight (:meth:`wait` joins and re-raises).
* **keep-K** garbage collection.
* **reshard-on-load** (elastic scaling): leaves are stored as full arrays;
  ``to_device`` re-places them under the *current* model's shardings, so a
  run checkpointed on a (16,16) mesh restarts on (2,16,16) or on a single
  CPU device unchanged.
* data-pipeline state rides along in the manifest (deterministic resume).

Multi-host note: in this single-process environment leaves are gathered to
host before writing.  On a real multi-pod deployment the same layout is
written per-process for the process-local shards (addressable_shards), with
the manifest recording the global sharding — the restore path is identical
because to_device re-shards whatever was read.
"""
from __future__ import annotations

import json
import logging
import os
import shutil
import threading
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

log = logging.getLogger(__name__)
Pytree = Any

_SEP = "::"
_SCALE_SUFFIX = "::scale"


class CheckpointError(RuntimeError):
    """A checkpoint directory failed validation (missing/corrupt manifest,
    missing shard, CRC mismatch).  ``restore_latest`` skips past these."""


def _flatten(tree: Pytree) -> Dict[str, Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = leaf
    return out


def make_ckpt_runtime(ckpt, plan, memory, planner=None, mesh=None,
                      keep: int = 1):
    """Build the snapshot runtime for a :class:`CheckpointPlan`: the
    requested backing store behind the CheckpointTier drain with the
    snapshot codec stacked on top (core.tiers.build_ckpt_tier)."""
    from repro.core.runtime import MemoryRuntime
    from repro.core.tiers import build_ckpt_tier
    from repro.parallel.sharding import ShardingPlanner
    planner = planner or ShardingPlanner(plan)
    tier = build_ckpt_tier(memory, planner, mesh, backing=ckpt.tier,
                           codec=ckpt.codec, keep=keep)
    return MemoryRuntime(plan, memory, mesh, planner=planner, tier=tier)


class CheckpointManager:
    """Snapshot writer/reader over a checkpoint-tier runtime.

    runtime: a :class:`~repro.core.runtime.MemoryRuntime` whose tier is a
    CheckpointTier stack (:func:`make_ckpt_runtime`); None falls back to
    direct un-metered writes (the legacy path — tests and callers that
    never configured a CheckpointPlan keep working unchanged).
    on_commit: callback ``(step, final_dir)`` invoked after the atomic
    rename — the chaos harness corrupts a committed shard through it.
    """

    def __init__(self, directory: str, keep: int = 3,
                 runtime=None, shards: int = 1,
                 async_saves: bool = False,
                 on_commit: Optional[Callable[[int, str], None]] = None):
        self.dir = directory
        self.keep = keep
        self.runtime = runtime
        self.shards = max(1, shards)
        self.async_saves = async_saves
        self.on_commit = on_commit
        self._inflight: Optional[threading.Thread] = None
        self._async_exc: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    # save path
    def _encode(self, state: Pytree) -> Tuple[Dict[str, np.ndarray],
                                              List[Dict[str, Any]],
                                              Dict[str, float]]:
        """Flatten + push every leaf through the snapshot tier, gathered to
        host numpy.  Synchronous by design: the caller's next train step
        donates the state buffers, so nothing may reference them after
        this returns."""
        flat = _flatten(state)
        arrays: Dict[str, np.ndarray] = {}
        entries: List[Dict[str, Any]] = []
        raw_total = wire_total = 0.0
        for key, leaf in flat.items():
            if leaf is None:
                continue
            logical_dtype = str(jnp.asarray(leaf).dtype)
            logical_shape = list(np.shape(leaf))
            if self.runtime is not None:
                q, scale = self.runtime.snapshot(jnp.asarray(leaf))
            else:
                q, scale = leaf, None
            q_np = np.asarray(jax.device_get(q))
            arrays[key] = q_np
            entry = {"key": key, "dtype": logical_dtype,
                     "shape": logical_shape,
                     "payload_dtype": str(q_np.dtype),
                     "nbytes": int(q_np.nbytes)}
            raw = float(np.dtype(logical_dtype).itemsize) * \
                float(np.prod(logical_shape or [1]))
            wire = float(q_np.nbytes)
            if scale is not None:
                s_np = np.asarray(jax.device_get(scale))
                arrays[key + _SCALE_SUFFIX] = s_np
                entry["scale_dtype"] = str(s_np.dtype)
                entry["nbytes"] += int(s_np.nbytes)
                wire += float(s_np.nbytes)
            raw_total += raw
            wire_total += wire
            entries.append(entry)
        return arrays, entries, {"raw": raw_total, "wire": wire_total}

    def _assign_shards(self, entries: List[Dict[str, Any]]) -> None:
        """Balance leaves over shard files by cumulative payload bytes."""
        load = [0] * self.shards
        for e in sorted(entries, key=lambda e: -e["nbytes"]):
            s = load.index(min(load))
            e["shard"] = s
            load[s] += e["nbytes"]

    @staticmethod
    def shard_file(index: int) -> str:
        return "arrays.npz" if index == 0 else f"arrays.{index}.npz"

    def _write_commit(self, step: int, arrays: Dict[str, np.ndarray],
                      meta: Dict[str, Any]) -> str:
        """Write shards + manifest into tmp.<step>, then atomically
        commit.  Runs on the async thread when async_saves is set."""
        tmp = os.path.join(self.dir, f"tmp.{step}")
        final = os.path.join(self.dir, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        by_shard: Dict[int, Dict[str, np.ndarray]] = {}
        for e in meta["keys"]:
            sh = by_shard.setdefault(e["shard"], {})
            sh[e["key"]] = arrays[e["key"]]
            if e["key"] + _SCALE_SUFFIX in arrays:
                sh[e["key"] + _SCALE_SUFFIX] = arrays[e["key"] + _SCALE_SUFFIX]
        meta["shards"] = []
        for s in range(self.shards):
            fname = self.shard_file(s)
            path = os.path.join(tmp, fname)
            np.savez(path, **by_shard.get(s, {}))
            with open(path, "rb") as f:
                blob = f.read()
            meta["shards"].append({"file": fname,
                                   "crc32": zlib.crc32(blob) & 0xFFFFFFFF,
                                   "nbytes": len(blob)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        log.info("checkpoint written: %s", final)
        if self.on_commit is not None:
            self.on_commit(step, final)
        return final

    def save(self, step: int, payload: Pytree) -> str:
        """Snapshot ``payload["state"]`` (+ data-iterator state) at
        ``step``.  Returns the final directory (sync) or the directory the
        async commit will land in."""
        self.wait()
        # sweep orphaned tmp dirs a crashed previous save left behind
        for name in os.listdir(self.dir):
            if name.startswith("tmp."):
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)
        arrays, entries, nbytes = self._encode(payload.get("state"))
        meta = {"step": step, "keys": entries, "data": payload.get("data"),
                "bytes": nbytes,
                "codec": getattr(self.runtime, "tier", None) and
                self.runtime.tier.describe() or "none"}
        self._assign_shards(entries)
        final = os.path.join(self.dir, f"step_{step:08d}")
        if not self.async_saves:
            return self._write_commit(step, arrays, meta)

        def _bg():
            try:
                self._write_commit(step, arrays, meta)
            except BaseException as e:      # noqa: BLE001 — re-raised in wait
                self._async_exc = e
        self._inflight = threading.Thread(target=_bg, daemon=True,
                                          name=f"ckpt-save-{step}")
        self._inflight.start()
        return final

    def wait(self) -> None:
        """Join the in-flight async save; re-raise its failure if any."""
        if self._inflight is not None:
            self._inflight.join()
            self._inflight = None
        if self._async_exc is not None:
            exc, self._async_exc = self._async_exc, None
            raise exc

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    # ------------------------------------------------------------------
    # restore path
    def _read_manifest(self, path: str) -> Dict[str, Any]:
        mpath = os.path.join(path, "manifest.json")
        if not os.path.exists(mpath):
            raise CheckpointError(f"{path}: manifest.json missing")
        try:
            with open(mpath) as f:
                return json.load(f)
        except (json.JSONDecodeError, OSError) as e:
            raise CheckpointError(f"{path}: manifest.json unreadable: {e}")

    def _read_shards(self, path: str,
                     meta: Dict[str, Any]) -> Dict[str, np.ndarray]:
        arrays: Dict[str, np.ndarray] = {}
        import io
        for sh in meta.get("shards",
                           [{"file": "arrays.npz", "crc32": None}]):
            spath = os.path.join(path, sh["file"])
            if not os.path.exists(spath):
                raise CheckpointError(f"{path}: shard {sh['file']} missing")
            with open(spath, "rb") as f:
                blob = f.read()
            if sh.get("crc32") is not None and \
                    (zlib.crc32(blob) & 0xFFFFFFFF) != sh["crc32"]:
                raise CheckpointError(
                    f"{path}: shard {sh['file']} CRC mismatch "
                    f"(corrupt or truncated)")
            try:
                with np.load(io.BytesIO(blob)) as z:
                    for k in z.files:
                        arrays[k] = z[k]
            except Exception as e:      # zipfile/format errors vary
                raise CheckpointError(
                    f"{path}: shard {sh['file']} unreadable: {e}")
        return arrays

    def restore(self, step: int) -> Tuple[int, Dict[str, Any]]:
        """Read + validate + decode the snapshot at ``step``.

        Raises :class:`CheckpointError` with the failing file on any
        missing/corrupt manifest or shard — the fault-injection harness
        (train/chaos.py) exercises exactly this path.
        """
        path = os.path.join(self.dir, f"step_{step:08d}")
        if not os.path.isdir(path):
            raise CheckpointError(f"{path}: no such checkpoint")
        meta = self._read_manifest(path)
        if "keys" not in meta:
            raise CheckpointError(f"{path}: manifest has no key table")
        raw = self._read_shards(path, meta)
        flat: Dict[str, Any] = {}
        for e in meta["keys"]:
            key = e["key"]
            if key not in raw:
                raise CheckpointError(
                    f"{path}: leaf {key!r} missing from its shard")
            arr = raw[key]
            pdtype = e.get("payload_dtype", e["dtype"])
            if arr.dtype.kind == "V":   # ml_dtypes (bfloat16/fp8) round-trip
                arr = arr.view(np.dtype(pdtype))
            scale = raw.get(key + _SCALE_SUFFIX)
            if self.runtime is not None:
                from repro.core.tiers import TransferHints
                x = self.runtime.restore_snapshot(
                    (jnp.asarray(arr),
                     None if scale is None else jnp.asarray(scale)),
                    TransferHints(dtype=jnp.dtype(e["dtype"]), name=key))
                flat[key] = np.asarray(jax.device_get(x))
            elif scale is not None:
                # codec payload restored without a tier runtime: decompress
                # directly through the registry (manifest records the stack)
                from repro.core.compress import get_codec
                codec = next((c for c in ("fp8", "int8", "blocksparse")
                              if c in meta.get("codec", "")), None)
                if codec is None:
                    raise CheckpointError(
                        f"{path}: leaf {key!r} is codec-compressed "
                        f"({meta.get('codec')}) but no codec is resolvable")
                x = get_codec(codec).decompress(
                    jnp.asarray(arr), jnp.asarray(scale),
                    jnp.dtype(e["dtype"]))
                flat[key] = np.asarray(jax.device_get(x))
            else:
                flat[key] = arr
        return meta.get("step", step), {"state": flat,
                                        "data": meta.get("data")}

    def restore_latest(self) -> Optional[Tuple[int, Dict[str, Any]]]:
        """Restore the newest checkpoint that validates, skipping (with a
        warning) any step dir with a corrupt manifest or shard."""
        self.wait()
        for step in reversed(self.all_steps()):
            try:
                return self.restore(step)
            except CheckpointError as e:
                log.warning("skipping corrupt checkpoint step %d: %s",
                            step, e)
        return None


# ---------------------------------------------------------------------------
def to_device(flat: Dict[str, np.ndarray], template: Pytree, model=None,
              tc=None) -> Pytree:
    """Rebuild the state pytree from flat arrays, re-sharding onto the
    current mesh (elastic restart: the stored mesh is irrelevant)."""
    shardings = None
    if model is not None and model.mesh is not None and tc is not None:
        from repro.train.train_state import state_shardings
        shardings = _flatten(state_shardings(model, tc))

    flat_template = _flatten(template)
    rebuilt = {}
    for key, leaf in flat_template.items():
        if leaf is None:
            rebuilt[key] = None
            continue
        arr = flat[key]
        want = jnp.dtype(leaf.dtype)
        x = jnp.asarray(arr).astype(want)
        if shardings is not None and key in shardings:
            x = jax.device_put(x, shardings[key])
        rebuilt[key] = x
    return _unflatten_like(template, rebuilt)


def _unflatten_like(template: Pytree, flat: Dict[str, Any]) -> Pytree:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, _ in paths:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)
