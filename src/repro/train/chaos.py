"""Deterministic fault-injection harness for the elastic training stack.

A chaos schedule is a seeded, declarative list of fault events that drive
``train/fault.py``'s retry/SIGTERM paths and ``train/elastic.py``'s
replan-on-stage-loss **in-process** — no containers to kill, every failure
reproducible from ``(spec, seed)``:

  ``kill@K``         the train step at step K raises a
                     :class:`TransientCollectiveError` on its first
                     ``arg`` attempts (default 1) — exercised by
                     ``retry_step``; the retry recomputes the same
                     functional step, so the loss curve is unchanged.
  ``preempt@K``      SIGTERM before step K: delivered as a real signal
                     when the ``FaultHandler`` installed handlers (the
                     launch path), else via its handler directly (tests).
                     The loop checkpoints at the boundary and exits 0.
  ``corrupt@K``      after the first checkpoint committed at/after step
                     K, flip bytes in one snapshot shard (seeded choice
                     unless ``arg`` pins the shard index).  The next
                     ``restore_latest`` must CRC-reject it and fall back.
  ``stage_loss@K``   before step K, raise :class:`StageLostError` (stage
                     index ``arg``): the loop hands it to the
                     ``ElasticController`` which replans n_micro/stages
                     via ``plan_memory`` and restores from the pool.

Spec grammar: ``"kill@3,corrupt@5,stage_loss@7:1,preempt@9"`` — comma
separated ``kind@step[:arg]``.  :meth:`ChaosSchedule.random` draws a
schedule from per-kind rates with a seeded RNG instead.

Injected failures raise *before* the jitted step dispatches, so donated
input buffers are never invalidated mid-execution — retry semantics stay
exact (a real mid-collective XLA fault would instead surface through the
restart-from-checkpoint path, which ``preempt`` + ``corrupt`` cover).
"""
from __future__ import annotations

import dataclasses
import logging
import os
import random
import signal
from typing import Dict, List, Optional

log = logging.getLogger(__name__)


class TransientCollectiveError(RuntimeError):
    """An injected transient step failure (the XLA collective-error
    analogue); ``retry_step`` absorbs it."""


class StageLostError(RuntimeError):
    """A pipeline stage dropped out mid-run."""

    def __init__(self, stage: int):
        super().__init__(f"pipeline stage {stage} lost")
        self.stage = stage


@dataclasses.dataclass
class ChaosEvent:
    step: int
    kind: str                    # kill | preempt | corrupt | stage_loss
    arg: int = -1                # kill: failed attempts (-1 -> 1);
    #                              stage_loss: stage idx (-1 -> last);
    #                              corrupt: shard idx (-1 -> seeded)
    fired: bool = False


KINDS = ("kill", "preempt", "corrupt", "stage_loss")


@dataclasses.dataclass
class ChaosSchedule:
    events: List[ChaosEvent]

    @classmethod
    def parse(cls, spec: str) -> "ChaosSchedule":
        """``"kill@3,corrupt@5,stage_loss@7:1"`` -> schedule."""
        events = []
        for part in filter(None, (p.strip() for p in spec.split(","))):
            kind, _, rest = part.partition("@")
            if kind not in KINDS:
                raise ValueError(f"unknown chaos kind {kind!r} "
                                 f"(one of {KINDS}) in {spec!r}")
            step_s, _, arg_s = rest.partition(":")
            try:
                step = int(step_s)
                arg = int(arg_s) if arg_s else -1
            except ValueError:
                raise ValueError(f"bad chaos event {part!r} in {spec!r}")
            events.append(ChaosEvent(step=step, kind=kind, arg=arg))
        return cls(sorted(events, key=lambda e: e.step))

    @classmethod
    def random(cls, seed: int, n_steps: int,
               rates: Optional[Dict[str, float]] = None) -> "ChaosSchedule":
        """Draw a schedule from per-kind per-step probabilities with a
        seeded RNG — same ``(seed, n_steps, rates)`` -> same schedule."""
        rng = random.Random(seed)
        rates = rates or {"kill": 0.05, "preempt": 0.0,
                          "corrupt": 0.02, "stage_loss": 0.01}
        events = []
        for step in range(n_steps):
            for kind in KINDS:
                if rng.random() < rates.get(kind, 0.0):
                    events.append(ChaosEvent(step=step, kind=kind))
        return cls(events)

    def spec(self) -> str:
        return ",".join(f"{e.kind}@{e.step}" +
                        (f":{e.arg}" if e.arg >= 0 else "")
                        for e in self.events)


class ChaosMonkey:
    """Executes a :class:`ChaosSchedule` against the training loop.

    The loop calls three hooks: :meth:`before_step` (may raise
    :class:`StageLostError` or request preemption), :meth:`wrap_step`
    (arms kill events against the jitted step), and :meth:`after_save`
    (corrupts a committed snapshot shard).  ``fired`` records every event
    actually delivered, for tests and the exit log.
    """

    def __init__(self, schedule: ChaosSchedule, seed: int = 0,
                 retries: int = 2, backoff: float = 0.0):
        self.schedule = schedule
        self.rng = random.Random(seed)
        self.retries = retries          # loop-side retry_step budget
        self.backoff = backoff
        self.fired: List[str] = []
        self._kill_remaining: Dict[int, int] = {}
        for e in schedule.events:
            if e.kind == "kill":
                self._kill_remaining[e.step] = max(1, e.arg)

    # ------------------------------------------------------------------
    def before_step(self, step_idx: int, fault_handler=None) -> None:
        for e in self.schedule.events:
            if e.fired or e.step != step_idx:
                continue
            if e.kind == "stage_loss":
                e.fired = True
                stage = e.arg   # -1 -> resolved by the elastic controller
                self.fired.append(f"stage_loss@{step_idx}")
                log.warning("chaos: dropping pipeline stage %d before "
                            "step %d", stage, step_idx)
                raise StageLostError(stage)
            if e.kind == "preempt":
                e.fired = True
                self.fired.append(f"preempt@{step_idx}")
                log.warning("chaos: preempting before step %d", step_idx)
                if fault_handler is not None and \
                        getattr(fault_handler, "_prev", None):
                    os.kill(os.getpid(), signal.SIGTERM)
                elif fault_handler is not None:
                    fault_handler._handle(signal.SIGTERM, None)

    def wrap_step(self, step_fn, step_idx: int):
        """Arm the kill events for this step: the wrapped step raises a
        :class:`TransientCollectiveError` on its first ``arg`` attempts
        (before the jitted function dispatches — donation-safe), then
        passes through."""
        if self._kill_remaining.get(step_idx, 0) <= 0:
            return step_fn

        def wrapped(state, batch):
            if self._kill_remaining.get(step_idx, 0) > 0:
                self._kill_remaining[step_idx] -= 1
                self.fired.append(f"kill@{step_idx}")
                raise TransientCollectiveError(
                    f"injected collective failure at step {step_idx}")
            return step_fn(state, batch)
        return wrapped

    def after_save(self, step: int, path: str) -> None:
        """Corrupt one shard of the checkpoint just committed at ``path``
        when a pending ``corrupt`` event is due (event step <= saved
        step).  Usable directly as ``CheckpointManager.on_commit``."""
        for e in self.schedule.events:
            if e.fired or e.kind != "corrupt" or e.step > step:
                continue
            e.fired = True
            def shard_index(name):      # arrays.npz is shard 0, arrays.N.npz is N
                parts = name.split(".")
                return int(parts[1]) if len(parts) == 3 else 0
            shards = sorted((n for n in os.listdir(path)
                             if n.startswith("arrays") and n.endswith(".npz")),
                            key=shard_index)
            if not shards:
                continue
            target = shards[e.arg % len(shards)] if e.arg >= 0 \
                else self.rng.choice(shards)
            fpath = os.path.join(path, target)
            size = os.path.getsize(fpath)
            offset = self.rng.randrange(max(1, size))
            with open(fpath, "r+b") as f:
                f.seek(offset)
                b = f.read(1)
                f.seek(offset)
                f.write(bytes([(b[0] ^ 0xFF) if b else 0xFF]))
            self.fired.append(f"corrupt@{step}:{target}")
            log.warning("chaos: corrupted %s byte %d of checkpoint %s",
                        target, offset, path)

    def summary(self) -> str:
        return ",".join(self.fired) or "none"
