"""Training substrate: optimizer, state, loop, checkpoints, fault handling."""
