"""Training loop: jitted train_step builder + the driver with gradient
accumulation, checkpointing, fault handling, and metrics."""
from __future__ import annotations

import logging
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.models.model import Model
from repro.parallel.pipeline import accumulate_microbatches
from repro.train import chaos as chaos_mod
from repro.train import checkpoint as ckpt_mod
from repro.train import fault as fault_mod
from repro.train.optimizer import apply_adamw
from repro.train.train_state import init_state, state_shardings

log = logging.getLogger(__name__)
Pytree = Any


# ---------------------------------------------------------------------------
def make_train_step(model: Model, tc: TrainConfig
                    ) -> Callable[[Pytree, Dict[str, jax.Array]],
                                  Tuple[Pytree, Dict[str, jax.Array]]]:
    """(state, batch) -> (state, metrics).

    Microbatching runs on one schedule path (parallel/pipeline.py): a
    pipeline-enabled model microbatches *inside* its pipelined forward
    (``model.pipeline.n_micro`` over the stage mesh), while gradient
    accumulation (``tc.grad_accum > 1``) is the degenerate single-stage
    schedule — microbatches scanned sequentially with gradients averaged
    and metrics accumulated across microbatches
    (:func:`repro.parallel.pipeline.accumulate_microbatches`).
    """

    def loss(params, batch):
        return model.loss_fn(params, batch)

    pipelined = (getattr(model, "pipeline", None) is not None
                 and model.pipeline.enabled)

    def grads_of(params, batch):
        if tc.grad_accum <= 1 or pipelined:
            (l, metrics), g = jax.value_and_grad(loss, has_aux=True)(
                params, batch)
            return g, l, metrics
        return accumulate_microbatches(loss, params, batch, tc.grad_accum)

    def train_step(state, batch):
        g, l, metrics = grads_of(state["params"], batch)
        params, opt, opt_metrics = apply_adamw(state["params"], g,
                                               state["opt"], tc)
        new_state = dict(state, params=params, opt=opt,
                         step=state["step"] + 1)
        metrics = dict(metrics, **opt_metrics)
        return new_state, metrics

    return train_step


def jit_train_step(model: Model, tc: TrainConfig, batch_shardings=None):
    step = make_train_step(model, tc)
    if model.mesh is None:
        return jax.jit(step, donate_argnums=0)
    shardings = state_shardings(model, tc)
    return jax.jit(step,
                   in_shardings=(shardings, batch_shardings),
                   out_shardings=(shardings, None),
                   donate_argnums=0)


# ---------------------------------------------------------------------------
def make_manager(model: Model, tc: TrainConfig, ckpt=None, chaos=None
                 ) -> ckpt_mod.CheckpointManager:
    """Build the run's CheckpointManager: tier-backed + metered when a
    :class:`~repro.configs.base.CheckpointPlan` is enabled, the legacy
    direct writer otherwise.  The chaos harness's shard corruptor rides
    on the manager's post-commit hook."""
    on_commit = chaos.after_save if chaos is not None else None
    if ckpt is None or not ckpt.enabled:
        return ckpt_mod.CheckpointManager(tc.checkpoint_dir,
                                          keep=tc.keep_checkpoints,
                                          on_commit=on_commit)
    runtime = ckpt_mod.make_ckpt_runtime(ckpt, model.plan, model.memory,
                                         planner=model.planner,
                                         mesh=model.mesh,
                                         keep=tc.keep_checkpoints)
    return ckpt_mod.CheckpointManager(tc.checkpoint_dir,
                                      keep=tc.keep_checkpoints,
                                      runtime=runtime, shards=ckpt.shards,
                                      async_saves=ckpt.async_saves,
                                      on_commit=on_commit)


def train(model: Model, tc: TrainConfig, data_iter, *,
          state: Optional[Pytree] = None,
          fault_handler=None,
          hooks: Optional[Dict[str, Callable]] = None,
          ckpt=None, chaos=None, elastic=None, mgr=None
          ) -> Tuple[Pytree, Dict[str, jax.Array]]:
    """The end-to-end driver (examples/train_*.py).

    data_iter: yields (step_idx, batch) — resumable via its own state.
    fault_handler: train.fault.FaultHandler (SIGTERM-safe checkpointing).
    ckpt: optional :class:`~repro.configs.base.CheckpointPlan` — snapshots
      then flow through the checkpoint tier (metered ``ckpt_save`` /
      ``ckpt_load``), sharded + CRC-manifested, optionally async.
    chaos: optional :class:`~repro.train.chaos.ChaosMonkey` — injects the
      scheduled kills (absorbed by ``retry_step``), preemptions (the
      SIGTERM path), shard corruptions and stage losses.
    elastic: optional :class:`~repro.train.elastic.ElasticController` —
      on a stage loss, replans the pipeline for the surviving stages and
      restores from the checkpoint tier; without it a stage loss is
      fatal.
    mgr: override the CheckpointManager (tests wiring custom runtimes).

    Returns ``(state, metrics)``: the final train state and the last
    step's metrics.  On exit it logs the memory-tier traffic summary, the
    stage tier's ``act_stash``/``act_fetch`` traffic for pipelined runs,
    and the checkpoint tier's ``ckpt_save``/``ckpt_load`` traffic.
    """
    hooks = hooks or {}
    step_fn = jit_train_step(model, tc)
    if mgr is None:
        mgr = make_manager(model, tc, ckpt, chaos)
    ckpt_every = (ckpt.every if ckpt is not None and ckpt.every > 0
                  else tc.checkpoint_every)

    start_step = 0
    if state is None:
        restored = mgr.restore_latest()
        if restored is not None:
            start_step, payload = restored
            template = jax.eval_shape(
                lambda: init_state(model, tc, jax.random.PRNGKey(tc.seed)))
            state = ckpt_mod.to_device(payload["state"], template, model, tc)
            if hasattr(data_iter, "set_state") and "data" in payload:
                data_iter.set_state(payload["data"])
            log.info("resumed from step %d", start_step)
        else:
            state = init_state(model, tc)

    times = []
    metrics = {}
    for step_idx, batch in data_iter:
        if step_idx < start_step:
            continue
        if step_idx >= tc.total_steps:
            break
        if chaos is not None:
            try:
                chaos.before_step(step_idx, fault_handler)
            except chaos_mod.StageLostError as err:
                if elastic is None:
                    raise
                model, state, start_step = elastic.recover(
                    tc, data_iter, err.stage)
                step_fn = jit_train_step(model, tc)
                continue
        t0 = time.perf_counter()
        if chaos is not None:
            state, metrics = fault_mod.retry_step(
                chaos.wrap_step(step_fn, step_idx), state, batch,
                retries=chaos.retries, backoff=chaos.backoff)
        else:
            state, metrics = step_fn(state, batch)
        if fault_handler is not None:
            fault_handler.observe_step(time.perf_counter() - t0)
        times.append(time.perf_counter() - t0)

        done = step_idx + 1
        if done % tc.log_every == 0:
            m = {k: float(v) for k, v in metrics.items()}
            log.info("step %d loss=%.4f grad_norm=%.3f lr=%.2e (%.1f ms)",
                     done, m.get("loss", -1), m.get("grad_norm", -1),
                     m.get("lr", 0), 1e3 * times[-1])
            if "on_log" in hooks:
                hooks["on_log"](done, m)
        save_now = (done % ckpt_every == 0)
        if fault_handler is not None and fault_handler.should_stop:
            save_now = True
        if save_now:
            data_state = (data_iter.get_state()
                          if hasattr(data_iter, "get_state") else None)
            mgr.save(done, {"state": state, "data": data_state})
        if fault_handler is not None and fault_handler.should_stop:
            mgr.wait()      # the preemption checkpoint must land on disk
            log.warning("preemption requested — checkpoint written, exiting")
            break
    mgr.wait()
    runtime = getattr(model, "runtime", None)
    if runtime is not None and runtime.offloads:
        log.info("memory traffic: %s", runtime.traffic_summary())
    stage_runtime = getattr(model, "stage_runtime", None)
    if stage_runtime is not None and stage_runtime.offloads:
        log.info("pipeline traffic: %s", stage_runtime.traffic_summary())
    if mgr.runtime is not None:
        log.info("checkpoint traffic: %s", mgr.runtime.traffic_summary())
    if chaos is not None:
        log.info("chaos events fired: %s", chaos.summary())
    return state, metrics
