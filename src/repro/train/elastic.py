"""Elastic recovery: replan, reshard, and resume after losing a stage.

The restart-with-reshard primitive everything in ``train/fault.py``
reduces to, made available *mid-run*: when the chaos harness (or a real
collective failure surfaced as :class:`~repro.train.chaos.StageLostError`)
drops a pipeline stage, the :class:`ElasticController`

  1. waits out any in-flight async snapshot, then shrinks the pipeline
     plan to the surviving stages (``n_stages - 1``; a 2-stage run
     degrades to the sequential single-stage schedule),
  2. rebuilds the model through :func:`~repro.models.model.build_model`,
     which re-runs the ``plan_memory`` bubble-vs-stall sweep so
     ``n_micro`` and the per-stage KEEP/POOL/RECOMPUTE split are replanned
     for the new stage count (``n_micro=0`` → planner-chosen),
  3. restores the newest validating snapshot from the checkpoint tier —
     ``to_device`` re-shards the stored full arrays under the *new*
     model's shardings (reshard-on-load), corrupt snapshots are CRC-
     skipped — and rewinds the data iterator to the restored step,
  4. hands ``(model, state, start_step)`` back to the loop, which re-jits
     the train step and replays forward deterministically.

Steps replayed after restore recompute the same batches (the data
iterator is a pure function of ``(seed, step)``), so a same-config resume
is bit-identical; a changed stage partition replays the same *math* under
a different reduction order (loss parity within float tolerance — pinned
by tests/multidev/elastic.py).
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Optional, Tuple

import jax
import numpy as np

log = logging.getLogger(__name__)


class ElasticController:
    """Owns the run description + checkpoint manager needed to rebuild.

    run: the full :class:`~repro.configs.base.RunConfig` of the current
    model (the controller keeps it updated as stages are lost).
    mgr: the :class:`~repro.train.checkpoint.CheckpointManager` to restore
    through (its runtime meters the ``ckpt_load`` traffic).
    """

    def __init__(self, run, mgr, mesh=None, pipe_mesh=None):
        self.run = run
        self.mgr = mgr
        self.mesh = mesh
        self.pipe_mesh = pipe_mesh
        self.recoveries = 0

    # ------------------------------------------------------------------
    def surviving_stages(self, lost_stage: int) -> int:
        pipe = self.run.pipeline
        s_old = pipe.n_stages if pipe.enabled else 1
        return max(1, s_old - 1)

    def _shrink_pipe_mesh(self, s_new: int, lost_stage: int):
        if s_new <= 1 or self.pipe_mesh is None:
            return None
        from jax.sharding import Mesh
        devs = list(self.pipe_mesh.devices.flatten())
        if 0 <= lost_stage < len(devs):
            devs.pop(lost_stage)
        axis = self.run.pipeline.axis_name
        return Mesh(np.array(devs[:s_new]), (axis,))

    def recover(self, tc, data_iter, lost_stage: int
                ) -> Tuple[object, object, int]:
        """Rebuild for the surviving stages and restore from the pool.

        Returns ``(model, state, start_step)``; the caller re-jits its
        step function against the new model.
        """
        from repro.models.model import build_model
        from repro.train.checkpoint import to_device
        from repro.train.train_state import init_state

        self.mgr.wait()
        pipe = self.run.pipeline
        s_new = self.surviving_stages(lost_stage)
        if pipe.enabled:
            # S=1 still runs the schedule's local path (microbatched),
            # so the plan stays enabled with the stage count shrunk
            new_pipe = dataclasses.replace(pipe, n_stages=s_new, n_micro=0)
            self.pipe_mesh = self._shrink_pipe_mesh(s_new, lost_stage)
            self.run = dataclasses.replace(self.run, pipeline=new_pipe)
        log.warning("elastic: lost stage %d -> replanning for %d stage(s)",
                    lost_stage, s_new)
        model = build_model(self.run, mesh=self.mesh,
                            pipe_mesh=self.pipe_mesh)
        if model.pipeline_report is not None:
            from repro.core.policy import summarize
            log.info("elastic replan: %s", summarize(model.pipeline_report))

        restored = self.mgr.restore_latest()
        if restored is None:
            log.warning("elastic: no validating checkpoint — restarting "
                        "from initialization")
            state, start_step = init_state(model, tc), 0
        else:
            start_step, payload = restored
            template = jax.eval_shape(
                lambda: init_state(model, tc, jax.random.PRNGKey(tc.seed)))
            state = to_device(payload["state"], template, model, tc)
            log.info("elastic: restored step %d from %s", start_step,
                     self.mgr.runtime.tier.describe()
                     if self.mgr.runtime else "local files")
        if hasattr(data_iter, "set_state"):
            if restored is not None and (restored[1].get("data") or None):
                data_iter.set_state(restored[1]["data"])
            elif hasattr(data_iter, "get_state"):
                ds = dict(data_iter.get_state())
                ds["step"] = start_step
                data_iter.set_state(ds)
        self.recoveries += 1
        return model, state, start_step
