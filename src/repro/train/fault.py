"""Fault tolerance: preemption handling, auto-resume, straggler mitigation.

Designed for the 1000+-node regime (DESIGN.md §5):

* **Preemption / node failure**: SIGTERM (the cloud preemption signal) sets
  a stop flag; the training loop checkpoints at the next step boundary and
  exits 0.  On restart, ``CheckpointManager.restore_latest`` + reshard-on-
  load resume bit-exact (data-pipeline state is in the checkpoint), on the
  *same or a different* mesh — losing a pod means restarting on the
  remaining ones with the identical checkpoint (elastic scaling).

* **Straggler mitigation**: ``StragglerMonitor`` keeps a rolling step-time
  median; a step slower than ``threshold x median`` is flagged.  In a
  multi-pod deployment the flag feeds the synchronous-with-backup policy:
  the launcher (launch/train.py) holds hot-spare hosts, and a persistently
  flagged host is replaced at the next checkpoint boundary — this is a
  *coordination* policy, so the in-process component is detection + the
  decision callback; the replace itself is the restart path above (which is
  why restart-with-reshard is the primitive everything reduces to).

* **In-step retries**: transient collective failures surface as XLA errors;
  ``retry_step`` re-executes the step function (idempotent: state is only
  replaced on success — functional updates make retry safe).
"""
from __future__ import annotations

import logging
import signal
import statistics
import time
from typing import Callable, List, Optional

log = logging.getLogger(__name__)


class FaultHandler:
    """SIGTERM/SIGINT-safe stop flag + straggler detection."""

    def __init__(self, straggler_threshold: float = 3.0,
                 window: int = 50,
                 on_straggler: Optional[Callable[[float, float], None]] = None,
                 install_signals: bool = True):
        self.should_stop = False
        self.monitor = StragglerMonitor(straggler_threshold, window,
                                        on_straggler)
        self._prev = {}
        if install_signals:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev[sig] = signal.signal(sig, self._handle)
                except ValueError:      # non-main thread (tests)
                    pass

    def _handle(self, signum, frame):
        log.warning("signal %s received — requesting clean stop", signum)
        self.should_stop = True

    def observe_step(self, seconds: float) -> bool:
        return self.monitor.observe(seconds)

    def uninstall(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


class StragglerMonitor:
    def __init__(self, threshold: float = 3.0, window: int = 50,
                 on_straggler: Optional[Callable[[float, float], None]] = None):
        self.threshold = threshold
        self.window = window
        self.on_straggler = on_straggler
        self.times: List[float] = []
        self.flagged = 0

    def observe(self, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        is_straggler = False
        if len(self.times) >= 10:
            med = statistics.median(self.times[-self.window:])
            if seconds > self.threshold * med:
                is_straggler = True
                self.flagged += 1
                log.warning("straggler step: %.1f ms vs median %.1f ms",
                            1e3 * seconds, 1e3 * med)
                if self.on_straggler:
                    self.on_straggler(seconds, med)
        self.times.append(seconds)
        if len(self.times) > 4 * self.window:
            self.times = self.times[-2 * self.window:]
        return is_straggler


def retry_step(step_fn, state, batch, retries: int = 2, backoff: float = 0.5,
               sleep=time.sleep):
    """Execute a functional train step with retry — safe because the state
    is only replaced by the successful result.

    The terminal failure raises immediately: no backoff sleep after the
    last attempt (it used to waste ``backoff * 2**retries`` seconds on
    every step that was going to raise anyway).  ``sleep`` is injectable
    for tests with a fake clock.
    """
    err = None
    for attempt in range(retries + 1):
        try:
            return step_fn(state, batch)
        except Exception as e:          # noqa: BLE001 — surface after retries
            err = e
            log.warning("step failed (attempt %d/%d): %s",
                        attempt + 1, retries + 1, e)
            if attempt < retries:
                sleep(backoff * (2 ** attempt))
    raise err
