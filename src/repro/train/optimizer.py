"""AdamW with optional 8-bit moment quantization (beyond-paper).

No optax in this environment — the optimizer is implemented directly.
The 8-bit mode stores both Adam moments as int8 with a per-row fp32 scale
(row = leading dims, blocked over the last axis), shrinking optimizer state
from 8 bytes/param to ~2 — this is what lets llama4-maverick-400b's training
state fit a single 256-chip pod (DESIGN.md §2 capacity math).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import TrainConfig

Pytree = Any
INT8_MAX = 127.0


# ---------------------------------------------------------------------------
# 8-bit moment quantization.
#   m (signed, zero-centred): per-row absmax linear int8.
#   v (non-negative, huge dynamic range): per-row *log-domain* int8 — linear
#   quantization underflows small v entries to 0 and Adam's m/(sqrt(v)+eps)
#   explodes; quantizing log(v) bounds the relative error instead (the same
#   reason bitsandbytes uses dynamic-exponent quantization).
_V_FLOOR = 1e-16


def _q8(x: jax.Array) -> Dict[str, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True),
                        1e-30) / INT8_MAX
    q = jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _dq8(s: Dict[str, jax.Array]) -> jax.Array:
    return s["q"].astype(jnp.float32) * s["scale"]


def _q8_log(x: jax.Array) -> Dict[str, jax.Array]:
    lx = jnp.log(jnp.maximum(x, _V_FLOOR))
    lo = jnp.min(lx, axis=-1, keepdims=True)
    hi = jnp.max(lx, axis=-1, keepdims=True)
    span = jnp.maximum(hi - lo, 1e-6)
    q = jnp.clip(jnp.round((lx - lo) / span * 254.0 - 127.0),
                 -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return {"q": q, "lo": lo.astype(jnp.float32), "hi": hi.astype(jnp.float32)}


def _dq8_log(s: Dict[str, jax.Array]) -> jax.Array:
    span = jnp.maximum(s["hi"] - s["lo"], 1e-6)
    lx = s["lo"] + (s["q"].astype(jnp.float32) + 127.0) / 254.0 * span
    v = jnp.exp(lx)
    return jnp.where(v <= _V_FLOOR * 1.01, 0.0, v)


def _is_q8(leaf) -> bool:
    return isinstance(leaf, dict) and set(leaf) in ({"q", "scale"},
                                                    {"q", "lo", "hi"})


def _dq_any(leaf) -> jax.Array:
    return _dq8_log(leaf) if "lo" in leaf else _dq8(leaf)


# ---------------------------------------------------------------------------
def init_opt_state(params: Pytree, bits: int = 32) -> Pytree:
    def zero_m(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _q8(z) if (bits == 8 and p.ndim >= 1) else z

    def zero_v(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _q8_log(z) if (bits == 8 and p.ndim >= 1) else z

    return {
        "m": jax.tree.map(zero_m, params),
        "v": jax.tree.map(zero_v, params),
        "count": jnp.zeros((), jnp.int32),
    }


def opt_state_specs(param_specs: Pytree, bits: int = 32) -> Pytree:
    """Moment sharding mirrors the parameter sharding (per-row scales drop
    the last dim's axis)."""

    def like_m(sp: P):
        if bits != 8:
            return sp
        parts = tuple(sp)
        row = P(*(parts[:-1] + (None,))) if parts else P()
        return {"q": sp, "scale": row}

    def like_v(sp: P):
        if bits != 8:
            return sp
        parts = tuple(sp)
        row = P(*(parts[:-1] + (None,))) if parts else P()
        return {"q": sp, "lo": row, "hi": row}

    return {
        "m": jax.tree.map(like_m, param_specs, is_leaf=lambda v: isinstance(v, P)),
        "v": jax.tree.map(like_v, param_specs, is_leaf=lambda v: isinstance(v, P)),
        "count": P(),
    }


# ---------------------------------------------------------------------------
def lr_schedule(tc: TrainConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to 10%."""
    warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - tc.warmup_steps)
                    / jnp.maximum(tc.total_steps - tc.warmup_steps, 1), 0, 1)
    cos = 0.1 + 0.45 * (1 + jnp.cos(jnp.pi * prog))
    return tc.learning_rate * warm * cos


def global_norm(tree: Pytree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def apply_adamw(params: Pytree, grads: Pytree, state: Pytree,
                tc: TrainConfig) -> Tuple[Pytree, Pytree, Dict[str, jax.Array]]:
    """One AdamW step with global-norm clipping.  Returns (params, state,
    metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, tc.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if tc.grad_clip > 0 else 1.0
    lr = lr_schedule(tc, count)
    b1, b2, eps = tc.beta1, tc.beta2, tc.eps
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = tree.flatten_up_to(state["m"])
    flat_v = tree.flatten_up_to(state["v"])

    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        g = g.astype(jnp.float32) * clip
        mq, vq = _is_q8(m), _is_q8(v)
        m_f = _dq_any(m) if mq else m
        v_f = _dq_any(v) if vq else v
        m_f = b1 * m_f + (1 - b1) * g
        v_f = b2 * v_f + (1 - b2) * jnp.square(g)
        upd = (m_f / bc1) / (jnp.sqrt(v_f / bc2) + eps)
        if p.ndim >= 1:   # decoupled weight decay (skip scalars/norms)
            upd = upd + tc.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_m.append(_q8(m_f) if mq else m_f)
        new_v.append(_q8_log(v_f) if vq else v_f)

    metrics = {"grad_norm": gnorm, "lr": lr}
    return (tree.unflatten(new_p),
            {"m": tree.unflatten(new_m), "v": tree.unflatten(new_v),
             "count": count},
            metrics)
