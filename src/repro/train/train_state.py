"""Train state: params + optimizer moments + step + compression error."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import TrainConfig
from repro.models.model import Model
from repro.train.optimizer import init_opt_state, opt_state_specs

Pytree = Any


def init_state(model: Model, tc: TrainConfig, key=None) -> Pytree:
    params = model.init(key if key is not None else
                        jax.random.PRNGKey(tc.seed))
    state = {
        "params": params,
        "opt": init_opt_state(params, model.memory.opt_state_bits),
        "step": jnp.zeros((), jnp.int32),
    }
    if tc.grad_compress == "int8":
        state["ef_err"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def state_specs(model: Model, tc: TrainConfig) -> Pytree:
    pspecs = model.param_specs()
    s = {
        "params": pspecs,
        "opt": opt_state_specs(pspecs, model.memory.opt_state_bits),
        "step": P(),
    }
    if tc.grad_compress == "int8":
        s["ef_err"] = pspecs
    return s


def state_shardings(model: Model, tc: TrainConfig) -> Pytree:
    assert model.mesh is not None
    return jax.tree.map(lambda sp: NamedSharding(model.mesh, sp),
                        state_specs(model, tc),
                        is_leaf=lambda v: isinstance(v, P))


def abstract_state(model: Model, tc: TrainConfig) -> Pytree:
    """ShapeDtypeStruct state for the dry-run (no allocation)."""
    return jax.eval_shape(lambda: init_state(model, tc,
                                             jax.random.PRNGKey(0)))
