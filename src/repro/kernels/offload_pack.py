"""Fused fp8 quantize+pack for the stash path — the memory-node's
"compression ASIC" (paper §III-A, Fig. 6) realised as a Pallas kernel.

Quantizes a (rows, cols) activation to float8_e4m3fn with a per-row-block
absmax scale in a single VMEM pass, halving the bytes that cross the ICI
into the pool.  Blockwise scales (vs core.compress's per-tensor scale)
bound the quantization error per block — a strictly better trade at zero
extra traffic (one f32 per block).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

FP8_MAX = 448.0
INT8_MAX = 127.0
BLOCKSPARSE_TAU = 32.0   # prune |x| < block_absmax / TAU to exact zero


def _pack_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(absmax / FP8_MAX, 1e-12)
    q_ref[...] = (x / scale).astype(q_ref.dtype)
    s_ref[0, 0] = scale


def _int8_pack_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(absmax / INT8_MAX, 1e-30)
    q_ref[...] = jnp.clip(jnp.round(x / scale),
                          -INT8_MAX, INT8_MAX).astype(q_ref.dtype)
    s_ref[0, 0] = scale


def _blocksparse_pack_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(absmax / INT8_MAX, 1e-30)
    q = jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX)
    keep = jnp.abs(x) >= absmax / BLOCKSPARSE_TAU
    q_ref[...] = jnp.where(keep, q, 0.0).astype(q_ref.dtype)
    s_ref[0, 0] = scale


def _unpack_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = (q_ref[...].astype(jnp.float32)
                  * s_ref[0, 0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fp8_pack(x: jax.Array, *, block_rows: int = 128,
             interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: (R, C) -> (q: fp8 (R, C), scales: f32 (R//block_rows,))."""
    R, C = x.shape
    assert R % block_rows == 0, (R, block_rows)
    nb = R // block_rows
    q, s = pl.pallas_call(
        _pack_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_rows, C), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), jnp.float8_e4m3fn),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, s[:, 0]


@functools.partial(jax.jit, static_argnames=("block_rows", "dtype",
                                             "interpret"))
def fp8_unpack(q: jax.Array, scales: jax.Array, *, block_rows: int = 128,
               dtype=jnp.bfloat16, interpret: bool = False) -> jax.Array:
    R, C = q.shape
    nb = R // block_rows
    return pl.pallas_call(
        _unpack_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), dtype),
        interpret=interpret,
    )(q, scales[:, None])


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def int8_pack(x: jax.Array, *, block_rows: int = 128,
              interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: (R, C) -> (q: int8 (R, C), scales: f32 (R//block_rows,)).

    The int8 codec twin of :func:`fp8_pack` — same per-row-block absmax
    scaling, round-and-clip instead of fp8 cast (int8 has no subnormals,
    so the round is explicit)."""
    R, C = x.shape
    assert R % block_rows == 0, (R, block_rows)
    nb = R // block_rows
    q, s = pl.pallas_call(
        _int8_pack_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_rows, C), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), jnp.int8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, s[:, 0]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def blocksparse_pack(x: jax.Array, *, block_rows: int = 128,
                     interpret: bool = False
                     ) -> Tuple[jax.Array, jax.Array]:
    """x: (R, C) -> (q: int8 (R, C) with small entries pruned, scales f32).

    The block-sparse codec twin: per-row-block int8 quantization (as
    :func:`int8_pack`) plus in-block magnitude pruning — entries below
    ``absmax / BLOCKSPARSE_TAU`` become *exact* zeros, so a run-length /
    entropy stage on the wire (the memory node's compression ASIC,
    §III-A) sees dense zero runs.  Decode needs no sparsity metadata: the
    zeros dequantize to zero through the shared unpack twin.
    """
    R, C = x.shape
    assert R % block_rows == 0, (R, block_rows)
    nb = R // block_rows
    q, s = pl.pallas_call(
        _blocksparse_pack_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_rows, C), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), jnp.int8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, s[:, 0]


#: dequantize-by-scale has no dtype-specific logic — the int8 and
#: blocksparse unpack twins ARE the fp8 one (kernels/ref.py delegates
#: identically; pruned zeros dequantize to zero by construction)
int8_unpack = fp8_unpack
blocksparse_unpack = fp8_unpack
