"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
def gemm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (M, K) @ w: (K, N) -> (M, N), f32 accumulation."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  scale: Optional[float] = None) -> jax.Array:
    """q: (B, H, S, d); k/v: (B, Hkv, T, d); GQA via head repeat.

    Dense softmax reference (materializes S x T — small tests only).
    """
    B, H, S, d = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    if H != Hkv:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= q_pos >= k_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


# ---------------------------------------------------------------------------
def ssd_ref(x: jax.Array, a: jax.Array, B: jax.Array, C: jax.Array
            ) -> Tuple[jax.Array, jax.Array]:
    """Single-(batch,head) SSD recurrence oracle.

    x: (S, P) inputs (already dt-scaled), a: (S,) log-decay per step,
    B: (S, N), C: (S, N).  Returns (y: (S, P), final state (P, N)).
    """
    S, P = x.shape
    N = B.shape[1]

    def step(state, t):
        xt, at, Bt, Ct = t
        state = state * jnp.exp(at) + jnp.outer(xt, Bt)
        return state, state @ Ct

    xs = (x.astype(jnp.float32), a.astype(jnp.float32),
          B.astype(jnp.float32), C.astype(jnp.float32))
    final, y = jax.lax.scan(step, jnp.zeros((P, N), jnp.float32), xs)
    return y.astype(x.dtype), final


# ---------------------------------------------------------------------------
def fp8_pack_ref(x: jax.Array, block_rows: int
                 ) -> Tuple[jax.Array, jax.Array]:
    """Blockwise fp8 quantize: x (R, C) -> (q fp8 (R, C), scales (R/br,))."""
    R, C = x.shape
    nb = R // block_rows
    xb = x.reshape(nb, block_rows, C).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xb), axis=(1, 2))
    scale = jnp.maximum(absmax / 448.0, 1e-12)
    q = (xb / scale[:, None, None]).astype(jnp.float8_e4m3fn)
    return q.reshape(R, C), scale


def fp8_unpack_ref(q: jax.Array, scale: jax.Array, block_rows: int,
                   dtype=jnp.bfloat16) -> jax.Array:
    R, C = q.shape
    nb = R // block_rows
    xb = q.reshape(nb, block_rows, C).astype(jnp.float32)
    return (xb * scale[:, None, None]).reshape(R, C).astype(dtype)


def int8_pack_ref(x: jax.Array, block_rows: int
                  ) -> Tuple[jax.Array, jax.Array]:
    """Blockwise int8 quantize: x (R, C) -> (q int8 (R, C), scales (R/br,))."""
    R, C = x.shape
    nb = R // block_rows
    xb = x.reshape(nb, block_rows, C).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xb), axis=(1, 2))
    scale = jnp.maximum(absmax / 127.0, 1e-30)
    q = jnp.clip(jnp.round(xb / scale[:, None, None]),
                 -127.0, 127.0).astype(jnp.int8)
    return q.reshape(R, C), scale


def int8_unpack_ref(q: jax.Array, scale: jax.Array, block_rows: int,
                    dtype=jnp.bfloat16) -> jax.Array:
    return fp8_unpack_ref(q, scale, block_rows, dtype)


def blocksparse_pack_ref(x: jax.Array, block_rows: int
                         ) -> Tuple[jax.Array, jax.Array]:
    """Blockwise int8 quantize + in-block magnitude pruning: entries with
    |x| < block_absmax / BLOCKSPARSE_TAU become exact zeros (the
    block-sparse stash codec; offload_pack.blocksparse_pack is the Pallas
    twin and owns the threshold constant)."""
    from repro.kernels.offload_pack import BLOCKSPARSE_TAU
    R, C = x.shape
    nb = R // block_rows
    xb = x.reshape(nb, block_rows, C).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xb), axis=(1, 2))
    scale = jnp.maximum(absmax / 127.0, 1e-30)
    q = jnp.clip(jnp.round(xb / scale[:, None, None]), -127.0, 127.0)
    keep = jnp.abs(xb) >= (absmax / BLOCKSPARSE_TAU)[:, None, None]
    q = jnp.where(keep, q, 0.0).astype(jnp.int8)
    return q.reshape(R, C), scale


def blocksparse_unpack_ref(q: jax.Array, scale: jax.Array, block_rows: int,
                           dtype=jnp.bfloat16) -> jax.Array:
    return fp8_unpack_ref(q, scale, block_rows, dtype)
