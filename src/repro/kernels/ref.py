"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
def gemm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (M, K) @ w: (K, N) -> (M, N), f32 accumulation."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  scale: Optional[float] = None) -> jax.Array:
    """q: (B, H, S, d); k/v: (B, Hkv, T, d); GQA via head repeat.

    Dense softmax reference (materializes S x T — small tests only).
    """
    B, H, S, d = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    if H != Hkv:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= q_pos >= k_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


# ---------------------------------------------------------------------------
def inflate_pages_ref(pool: jax.Array, page_map: jax.Array,
                      qpool: Optional[jax.Array] = None,
                      scales: Optional[jax.Array] = None) -> jax.Array:
    """Gather the contiguous (B, pp*page, K, hd) view a page map describes.

    pool: (P, page, K, hd); page_map: (B, pp) int32.  Ids ``>= P`` address
    frame ``id - P`` of the compressed side pool ``qpool`` (C, page, K, hd)
    with per-page ``scales`` (C, 1) and decode as ``q*scale`` cast to the
    pool dtype — exactly ``core.compress.decode_tensor`` per page.  This is
    the inflate-then-gather the in-kernel path replaces.
    """
    P, page, K, hd = pool.shape
    B, pp = page_map.shape
    flat = page_map.reshape(-1)
    out = jnp.take(pool, jnp.clip(flat, 0, P - 1), axis=0)
    if qpool is not None:
        C = qpool.shape[0]
        ci = jnp.clip(flat - P, 0, C - 1)
        dec = (jnp.take(qpool, ci, axis=0).astype(jnp.float32)
               * jnp.take(scales.reshape(-1), ci)[:, None, None, None]
               ).astype(pool.dtype)
        out = jnp.where((flat >= P)[:, None, None, None], dec, out)
    return out.reshape(B, pp * page, K, hd)


def paged_decode_attention_ref(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, page_map: jax.Array,
                               cache_index: jax.Array, *,
                               window: int = 0, softcap: float = 0.0,
                               kq_pool: Optional[jax.Array] = None,
                               vq_pool: Optional[jax.Array] = None,
                               k_scale: Optional[jax.Array] = None,
                               v_scale: Optional[jax.Array] = None
                               ) -> jax.Array:
    """Pure-XLA twin of kernels/paged_attention.paged_decode_attention:
    inflate+gather the page map, then the exact ``decode_attention`` math
    of the legacy gather-then-attend decode path."""
    from repro.models.attention import decode_attention
    k = inflate_pages_ref(k_pool, page_map, kq_pool, k_scale)
    v = inflate_pages_ref(v_pool, page_map, vq_pool, v_scale)
    return decode_attention(q, k, v, cache_index, window=window,
                            softcap=softcap)


# ---------------------------------------------------------------------------
def ssd_ref(x: jax.Array, a: jax.Array, B: jax.Array, C: jax.Array
            ) -> Tuple[jax.Array, jax.Array]:
    """Single-(batch,head) SSD recurrence oracle.

    x: (S, P) inputs (already dt-scaled), a: (S,) log-decay per step,
    B: (S, N), C: (S, N).  Returns (y: (S, P), final state (P, N)).
    """
    S, P = x.shape
    N = B.shape[1]

    def step(state, t):
        xt, at, Bt, Ct = t
        state = state * jnp.exp(at) + jnp.outer(xt, Bt)
        return state, state @ Ct

    xs = (x.astype(jnp.float32), a.astype(jnp.float32),
          B.astype(jnp.float32), C.astype(jnp.float32))
    final, y = jax.lax.scan(step, jnp.zeros((P, N), jnp.float32), xs)
    return y.astype(x.dtype), final


# ---------------------------------------------------------------------------
def fp8_pack_ref(x: jax.Array, block_rows: int
                 ) -> Tuple[jax.Array, jax.Array]:
    """Blockwise fp8 quantize: x (R, C) -> (q fp8 (R, C), scales (R/br,))."""
    R, C = x.shape
    nb = R // block_rows
    xb = x.reshape(nb, block_rows, C).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xb), axis=(1, 2))
    scale = jnp.maximum(absmax / 448.0, 1e-12)
    q = (xb / scale[:, None, None]).astype(jnp.float8_e4m3fn)
    return q.reshape(R, C), scale


def fp8_unpack_ref(q: jax.Array, scale: jax.Array, block_rows: int,
                   dtype=jnp.bfloat16) -> jax.Array:
    R, C = q.shape
    nb = R // block_rows
    xb = q.reshape(nb, block_rows, C).astype(jnp.float32)
    return (xb * scale[:, None, None]).reshape(R, C).astype(dtype)


def int8_pack_ref(x: jax.Array, block_rows: int
                  ) -> Tuple[jax.Array, jax.Array]:
    """Blockwise int8 quantize: x (R, C) -> (q int8 (R, C), scales (R/br,))."""
    R, C = x.shape
    nb = R // block_rows
    xb = x.reshape(nb, block_rows, C).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xb), axis=(1, 2))
    scale = jnp.maximum(absmax / 127.0, 1e-30)
    q = jnp.clip(jnp.round(xb / scale[:, None, None]),
                 -127.0, 127.0).astype(jnp.int8)
    return q.reshape(R, C), scale


def int8_unpack_ref(q: jax.Array, scale: jax.Array, block_rows: int,
                    dtype=jnp.bfloat16) -> jax.Array:
    return fp8_unpack_ref(q, scale, block_rows, dtype)


def blocksparse_pack_ref(x: jax.Array, block_rows: int
                         ) -> Tuple[jax.Array, jax.Array]:
    """Blockwise int8 quantize + in-block magnitude pruning: entries with
    |x| < block_absmax / BLOCKSPARSE_TAU become exact zeros (the
    block-sparse stash codec; offload_pack.blocksparse_pack is the Pallas
    twin and owns the threshold constant)."""
    from repro.kernels.offload_pack import BLOCKSPARSE_TAU
    R, C = x.shape
    nb = R // block_rows
    xb = x.reshape(nb, block_rows, C).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xb), axis=(1, 2))
    scale = jnp.maximum(absmax / 127.0, 1e-30)
    q = jnp.clip(jnp.round(xb / scale[:, None, None]), -127.0, 127.0)
    keep = jnp.abs(xb) >= (absmax / BLOCKSPARSE_TAU)[:, None, None]
    q = jnp.where(keep, q, 0.0).astype(jnp.int8)
    return q.reshape(R, C), scale


def blocksparse_unpack_ref(q: jax.Array, scale: jax.Array, block_rows: int,
                           dtype=jnp.bfloat16) -> jax.Array:
    return fp8_unpack_ref(q, scale, block_rows, dtype)
