"""Flash attention (forward) — Pallas TPU kernel with causal/SWA masking.

Online-softmax attention with (bq x bk) score tiles living in VMEM; the
running max / denominator / output accumulator persist in VMEM scratch
across the kv-block grid dimension.  GQA is handled in the index_map (query
head h reads kv head h // group) — no k/v repeat is materialized.

Block skipping: with causal masking, kv blocks strictly above the diagonal
(and, for sliding-window, strictly below the window band) contribute
nothing; their compute is guarded out with ``pl.when`` so the FLOPs match
the exact causal/banded count, not the dense rectangle.

The backward pass recomputes through the XLA blockwise twin
(models/attention.blockwise_attention) via ``ops.flash_attention`` 's
custom_vjp — forward takes the kernel, backward the XLA path.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int,
                  bq: int, bk: int, n_kv: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q0 = qi * bq
    k0 = ki * bk
    # static-ish skip decision must be dynamic (q0/k0 are traced via ids):
    # guard the whole block with pl.when on the band intersection test.
    block_live = jnp.asarray(True)
    if causal:
        block_live = (k0 <= q0 + bq - 1)            # not above diagonal
        if window > 0:
            block_live &= (k0 + bk - 1 >= q0 - window + 1)

    @pl.when(block_live)
    def _():
        q = q_ref[0, 0]                              # (bq, d)
        k = k_ref[0, 0]                              # (bk, d)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        q_pos = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < kv_len
        if causal:
            mask &= q_pos >= k_pos
            if window > 0:
                mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                          # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(ki == n_kv - 1)
    def _():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        bq: int = 128, bk: int = 128,
                        interpret: bool = False) -> jax.Array:
    """q: (B, H, S, d); k/v: (B, Hkv, T, d) -> (B, H, S, d)."""
    B, H, S, d = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    group = H // Hkv
    bq = min(bq, S)
    bk = min(bk, T)
    pad_q = (-S) % bq
    pad_k = (-T) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sq, Tk = q.shape[2], k.shape[2]
    n_kv = Tk // bk
    scale = 1.0 / math.sqrt(d)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, n_kv=n_kv, kv_len=T),
        grid=(B * H, Sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d),
                         lambda bh, qi, ki: (bh // H, bh % H, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bh, qi, ki: (bh // H, (bh % H) // group,
                                             ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bh, qi, ki: (bh // H, (bh % H) // group,
                                             ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bh, qi, ki: (bh // H, bh % H, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    if pad_q:
        out = out[:, :, :S]
    return out
