"""Paged-attention decode — Pallas TPU kernel with in-kernel page lookup.

The decode-path expression of the paper's memory-centric argument: instead
of gathering a session's pages into a contiguous view before attention can
run (bytes shipped to the compute), the block table rides into the kernel
as a scalar-prefetch operand and the BlockSpec index_maps dereference it —
each grid step DMAs exactly one page frame of the pool, in place.  Pages a
query cannot see (beyond ``cache_index``, or below the sliding-window band)
are skipped with ``pl.when``, so the bytes touched scale with the rows a
session actually holds, never with the pool size.

Fused codec decode: page-map ids ``>= num_frames`` address a *compressed*
side pool (int8/fp8 payload + one per-page scale, the ``core/compress.py``
per-page spill encoding).  The K/V load dequantizes those pages inline —
``q.astype(f32) * scale`` cast back to the pool dtype, bit-identical to
``decode_tensor`` — so cold pages resumed in compressed form are attended
without a separate inflate pass (Buddy-Compression-style transparent
capacity carried through the kernel boundary).

Online softmax follows the flash-attention blocking idiom
(kernels/flash_attention.py): running max / denominator / accumulator live
in VMEM scratch across the page grid dimension, masking uses a finite
``NEG_INF`` so a fully-masked (inactive) slot yields a finite discarded
row.  GQA is layout-native: q arrives as (B, K, G, hd) and each grid step
serves one kv head's G query heads — no k/v repeat.

The pure-XLA twin is :func:`repro.kernels.ref.paged_decode_attention_ref`
(gather-then-``decode_attention``, the exact math of the legacy path);
``tests/test_kernels.py`` pins kernel == ref across page sizes, windows,
softcap, GQA group counts, and every registered codec.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(pm_ref, idx_ref, q_ref, k_ref, v_ref, kq_ref, vq_ref,
                  ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, window: int, softcap: float,
                  page: int, pp: int, n_raw: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    idx = idx_ref[0]
    pid = pm_ref[b * pp + j]
    base = j * page
    # page visibility: any row <= idx (and, with a sliding window, any row
    # inside the band).  Dead pages — unowned tail entries routed to the
    # scratch frame included — cost neither DMA math nor FLOPs.
    live = base <= idx
    if window > 0:
        live &= (base + page - 1) > idx - window

    @pl.when(live)
    def _():
        is_comp = pid >= n_raw
        kr = k_ref[0, :, 0, :]                        # (page, hd) raw
        vr = v_ref[0, :, 0, :]
        # fused codec decode: the per-page scale+unpack of the registered
        # spill codecs (int8 / blocksparse / fp8 all decode as q*scale),
        # cast to the pool dtype so the math equals inflate-then-attend
        kd = (kq_ref[0, :, 0, :].astype(jnp.float32)
              * ks_ref[0, 0]).astype(kr.dtype)
        vd = (vq_ref[0, :, 0, :].astype(jnp.float32)
              * vs_ref[0, 0]).astype(vr.dtype)
        k = jnp.where(is_comp, kd, kr)
        v = jnp.where(is_comp, vd, vr)
        q = q_ref[0, 0]                               # (G, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, page)
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        pos = base + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        mask = pos <= idx
        if window > 0:
            mask &= pos > idx - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                           # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(j == pp - 1)
    def _():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "softcap",
                                             "interpret"))
def paged_decode_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, page_map: jax.Array,
                           cache_index: jax.Array, *,
                           window: int = 0, softcap: float = 0.0,
                           kq_pool: Optional[jax.Array] = None,
                           vq_pool: Optional[jax.Array] = None,
                           k_scale: Optional[jax.Array] = None,
                           v_scale: Optional[jax.Array] = None,
                           interpret: bool = False) -> jax.Array:
    """Single-token decode attention straight over the page pool.

    q: (B, 1, H, hd); pools: (P, page, K, hd) — ``P`` frames including the
    trailing scratch frame; page_map: (B, pages_per_slot) int32 frame ids
    in logical page order (unowned entries -> scratch); cache_index:
    scalar int32, the new token attends to rows [0, cache_index].

    ``kq_pool``/``vq_pool`` (C, page, K, hd) + ``k_scale``/``v_scale``
    (C, 1): compressed side pool; page-map ids ``>= P`` address frame
    ``id - P`` there and decode in-kernel.  Semantics (window / softcap /
    GQA / masking) match ``models/attention.decode_attention`` over the
    gathered equivalent view.
    """
    B, one, H, hd = q.shape
    assert one == 1, f"decode kernel takes a single query row, got {one}"
    P, page, K, _ = k_pool.shape
    G = H // K
    pp = page_map.shape[1]
    scale = 1.0 / math.sqrt(hd)
    qq = q.reshape(B, K, G, hd)

    if kq_pool is None:
        # no compressed frames: a 1-frame dummy side pool keeps the kernel
        # signature static; ids never reach it (is_comp is always false)
        kq_pool = jnp.zeros((1, page, K, hd), jnp.int8)
        vq_pool = jnp.zeros((1, page, K, hd), jnp.int8)
        k_scale = jnp.zeros((1, 1), jnp.float32)
        v_scale = jnp.zeros((1, 1), jnp.float32)
    C = kq_pool.shape[0]

    flat_map = page_map.reshape(-1).astype(jnp.int32)
    idx = jnp.asarray(cache_index, jnp.int32).reshape(1)

    # scalar-prefetched block table: the page map (and cache_index) land
    # in SMEM before the grid runs, so the index_maps below dereference
    # them to pick each step's page frame — the block-tabled K/V lookup
    def qmap(b, kh, j, pm, ix):
        return (b, kh, 0, 0)

    def rawmap(b, kh, j, pm, ix):
        return (jnp.clip(pm[b * pp + j], 0, P - 1), 0, kh, 0)

    def compmap(b, kh, j, pm, ix):
        return (jnp.clip(pm[b * pp + j] - P, 0, C - 1), 0, kh, 0)

    def scalemap(b, kh, j, pm, ix):
        return (jnp.clip(pm[b * pp + j] - P, 0, C - 1), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, pp),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), qmap),
            pl.BlockSpec((1, page, 1, hd), rawmap),
            pl.BlockSpec((1, page, 1, hd), rawmap),
            pl.BlockSpec((1, page, 1, hd), compmap),
            pl.BlockSpec((1, page, 1, hd), compmap),
            pl.BlockSpec((1, 1), scalemap),
            pl.BlockSpec((1, 1), scalemap),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), qmap),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, scale=scale, window=window,
                          softcap=softcap, page=page, pp=pp, n_raw=P),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        interpret=interpret,
    )(flat_map, idx, qq, k_pool, v_pool, kq_pool, vq_pool, k_scale, v_scale)
    return out.reshape(B, 1, H, hd)
