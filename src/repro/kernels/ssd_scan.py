"""Mamba2 SSD chunked scan — Pallas TPU kernel.

One grid cell = one (batch*head, chunk).  The (P x N) inter-chunk SSM state
lives in a VMEM f32 scratch that persists across the chunk grid dimension
(TPU grids execute sequentially with the last axis innermost, so for a
fixed bh the chunks arrive in order; the state resets at chunk 0).

Within a chunk everything is MXU matmuls:
    scores  = (C L) B^T          (c x c masked decay matmul)
    y_intra = scores @ X
    y_inter = (C * in_decay) @ state
    state   = decay_total * state + (B * to_end)^T @ X
which is precisely the "quadratic intra + linear inter" structure of the
SSD duality — the TPU-native re-think of the paper-era GPU scan kernels.

Inputs are pre-projected per-(batch,head) tensors (the surrounding
mamba_block does the projections); ``a`` is the per-step log-decay dt*A and
x is already dt-scaled.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, st_ref, *, c: int):
    ni = pl.program_id(1)

    @pl.when(ni == 0)
    def _():
        st_ref[...] = jnp.zeros_like(st_ref)

    x = x_ref[0]                                    # (c, P)
    a = a_ref[0].astype(jnp.float32)                # (c, 1)
    B = b_ref[0]                                    # (c, N)
    C = c_ref[0]                                    # (c, N)

    cum = jnp.cumsum(a, axis=0)                     # (c, 1) inclusive
    seg = cum - cum.T                               # (c, c) cum_i - cum_j
    ii = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    L = jnp.exp(jnp.where(ii >= jj, seg, NEG_INF))  # masked decay

    scores = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * L     # (c, c)
    y = jax.lax.dot_general(
        scores.astype(x.dtype), x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)         # (c, P) intra

    in_decay = jnp.exp(cum)                         # (c, 1)
    y += jax.lax.dot_general(
        (C.astype(jnp.float32) * in_decay), st_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)         # (c, P) inter: C @ S^T

    to_end = jnp.exp(cum[-1] - cum)                 # (c, 1)
    upd = jax.lax.dot_general(
        (B.astype(jnp.float32) * to_end), x.astype(jnp.float32),
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)         # (N, P)
    st_ref[...] = st_ref[...] * jnp.exp(cum[-1]) + upd.T  # (P, N)

    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, a: jax.Array, B: jax.Array, C: jax.Array, *,
             chunk: int = 128, interpret: bool = False) -> jax.Array:
    """x: (BH, S, P) dt-scaled inputs; a: (BH, S) log decay;
    B/C: (BH, S, N).  Returns y: (BH, S, P)."""
    BH, S, P = x.shape
    N = B.shape[-1]
    c = min(chunk, S)
    assert S % c == 0, (S, c)
    n = S // c
    a2 = a[..., None]

    return pl.pallas_call(
        functools.partial(_ssd_kernel, c=c),
        grid=(BH, n),
        in_specs=[
            pl.BlockSpec((1, c, P), lambda bh, ni: (bh, ni, 0)),
            pl.BlockSpec((1, c, 1), lambda bh, ni: (bh, ni, 0)),
            pl.BlockSpec((1, c, N), lambda bh, ni: (bh, ni, 0)),
            pl.BlockSpec((1, c, N), lambda bh, ni: (bh, ni, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, P), lambda bh, ni: (bh, ni, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, a2, B, C)
