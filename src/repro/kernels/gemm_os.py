"""Output-stationary GEMM — the paper's accelerator dataflow on the MXU.

The paper's device-node (§IV, Table II) is a PE-array accelerator using an
*output-stationary* dataflow ("output feature maps are stationed locally
on-chip").  The MXU analogue: each grid cell owns one (bm x bn) output tile
that stays resident in a VMEM f32 scratch accumulator while the K dimension
streams through in (bm x bk) / (bk x bn) blocks — HBM traffic is
O(MK + KN + MN) with the output written exactly once, and the tile shapes
are multiples of the 128x128 systolic array.

Block-size selection (``pick_blocks``) maximizes the K-streaming block
under the VMEM budget — the kernel-level twin of the §Perf tiling
hypothesis loop.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

VMEM_BUDGET = 12 * 1024 * 1024       # conservative per-core working set


def _gemm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    """Grid (M/bm, N/bn, K/bk); K is the innermost (sequential) dim."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def pick_blocks(M: int, K: int, N: int, itemsize: int = 2
                ) -> Tuple[int, int, int]:
    """Largest hardware-aligned blocks fitting the VMEM working set:
    bm*bk + bk*bn (operands, double-buffered by pallas) + bm*bn (acc+out)."""
    def fit(bm, bn, bk):
        return 2 * (bm * bk + bk * bn) * itemsize + bm * bn * (4 + itemsize)

    bm = 256 if M % 256 == 0 else min(128, M)
    bn = 256 if N % 256 == 0 else min(128, N)
    bk = min(128, K)
    while bk * 2 <= K and K % (bk * 2) == 0 and \
            fit(bm, bn, bk * 2) <= VMEM_BUDGET:
        bk *= 2
    return bm, bn, bk


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def gemm_os(x: jax.Array, w: jax.Array, *, bm: int = 0, bn: int = 0,
            bk: int = 0, interpret: bool = False) -> jax.Array:
    """x: (M, K) @ w: (K, N) -> (M, N) with f32 accumulation."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    if not (bm and bn and bk):
        bm, bn, bk = pick_blocks(M, K, N, x.dtype.itemsize)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, K, N, bm, bn, bk)
    n_k = K // bk
    return pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=n_k),
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
