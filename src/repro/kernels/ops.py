"""Public jit'd wrappers for the Pallas kernels.

Each op auto-selects interpret mode off-TPU (the kernels are written for
TPU BlockSpec/VMEM semantics; interpret=True executes the same kernel body
on CPU for correctness).  ``flash_attention`` adds the custom_vjp pairing:
Pallas forward + XLA-blockwise backward recompute.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import (flash_attention as fa, gemm_os as gos,
                           offload_pack as op, paged_attention as pa,
                           ref as kref, ssd_scan as ss)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def gemm(x: jax.Array, w: jax.Array, **kw) -> jax.Array:
    return gos.gemm_os(x, w, interpret=_interpret(), **kw)


def ssd(x, a, B, C, *, chunk: int = 128) -> jax.Array:
    return ss.ssd_scan(x, a, B, C, chunk=chunk, interpret=_interpret())


def fp8_pack(x, *, block_rows: int = 128):
    return op.fp8_pack(x, block_rows=block_rows, interpret=_interpret())


def fp8_unpack(q, scales, *, block_rows: int = 128, dtype=jnp.bfloat16):
    return op.fp8_unpack(q, scales, block_rows=block_rows, dtype=dtype,
                         interpret=_interpret())


def int8_pack(x, *, block_rows: int = 128):
    return op.int8_pack(x, block_rows=block_rows, interpret=_interpret())


def int8_unpack(q, scales, *, block_rows: int = 128, dtype=jnp.bfloat16):
    return op.int8_unpack(q, scales, block_rows=block_rows, dtype=dtype,
                          interpret=_interpret())


# ---------------------------------------------------------------------------
# paged decode attention: in-place block-tabled K/V lookup with fused codec
# decode.  A registry flag (not a bool) so backends stay pluggable: the
# serving stack routes through ``paged_attention`` and the active impl can
# be swapped (tests pin pallas == xla) without touching the call sites.
PAGED_IMPLS = ("pallas", "xla")
_PAGED_IMPL = {"default": "pallas"}


def set_paged_impl(name: str) -> None:
    """Select the paged-attention backend ('pallas' kernel / 'xla' ref)."""
    if name not in PAGED_IMPLS:
        raise ValueError(f"unknown paged-attention impl {name!r}; "
                         f"registered: {PAGED_IMPLS}")
    _PAGED_IMPL["default"] = name


def paged_attention(q, k_pool, v_pool, page_map, cache_index, *,
                    window: int = 0, softcap: float = 0.0,
                    kq_pool=None, vq_pool=None, k_scale=None, v_scale=None,
                    impl: Optional[str] = None):
    """q: (B, 1, H, d) over a (P, page, K, d) pool via a (B, pp) page map.

    Ids >= P address the compressed side pool (decoded in the K/V load).
    Semantics match ``models/attention.decode_attention`` on the gathered
    view — the ref twin IS that path."""
    name = impl or _PAGED_IMPL["default"]
    if name == "pallas":
        return pa.paged_decode_attention(
            q, k_pool, v_pool, page_map, cache_index, window=window,
            softcap=softcap, kq_pool=kq_pool, vq_pool=vq_pool,
            k_scale=k_scale, v_scale=v_scale, interpret=_interpret())
    if name == "xla":
        return kref.paged_decode_attention_ref(
            q, k_pool, v_pool, page_map, cache_index, window=window,
            softcap=softcap, kq_pool=kq_pool, vq_pool=vq_pool,
            k_scale=k_scale, v_scale=v_scale)
    raise ValueError(f"unknown paged-attention impl {name!r}")


# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, causal: bool = True, window: int = 0):
    """q: (B, H, S, d); k/v: (B, Hkv, T, d).  Pallas forward; backward
    recomputes through the XLA blockwise twin (exact same math)."""
    return fa.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                  interpret=_interpret())


def _fa_ref(q, k, v, causal, window):
    # XLA blockwise twin, in (B, S, H, d) layout
    from repro.models.attention import blockwise_attention
    o = blockwise_attention(q.swapaxes(1, 2), k.swapaxes(1, 2),
                            v.swapaxes(1, 2), causal=causal, window=window)
    return o.swapaxes(1, 2)


def _fa_fwd(q, k, v, causal, window):
    return flash_attention(q, k, v, causal, window), (q, k, v)


def _fa_bwd(causal, window, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: _fa_ref(q, k, v, causal, window),
                     q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)
