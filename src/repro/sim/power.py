"""Memory-node power model — paper Table IV + §V-C performance/watt.

DIMM TDPs are the paper's cited measurements (Samsung datasheets + Micron
DDR4 power calculator); a memory-node carries 10 DIMMs (§III-A).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

DGX_TDP_W = 3200.0           # DC-DLA baseline system TDP (paper §V-C)
N_MEMNODES = 8


@dataclasses.dataclass(frozen=True)
class DimmOption:
    name: str
    capacity_gb: int
    tdp_w: float             # single DIMM

    @property
    def node_tdp_w(self) -> float:
        return 10 * self.tdp_w

    @property
    def node_capacity_gb(self) -> float:
        return 10 * self.capacity_gb

    @property
    def gb_per_w(self) -> float:
        return self.node_capacity_gb / self.node_tdp_w


# paper Table IV
DIMM_OPTIONS: Tuple[DimmOption, ...] = (
    DimmOption("8GB RDIMM", 8, 2.9),
    DimmOption("16GB RDIMM", 16, 6.6),
    DimmOption("32GB LRDIMM", 32, 8.7),
    DimmOption("64GB LRDIMM", 64, 10.2),
    DimmOption("128GB LRDIMM", 128, 12.7),
)


def table4() -> Dict[str, Dict[str, float]]:
    out = {}
    for d in DIMM_OPTIONS:
        out[d.name] = {
            "dimm_tdp_w": d.tdp_w,
            "node_tdp_w": d.node_tdp_w,
            "gb_per_w": round(d.gb_per_w, 1),
            "node_capacity_gb": d.node_capacity_gb,
        }
    return out


def system_overhead(option: DimmOption) -> Dict[str, float]:
    """§V-C: added power, capacity, and perf/W of MC-DLA vs DC-DLA."""
    added_w = N_MEMNODES * option.node_tdp_w
    frac = added_w / DGX_TDP_W
    return {
        "added_power_w": added_w,
        "power_increase_frac": frac,
        "pool_capacity_tb": N_MEMNODES * option.node_capacity_gb / 1e3,
    }


def perf_per_watt(speedup: float, option: DimmOption) -> float:
    """Speedup / power-increase = perf/W gain over DC-DLA (paper: 2.1-2.6x
    for 2.8x speedup at +7%..+31% power)."""
    ov = system_overhead(option)
    return speedup / (1.0 + ov["power_increase_frac"])
