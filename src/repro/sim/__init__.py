"""The paper's evaluation, reproduced: analytic event-timeline simulator
over the DC/HC/MC system design points (§IV-§V), the 8 Table-III workloads,
and the Table-IV power model."""
from repro.sim.simulator import StepResult, simulate, speedup_table, harmonic_mean
from repro.sim.topology import (ALL_SYSTEMS, SYSTEMS_BY_NAME, DC_DLA,
                                DC_DLA_GEN4, DC_DLA_O, HC_DLA, MC_DLA_B,
                                MC_DLA_L, MC_DLA_S, SystemConfig)
from repro.sim.workloads import WORKLOADS, CNNS, RNNS
