"""The paper's 8 benchmarks (Table III) as per-layer FLOPs/bytes DAGs.

4 ImageNet CNNs (AlexNet, GoogLeNet, VGG-E=VGG-19, ResNet-34) with the
published layer shapes, and 4 DeepBench-style RNNs (vanilla GEMV, 2 LSTMs,
1 GRU) with DeepBench-suite hidden sizes.  Batch 512 (paper §IV), fp32
(paper-era training precision).  Cheap layers (ReLU/pool/norm) are folded —
they are re-computed rather than stashed (paper footnote 4), exactly as in
our executable runtime (core.offload recomputes intermediates).
"""
from __future__ import annotations

from typing import List, Tuple

from repro.core.dag import LayerDAG, LayerNode

BATCH = 512
F32 = 4


def _conv(name: str, cin: int, cout: int, k: int, hout: int,
          batch: int = BATCH) -> LayerNode:
    flops = 2.0 * batch * cout * hout * hout * cin * k * k
    act = batch * cout * hout * hout * F32
    w = cout * cin * k * k * F32
    return LayerNode(name, flops_fwd=flops, saved_bytes=act, weight_bytes=w)


def _fc(name: str, din: int, dout: int, batch: int = BATCH) -> LayerNode:
    return LayerNode(name, flops_fwd=2.0 * batch * din * dout,
                     saved_bytes=batch * dout * F32,
                     weight_bytes=din * dout * F32, fc=True)


# ---------------------------------------------------------------------------
def alexnet(batch: int = BATCH) -> LayerDAG:
    return LayerDAG([
        _conv("conv1", 3, 96, 11, 55, batch),
        _conv("conv2", 96, 256, 5, 27, batch),
        _conv("conv3", 256, 384, 3, 13, batch),
        _conv("conv4", 384, 384, 3, 13, batch),
        _conv("conv5", 384, 256, 3, 13, batch),
        _fc("fc6", 9216, 4096, batch),
        _fc("fc7", 4096, 4096, batch),
        _fc("fc8", 4096, 1000, batch),
    ])


def vgg_e(batch: int = BATCH) -> LayerDAG:
    layers: List[LayerNode] = []
    spec = [(3, 64, 224), (64, 64, 224), (64, 128, 112), (128, 128, 112),
            (128, 256, 56), (256, 256, 56), (256, 256, 56), (256, 256, 56),
            (256, 512, 28), (512, 512, 28), (512, 512, 28), (512, 512, 28),
            (512, 512, 14), (512, 512, 14), (512, 512, 14), (512, 512, 14)]
    for i, (cin, cout, h) in enumerate(spec):
        layers.append(_conv(f"conv{i}", cin, cout, 3, h, batch))
    layers += [_fc("fc6", 25088, 4096, batch), _fc("fc7", 4096, 4096, batch),
               _fc("fc8", 4096, 1000, batch)]
    return LayerDAG(layers)


_INCEPTION = [
    # (cin, 1x1, 3red, 3x3, 5red, 5x5, pool, spatial)
    ("3a", 192, 64, 96, 128, 16, 32, 32, 28),
    ("3b", 256, 128, 128, 192, 32, 96, 64, 28),
    ("4a", 480, 192, 96, 208, 16, 48, 64, 14),
    ("4b", 512, 160, 112, 224, 24, 64, 64, 14),
    ("4c", 512, 128, 128, 256, 24, 64, 64, 14),
    ("4d", 512, 112, 144, 288, 32, 64, 64, 14),
    ("4e", 528, 256, 160, 320, 32, 128, 128, 14),
    ("5a", 832, 256, 160, 320, 32, 128, 128, 7),
    ("5b", 832, 384, 192, 384, 48, 128, 128, 7),
]


def googlenet(batch: int = BATCH) -> LayerDAG:
    layers: List[LayerNode] = [
        _conv("stem7x7", 3, 64, 7, 112, batch),
        _conv("stem1x1", 64, 64, 1, 56, batch),
        _conv("stem3x3", 64, 192, 3, 56, batch),
    ]
    for (tag, cin, c1, c3r, c3, c5r, c5, cp, h) in _INCEPTION:
        layers += [
            _conv(f"{tag}_1x1", cin, c1, 1, h, batch),
            _conv(f"{tag}_3red", cin, c3r, 1, h, batch),
            _conv(f"{tag}_3x3", c3r, c3, 3, h, batch),
            _conv(f"{tag}_5red", cin, c5r, 1, h, batch),
            _conv(f"{tag}_5x5", c5r, c5, 5, h, batch),
            _conv(f"{tag}_pool", cin, cp, 1, h, batch),
        ]
    layers.append(_fc("fc", 1024, 1000, batch))
    return LayerDAG(layers)       # 3 + 9*6 + 1 = 58 layers (Table III)


def resnet34(batch: int = BATCH) -> LayerDAG:
    layers: List[LayerNode] = [_conv("stem", 3, 64, 7, 112, batch)]
    plan = [(64, 64, 56, 6), (64, 128, 28, 8), (128, 256, 14, 12),
            (256, 512, 7, 6)]
    for cin, cout, h, n in plan:
        for i in range(n):
            c_in = cin if i == 0 else cout
            layers.append(_conv(f"c{cout}_{i}", c_in, cout, 3, h, batch))
    layers.append(_fc("fc", 512, 1000, batch))
    return LayerDAG(layers)       # 1 + 32 + 1 = 34 layers


# ---------------------------------------------------------------------------
# DeepBench-style RNNs.  Per-timestep GEMMs; each timestep's hidden state is
# a saved feature map.  gates: vanilla=1, GRU=3, LSTM=4.
def _rnn(name: str, hidden: int, steps: int, gates: int,
         batch: int = BATCH) -> LayerDAG:
    layers = []
    flops = 2.0 * batch * (hidden * hidden * gates * 2)   # x-GEMM + h-GEMM
    act = batch * hidden * gates * F32
    w = 2 * hidden * hidden * gates * F32
    for t in range(steps):
        layers.append(LayerNode(f"{name}_t{t}", flops_fwd=flops,
                                saved_bytes=act,
                                weight_bytes=w if t == 0 else 0.0, fc=True))
    return LayerDAG(layers)


def rnn_gemv(batch: int = BATCH) -> LayerDAG:
    return _rnn("rnn", 2560, 50, 1, batch)        # speech recognition


def rnn_lstm1(batch: int = BATCH) -> LayerDAG:
    return _rnn("lstm1", 2048, 25, 4, batch)      # machine translation


def rnn_lstm2(batch: int = BATCH) -> LayerDAG:
    return _rnn("lstm2", 4096, 25, 4, batch)      # language modelling


def rnn_gru(batch: int = BATCH) -> LayerDAG:
    return _rnn("gru", 2816, 187, 3, batch)       # speech recognition


WORKLOADS = {
    "AlexNet": alexnet,
    "GoogLeNet": googlenet,
    "VGG-E": vgg_e,
    "ResNet": resnet34,
    "RNN-GEMV": rnn_gemv,
    "RNN-LSTM-1": rnn_lstm1,
    "RNN-LSTM-2": rnn_lstm2,
    "RNN-GRU": rnn_gru,
}

CNNS = ("AlexNet", "GoogLeNet", "VGG-E", "ResNet")
RNNS = ("RNN-GEMV", "RNN-LSTM-1", "RNN-LSTM-2", "RNN-GRU")
