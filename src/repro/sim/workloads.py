"""The paper's 8 benchmarks (Table III) as per-layer FLOPs/bytes DAGs.

4 ImageNet CNNs (AlexNet, GoogLeNet, VGG-E=VGG-19, ResNet-34) with the
published layer shapes, and 4 DeepBench-style RNNs (vanilla GEMV, 2 LSTMs,
1 GRU) with DeepBench-suite hidden sizes.  Batch 512 (paper §IV), fp32
(paper-era training precision).  Cheap layers (ReLU/pool/norm) are folded —
they are re-computed rather than stashed (paper footnote 4), exactly as in
our executable runtime (core.offload recomputes intermediates).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core.dag import LayerDAG, LayerNode

BATCH = 512
F32 = 4


def _conv(name: str, cin: int, cout: int, k: int, hout: int,
          batch: int = BATCH) -> LayerNode:
    flops = 2.0 * batch * cout * hout * hout * cin * k * k
    act = batch * cout * hout * hout * F32
    w = cout * cin * k * k * F32
    return LayerNode(name, flops_fwd=flops, saved_bytes=act, weight_bytes=w)


def _fc(name: str, din: int, dout: int, batch: int = BATCH) -> LayerNode:
    return LayerNode(name, flops_fwd=2.0 * batch * din * dout,
                     saved_bytes=batch * dout * F32,
                     weight_bytes=din * dout * F32, fc=True)


# ---------------------------------------------------------------------------
def alexnet(batch: int = BATCH) -> LayerDAG:
    return LayerDAG([
        _conv("conv1", 3, 96, 11, 55, batch),
        _conv("conv2", 96, 256, 5, 27, batch),
        _conv("conv3", 256, 384, 3, 13, batch),
        _conv("conv4", 384, 384, 3, 13, batch),
        _conv("conv5", 384, 256, 3, 13, batch),
        _fc("fc6", 9216, 4096, batch),
        _fc("fc7", 4096, 4096, batch),
        _fc("fc8", 4096, 1000, batch),
    ])


def vgg_e(batch: int = BATCH) -> LayerDAG:
    layers: List[LayerNode] = []
    spec = [(3, 64, 224), (64, 64, 224), (64, 128, 112), (128, 128, 112),
            (128, 256, 56), (256, 256, 56), (256, 256, 56), (256, 256, 56),
            (256, 512, 28), (512, 512, 28), (512, 512, 28), (512, 512, 28),
            (512, 512, 14), (512, 512, 14), (512, 512, 14), (512, 512, 14)]
    for i, (cin, cout, h) in enumerate(spec):
        layers.append(_conv(f"conv{i}", cin, cout, 3, h, batch))
    layers += [_fc("fc6", 25088, 4096, batch), _fc("fc7", 4096, 4096, batch),
               _fc("fc8", 4096, 1000, batch)]
    return LayerDAG(layers)


_INCEPTION = [
    # (cin, 1x1, 3red, 3x3, 5red, 5x5, pool, spatial)
    ("3a", 192, 64, 96, 128, 16, 32, 32, 28),
    ("3b", 256, 128, 128, 192, 32, 96, 64, 28),
    ("4a", 480, 192, 96, 208, 16, 48, 64, 14),
    ("4b", 512, 160, 112, 224, 24, 64, 64, 14),
    ("4c", 512, 128, 128, 256, 24, 64, 64, 14),
    ("4d", 512, 112, 144, 288, 32, 64, 64, 14),
    ("4e", 528, 256, 160, 320, 32, 128, 128, 14),
    ("5a", 832, 256, 160, 320, 32, 128, 128, 7),
    ("5b", 832, 384, 192, 384, 48, 128, 128, 7),
]


def googlenet(batch: int = BATCH) -> LayerDAG:
    layers: List[LayerNode] = [
        _conv("stem7x7", 3, 64, 7, 112, batch),
        _conv("stem1x1", 64, 64, 1, 56, batch),
        _conv("stem3x3", 64, 192, 3, 56, batch),
    ]
    for (tag, cin, c1, c3r, c3, c5r, c5, cp, h) in _INCEPTION:
        layers += [
            _conv(f"{tag}_1x1", cin, c1, 1, h, batch),
            _conv(f"{tag}_3red", cin, c3r, 1, h, batch),
            _conv(f"{tag}_3x3", c3r, c3, 3, h, batch),
            _conv(f"{tag}_5red", cin, c5r, 1, h, batch),
            _conv(f"{tag}_5x5", c5r, c5, 5, h, batch),
            _conv(f"{tag}_pool", cin, cp, 1, h, batch),
        ]
    layers.append(_fc("fc", 1024, 1000, batch))
    return LayerDAG(layers)       # 3 + 9*6 + 1 = 58 layers (Table III)


def resnet34(batch: int = BATCH) -> LayerDAG:
    layers: List[LayerNode] = [_conv("stem", 3, 64, 7, 112, batch)]
    plan = [(64, 64, 56, 6), (64, 128, 28, 8), (128, 256, 14, 12),
            (256, 512, 7, 6)]
    for cin, cout, h, n in plan:
        for i in range(n):
            c_in = cin if i == 0 else cout
            layers.append(_conv(f"c{cout}_{i}", c_in, cout, 3, h, batch))
    layers.append(_fc("fc", 512, 1000, batch))
    return LayerDAG(layers)       # 1 + 32 + 1 = 34 layers


# ---------------------------------------------------------------------------
# DeepBench-style RNNs.  Per-timestep GEMMs; each timestep's hidden state is
# a saved feature map.  gates: vanilla=1, GRU=3, LSTM=4.
def _rnn(name: str, hidden: int, steps: int, gates: int,
         batch: int = BATCH) -> LayerDAG:
    layers = []
    flops = 2.0 * batch * (hidden * hidden * gates * 2)   # x-GEMM + h-GEMM
    act = batch * hidden * gates * F32
    w = 2 * hidden * hidden * gates * F32
    for t in range(steps):
        layers.append(LayerNode(f"{name}_t{t}", flops_fwd=flops,
                                saved_bytes=act,
                                weight_bytes=w if t == 0 else 0.0, fc=True))
    return LayerDAG(layers)


def rnn_gemv(batch: int = BATCH) -> LayerDAG:
    return _rnn("rnn", 2560, 50, 1, batch)        # speech recognition


def rnn_lstm1(batch: int = BATCH) -> LayerDAG:
    return _rnn("lstm1", 2048, 25, 4, batch)      # machine translation


def rnn_lstm2(batch: int = BATCH) -> LayerDAG:
    return _rnn("lstm2", 4096, 25, 4, batch)      # language modelling


def rnn_gru(batch: int = BATCH) -> LayerDAG:
    return _rnn("gru", 2816, 187, 3, batch)       # speech recognition


WORKLOADS = {
    "AlexNet": alexnet,
    "GoogLeNet": googlenet,
    "VGG-E": vgg_e,
    "ResNet": resnet34,
    "RNN-GEMV": rnn_gemv,
    "RNN-LSTM-1": rnn_lstm1,
    "RNN-LSTM-2": rnn_lstm2,
    "RNN-GRU": rnn_gru,
}

CNNS = ("AlexNet", "GoogLeNet", "VGG-E", "ResNet")
RNNS = ("RNN-GEMV", "RNN-LSTM-1", "RNN-LSTM-2", "RNN-GRU")


# ---------------------------------------------------------------------------
# Synthetic LLM serving traffic (PR 7: the router's million-session feed).
#
# A seeded generator for session arrivals with the structure real serving
# traffic has and uniform Poisson lacks: a diurnal intensity cycle, bursts
# (correlated arrival clumps), a shared-prefix mixture (many sessions
# reuse a few system prompts — what prefix-affinity placement exploits),
# mixed SLO classes, and a tenant mix.  The same trace replays two ways:
# scaled down against the real Router (serve/router.py `replay_trace`) and
# analytically at full scale against DC/HC/MC TierSpecs
# (sim/simulator.py `simulate_serving`).

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticSession:
    """One generated session: everything the router or the analytic
    model needs to admit, place, and score it."""

    uid: int
    arrival: float              # seconds from trace start
    tenant: str
    prompt_len: int
    decode_len: int
    prefix_id: int | None       # shared system-prompt id (None: unique)
    prefix_len: int             # tokens shared when prefix_id is set
    slo: str                    # interactive | standard | batch
    slack_steps: float          # deadline slack on the router step clock


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """Knobs for :func:`generate_traffic` (all rates are per the whole
    trace horizon unless noted)."""

    sessions: int = 10_000
    horizon_s: float = 86_400.0        # one day
    diurnal_amplitude: float = 0.6     # 0: flat, 1: midnight trough ~ 0
    peak_hour: float = 14.0            # local-time intensity peak
    burst_rate_per_hour: float = 2.0   # Poisson rate of burst events
    burst_size: int = 50               # mean sessions per burst (geometric)
    burst_spread_s: float = 30.0       # arrival jitter inside a burst
    shared_prefix_frac: float = 0.6    # sessions drawn from the prefix pool
    prefix_pool: int = 16              # distinct shared system prompts
    prefix_len: int = 32
    prompt_mean: float = 96.0          # lognormal body lengths
    prompt_sigma: float = 0.7
    prompt_max: int = 1024
    decode_mean: float = 64.0
    decode_sigma: float = 0.8
    decode_max: int = 512
    # SLO class -> (mix weight, deadline slack as a multiple of the ideal
    # decode duration); None slack = no deadline (batch)
    slo_classes: tuple = (("interactive", 0.3, 2.0),
                          ("standard", 0.5, 4.0),
                          ("batch", 0.2, None))
    tenants: tuple = ("default", "burst", "batch")
    tenant_weights: tuple = (0.6, 0.25, 0.15)
    seed: int = 0


def _diurnal_arrivals(n: int, spec: TrafficSpec,
                      rng: np.random.Generator) -> np.ndarray:
    """Inverse-CDF sample n arrival times from the diurnal intensity
    lambda(t) = 1 + A*cos(2*pi*(t - peak)/day), on a fine grid."""
    grid = np.linspace(0.0, spec.horizon_s, 4096)
    day = 86_400.0
    lam = 1.0 + spec.diurnal_amplitude * np.cos(
        2.0 * math.pi * (grid - spec.peak_hour * 3600.0) / day)
    lam = np.maximum(lam, 1e-6)
    cdf = np.cumsum(lam)
    cdf = cdf / cdf[-1]
    return np.interp(rng.random(n), cdf, grid)


def _lognormal_lengths(n: int, mean: float, sigma: float, cap: int,
                       rng: np.random.Generator) -> np.ndarray:
    mu = math.log(mean) - sigma * sigma / 2.0
    x = rng.lognormal(mu, sigma, size=n)
    return np.clip(np.round(x), 1, cap).astype(int)


def generate_traffic(spec: TrafficSpec) -> List["SyntheticSession"]:
    """A seeded synthetic trace, sorted by arrival time.

    Deterministic for a given spec (one PRNG stream drives everything),
    so the router replay, the analytic sweep, and the benches all see the
    same sessions."""
    rng = np.random.default_rng(spec.seed)

    # arrivals: diurnal base + Poisson bursts of geometric size
    n_bursts = rng.poisson(spec.burst_rate_per_hour *
                           spec.horizon_s / 3600.0)
    burst_sizes = (1 + rng.geometric(1.0 / max(spec.burst_size, 1),
                                     size=n_bursts)
                   if n_bursts else np.zeros(0, int))
    n_burst = int(min(burst_sizes.sum(), spec.sessions // 2))
    n_base = spec.sessions - n_burst
    arrivals = [_diurnal_arrivals(n_base, spec, rng)]
    remaining = n_burst
    for size in burst_sizes:
        take = int(min(size, remaining))
        if take <= 0:
            break
        center = rng.random() * spec.horizon_s
        arrivals.append(np.clip(
            center + rng.exponential(spec.burst_spread_s, size=take),
            0.0, spec.horizon_s))
        remaining -= take
    arrival = np.sort(np.concatenate(arrivals))[:spec.sessions]

    n = len(arrival)
    prompt_len = _lognormal_lengths(n, spec.prompt_mean, spec.prompt_sigma,
                                    spec.prompt_max, rng)
    decode_len = _lognormal_lengths(n, spec.decode_mean, spec.decode_sigma,
                                    spec.decode_max, rng)

    shared = rng.random(n) < spec.shared_prefix_frac
    # Zipf-ish popularity over the prefix pool: a few prompts dominate
    pop = 1.0 / np.arange(1, spec.prefix_pool + 1)
    prefix_ids = rng.choice(spec.prefix_pool, size=n, p=pop / pop.sum())

    names, weights, slacks = zip(*[(c[0], c[1], c[2])
                                   for c in spec.slo_classes])
    w = np.asarray(weights, float)
    slo_idx = rng.choice(len(names), size=n, p=w / w.sum())
    tw = np.asarray(spec.tenant_weights, float)
    tenant_idx = rng.choice(len(spec.tenants), size=n, p=tw / tw.sum())

    out: List[SyntheticSession] = []
    for i in range(n):
        slo = names[slo_idx[i]]
        slack = slacks[slo_idx[i]]
        has_prefix = bool(shared[i]) and prompt_len[i] > spec.prefix_len
        out.append(SyntheticSession(
            uid=i,
            arrival=float(arrival[i]),
            tenant=spec.tenants[tenant_idx[i]],
            prompt_len=int(prompt_len[i]),
            decode_len=int(decode_len[i]),
            prefix_id=int(prefix_ids[i]) if has_prefix else None,
            prefix_len=spec.prefix_len if has_prefix else 0,
            slo=slo,
            slack_steps=(float("inf") if slack is None
                         else float(slack) * float(decode_len[i])),
        ))
    return out


def traffic_summary(trace: List[SyntheticSession]) -> dict:
    """Shape of a trace at a glance (the bench embeds this in its JSON)."""
    by_slo: Dict[str, int] = {}
    by_tenant: Dict[str, int] = {}
    shared = 0
    for s in trace:
        by_slo[s.slo] = by_slo.get(s.slo, 0) + 1
        by_tenant[s.tenant] = by_tenant.get(s.tenant, 0) + 1
        shared += s.prefix_id is not None
    return {
        "sessions": len(trace),
        "horizon_s": max((s.arrival for s in trace), default=0.0),
        "shared_prefix_frac": shared / len(trace) if trace else 0.0,
        "mean_prompt": (sum(s.prompt_len for s in trace) / len(trace)
                        if trace else 0.0),
        "mean_decode": (sum(s.decode_len for s in trace) / len(trace)
                        if trace else 0.0),
        "by_slo": by_slo,
        "by_tenant": by_tenant,
    }
