"""System design points of the paper's evaluation (§IV-§V).

Each :class:`SystemConfig` captures how one architecture partitions the
N=6 x B=25 GB/s high-bandwidth links between inter-device communication and
memory virtualization, plus the virtualization backing store's own limits:

  DC-DLA      all 6 links -> 3x8-node rings; virt over PCIe gen3 to host
  HC-DLA      3 links to CPU (75 GB/s virt), 3 links -> 1.5 rings; host
              socket overprovisioned to 300 GB/s for 4 devices (paper §IV)
  MC-DLA(S)   Fig 7(a/b): 2 links to a dedicated memory-node (50 GB/s
              virt), 4 links -> 2 rings; rings unbalanced (20-hop max)
  MC-DLA(L)   Fig 7(c) rings, LOCAL placement: one neighbour memory-node
              -> 3 links = 75 GB/s virt; 3x16-node rings for comm
  MC-DLA(B)   Fig 7(c) rings, BW_AWARE striping: left+right nodes
              -> 6 links = 150 GB/s virt; same 3x16-node rings
  DC-DLA(O)   oracle: infinite device memory, no virtualization traffic
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro import hw
from repro.core.tiers import TierSpec


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    name: str
    n_devices: int = 8
    device: hw.Chip = hw.PAPER_DEVICE

    # collective communication
    n_rings: float = 3.0            # concurrent rings
    ring_nodes: int = 8             # nodes per ring (hop count driver)
    ring_link_bw: float = 25e9      # per-direction GB/s of one ring link

    # memory virtualization — the backing store is a tier configuration:
    # "device" (oracle, nothing leaves HBM), "host" (DC/HC: PCIe or
    # dedicated links into host DRAM), "pooled" (MC: memory-nodes on the
    # device-side interconnect).  The TierSpec carries the same
    # bandwidth/capacity contract the executable tiers expose.
    tier_kind: str = "pooled"              # device | host | pooled
    virt_bw_per_device: float = 16e9       # stash/fetch bandwidth per device
    virt_shared_bw: float = 0.0            # host-side cap (0 = uncapped)
    cpu_socket_bw: float = hw.XEON_SOCKET_BW
    n_sockets: int = 2

    hop_latency_s: float = 0.5e-6          # per-hop ring latency
    msg_size: float = 4096.0               # ring message granularity (Fig 9)

    # serving wire: a KV handoff leg is carried by ``wire_streams``
    # parallel connections of ``wire_stream_bw`` each (single-socket TCP
    # tops out well below the link; striping aggregates narrow streams —
    # the TensorDIMM argument applied to the serving fabric), capped by
    # the backing tier and the DCN link in the simulator
    wire_streams: int = 1
    wire_stream_bw: float = 2.5e9

    @property
    def backing_tier(self) -> TierSpec:
        """The virtualization backing store as a tier contract."""
        return TierSpec(
            kind=self.tier_kind,
            bw_per_device=self.virt_bw_per_device,
            shared_bw=self.virt_shared_bw,
            uses_cpu=(self.tier_kind == "host"),
        )

    # legacy accessors (pre-tier API)
    @property
    def oracle(self) -> bool:
        return self.backing_tier.is_oracle

    @property
    def virt_uses_cpu(self) -> bool:
        return self.backing_tier.uses_cpu

    @property
    def comm_bw_per_device(self) -> float:
        """Aggregate ring-injection bandwidth per device."""
        return self.n_rings * self.ring_link_bw

    def effective_virt_bw(self, n_devices: int = 0) -> float:
        """Per-device virtualization bandwidth when ``n_devices`` stream
        concurrently — the paper's §I observation: the host-side bandwidth
        divides across the intra-node devices."""
        return self.backing_tier.effective_bw(n_devices or self.n_devices,
                                              self.n_sockets)

    def allreduce_time(self, nbytes: float) -> float:
        """Ring all-reduce of nbytes (per device) over the ring set."""
        n = self.ring_nodes
        if n <= 1 or self.comm_bw_per_device == 0:
            return 0.0
        steps = 2 * (n - 1)
        chunk = nbytes / n
        per_step = chunk / self.comm_bw_per_device + self.hop_latency_s
        return steps * per_step

    def allgather_time(self, nbytes: float) -> float:
        n = self.ring_nodes
        if n <= 1 or self.comm_bw_per_device == 0:
            return 0.0
        steps = n - 1
        chunk = nbytes / n
        return steps * (chunk / self.comm_bw_per_device + self.hop_latency_s)


PCIE = hw.PCIE_GEN3_BW
# DGX-1-style PCIe tree — see hw.PCIE_ROOT_PER_SOCKET: 8 GPUs streaming
# concurrently see ~8 GB/s each, not 16 (paper §I).
PCIE_ROOT_PER_SOCKET = hw.PCIE_ROOT_PER_SOCKET

DC_DLA = SystemConfig(
    name="DC-DLA", n_rings=3, ring_nodes=8, tier_kind="host",
    virt_bw_per_device=PCIE, virt_shared_bw=PCIE_ROOT_PER_SOCKET)

DC_DLA_GEN4 = dataclasses.replace(
    DC_DLA, name="DC-DLA(pcie4)", virt_bw_per_device=hw.PCIE_GEN4_BW,
    virt_shared_bw=2 * PCIE_ROOT_PER_SOCKET)

HC_DLA = SystemConfig(
    name="HC-DLA", n_rings=1.5, ring_nodes=8, tier_kind="host",
    virt_bw_per_device=3 * 25e9, virt_shared_bw=hw.HCDLA_SOCKET_BW,
    cpu_socket_bw=hw.HCDLA_SOCKET_BW)

MC_DLA_S = SystemConfig(
    name="MC-DLA(S)", n_rings=2, ring_nodes=14,   # unbalanced longest ring
    tier_kind="pooled", virt_bw_per_device=2 * 25e9)

MC_DLA_L = SystemConfig(
    name="MC-DLA(L)", n_rings=3, ring_nodes=16,
    tier_kind="pooled", virt_bw_per_device=3 * 25e9)

MC_DLA_B = SystemConfig(
    name="MC-DLA(B)", n_rings=3, ring_nodes=16,
    tier_kind="pooled", virt_bw_per_device=6 * 25e9)

DC_DLA_O = SystemConfig(
    name="DC-DLA(O)", n_rings=3, ring_nodes=8, tier_kind="device",
    virt_bw_per_device=float("inf"))

ALL_SYSTEMS = (DC_DLA, HC_DLA, MC_DLA_S, MC_DLA_L, MC_DLA_B, DC_DLA_O)
SYSTEMS_BY_NAME = {s.name: s for s in ALL_SYSTEMS}
