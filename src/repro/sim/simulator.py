"""Event-driven timeline simulator — the paper's evaluation methodology
(§IV): fixed-bandwidth memory channels, coarse-grained bulk DMA transfers,
double-buffered overlap of compute / synchronization / virtualization.

Three concurrent engines per device, as in the paper's system model:
  * the compute engine (PE array; time = max(FLOP-limited, HBM-limited)),
  * the DMA engine driving stash/prefetch to the backing store
    (host over PCIe for DC/HC, memory-nodes over the ring for MC),
  * the communication engine running ring collectives for DP/MP sync.

The forward pass stashes each layer's input feature map after its last use
(double-buffered: compute may run ahead of the DMA queue by one layer —
vDNN's memory-overlaying window); the backward pass prefetches one layer
ahead.  Cheap layers are recomputed, not stashed (footnote 4 — already
folded into the workload DAGs).

Outputs reproduce the paper's figures: the Fig. 11 latency breakdown (raw
per-category sums), Fig. 12 CPU-bandwidth usage, Fig. 13 speedups, Fig. 14
batch sensitivity, and §V-D scalability.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro import hw
from repro.core.dag import LayerDAG
from repro.sim.topology import SystemConfig

PE_EFFICIENCY = 0.5          # achievable fraction of peak on dense GEMM/conv


@dataclasses.dataclass
class StepResult:
    total: float                 # end-to-end iteration time (s)
    compute: float               # raw compute latency (Fig 11 category a)
    sync: float                  # raw synchronization latency (b)
    virt: float                  # raw virtualization latency (c)
    virt_bytes: float            # bytes moved to/from backing store
    cpu_bw_frac: float           # fraction of host memory BW consumed

    @property
    def breakdown(self) -> Tuple[float, float, float]:
        return (self.compute, self.sync, self.virt)


def _compute_time(flops: float, bytes_touched: float,
                  sys: SystemConfig) -> float:
    dev = sys.device
    return max(flops / (dev.peak_flops * PE_EFFICIENCY),
               bytes_touched / dev.hbm_bw)


def simulate(dag: LayerDAG, sys: SystemConfig, parallel: str = "dp",
             n_devices: int = None, virtualize: bool = True) -> StepResult:
    """One training iteration of `dag` on `sys` under dp/mp parallelism."""
    n = n_devices or sys.n_devices
    tier = sys.backing_tier          # DC/HC/MC as tier configurations
    virt_bw = tier.effective_bw(n, sys.n_sockets)
    L = dag.num_layers
    layers = dag.layers

    # per-device shares
    def c_fwd(i):
        f = layers[i].flops_fwd / n
        by = (layers[i].saved_bytes + layers[i].weight_bytes) / n * 2
        return _compute_time(f, by, sys)

    def c_bwd(i):
        return 2.0 * c_fwd(i)

    def stash_bytes(i):
        return layers[i].saved_bytes / n if virtualize and not tier.is_oracle \
            else 0.0

    # ---------------- forward ----------------
    t = 0.0                      # compute engine clock
    dma = 0.0                    # DMA engine clock
    comm = 0.0                   # comm engine clock
    stash_done = [0.0] * L
    raw_virt = 0.0
    raw_sync = 0.0
    raw_compute = 0.0

    for i in range(L):
        # vDNN window: layer i's compute waits for layer i-2's stash
        if i >= 2 and stash_bytes(i - 2) > 0:
            t = max(t, stash_done[i - 2])
        ct = c_fwd(i)
        raw_compute += ct
        t += ct
        if parallel == "mp" and layers[i].fc and n > 1:
            # Krizhevsky one-weird-trick MP: only FC/recurrent layers are
            # feature-split; all-gather the FULL feature map before the
            # next layer (blocking data dependency)
            ag = sys.allgather_time(layers[i].saved_bytes)
            raw_sync += ag
            t += ag
        sb = stash_bytes(i)
        if sb > 0:
            dma = max(dma, t) + sb / virt_bw
            stash_done[i] = dma
            raw_virt += sb / virt_bw

    # ---------------- backward ----------------
    fetch_done = [0.0] * L
    # prefetch pipeline primed with the last layer's X
    if stash_bytes(L - 1) > 0:
        dma = max(dma, t)
        dma += stash_bytes(L - 1) / virt_bw
        fetch_done[L - 1] = dma
        raw_virt += stash_bytes(L - 1) / virt_bw

    for i in range(L - 1, -1, -1):
        # prefetch one ahead (layer i-1) as soon as bwd of layer i starts
        if i >= 1 and stash_bytes(i - 1) > 0:
            dma = max(dma, t) + stash_bytes(i - 1) / virt_bw
            fetch_done[i - 1] = dma
            raw_virt += stash_bytes(i - 1) / virt_bw
        if stash_bytes(i) > 0:
            t = max(t, fetch_done[i])
        ct = c_bwd(i)
        raw_compute += ct
        t += ct
        if n == 1:
            pass                                  # single device: no sync
        elif parallel == "mp" and layers[i].fc:
            # dX partial sums (each device holds dX of the FULL input of its
            # feature shard) must reduce before layer i-1's backward; the
            # split weights need no dW sync.
            ar = sys.allreduce_time(layers[i].saved_bytes)
            raw_sync += ar
            t += ar
        elif layers[i].weight_bytes > 0:
            # data-parallel layers (all of DP mode; conv layers of MP mode):
            # dW all-reduce, overlapped with the remaining backward
            ar = sys.allreduce_time(layers[i].weight_bytes)
            raw_sync += ar
            comm = max(comm, t) + ar

    total = max(t, comm, dma)
    cpu_frac = 0.0
    if tier.uses_cpu and total > 0:
        moved = sum(stash_bytes(i) for i in range(L)) * 2 * n
        cpu_frac = (moved / total) / (sys.cpu_socket_bw * sys.n_sockets)
    return StepResult(total=total, compute=raw_compute, sync=raw_sync,
                      virt=raw_virt, virt_bytes=sum(
                          stash_bytes(i) for i in range(L)) * 2 * n,
                      cpu_bw_frac=cpu_frac)


# ---------------------------------------------------------------------------
def simulate_pipeline(dag: LayerDAG, sys: SystemConfig, n_stages: int,
                      n_micro: int, schedule: str = "1f1b",
                      virtualize: bool = True) -> StepResult:
    """Pipeline-parallel iteration over the paper's system design points.

    The layer DAG splits into ``n_stages`` contiguous stages over device
    groups of ``n_devices / n_stages``; ``n_micro`` microbatches stream
    through in T = M + S - 1 ticks (tick = the slowest stage's
    fwd + bwd per microbatch, bubble = (S-1) ticks).  Under ``1f1b`` each
    stage's saved microbatch inputs stream through the system's
    virtualization backing store — the pipeline-stage tier expressed in
    the DC/HC/MC ``TierSpec`` vocabulary — and a stage stalls when its
    per-microbatch DMA exceeds the tick; ``gpipe`` keeps activations
    resident (zero virtualization traffic, the whole cost is the bubble).
    """
    S, M = max(1, n_stages), max(1, n_micro)
    tier = sys.backing_tier
    stash = schedule == "1f1b" and virtualize and not tier.is_oracle
    per_stage = max(1, sys.n_devices // S)
    virt_bw = tier.effective_bw(per_stage, sys.n_sockets)
    L = dag.num_layers
    bounds = [round(s * L / S) for s in range(S + 1)]

    def stage_time(s: int) -> float:
        t = 0.0
        for l in dag.layers[bounds[s]:bounds[s + 1]]:
            f = l.flops_fwd / (M * per_stage)
            by = (l.saved_bytes + l.weight_bytes) / (M * per_stage) * 2
            t += 3.0 * _compute_time(f, by, sys)       # fwd + 2x bwd
        return t

    def stage_bytes(s: int) -> float:
        return sum(l.saved_bytes for l in dag.layers[bounds[s]:bounds[s + 1]]
                   if not l.cheap) / (M * per_stage)

    tick = max(stage_time(s) for s in range(S))
    bubble = (S - 1) * tick
    compute = M * sum(stage_time(s) for s in range(S))
    virt = 0.0
    stall = 0.0
    moved = 0.0
    if stash:
        for s in range(S):
            dma = 2.0 * stage_bytes(s) / virt_bw       # stash + fetch
            virt += M * dma
            stall += M * max(0.0, dma - tick)
            moved += 2.0 * stage_bytes(s) * M * per_stage
    total = (M + S - 1) * tick + stall
    cpu_frac = 0.0
    if tier.uses_cpu and total > 0 and moved > 0:
        cpu_frac = (moved / total) / (sys.cpu_socket_bw * sys.n_sockets)
    return StepResult(total=total, compute=compute, sync=bubble, virt=virt,
                      virt_bytes=moved, cpu_bw_frac=cpu_frac)


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CheckpointCost:
    system: str
    tier_kind: str               # device | host | pooled
    every: int                   # cadence (steps between snapshots)
    snapshot_bytes: float        # global bytes of one snapshot
    step_s: float                # simulated iteration time
    save_s: float                # one snapshot drain through the tier
    overhead_s: float            # amortized unhidden save, per step
    lost_s: float                # expected replay loss, per step
    async_saves: bool

    @property
    def total_s(self) -> float:
        return self.overhead_s + self.lost_s

    @property
    def overhead_frac(self) -> float:
        return self.total_s / self.step_s if self.step_s > 0 else 0.0


def simulate_checkpoint(dag: LayerDAG, sys: SystemConfig,
                        state_bytes: float, *,
                        every: int = 0, async_saves: bool = False,
                        mtbf_steps: int = 10_000,
                        parallel: str = "dp") -> CheckpointCost:
    """Snapshot-cost model over a system design point's backing tier.

    The snapshot (params + optimizer moments, sharded over the devices)
    drains through the same DC/HC/MC ``TierSpec`` the virtualization
    traffic uses — a checkpoint is cold pooled state riding the identical
    channel, so its cost obeys the same bandwidth-contention law
    (``effective_bw`` divides the host/pool bandwidth across concurrent
    devices).  ``every=0`` sweeps the Young-Daly cadence grid against the
    *simulated* step time and keeps the minimizer of amortized unhidden
    save + expected replay; async saves hide up to ``every . step`` of
    the drain behind the next steps.  The oracle design point snapshots
    HBM-to-HBM (nothing crosses a wire).
    """
    from repro.core.policy import CADENCE_CANDIDATES
    step = simulate(dag, sys, parallel).total
    tier = sys.backing_tier
    n = max(1, sys.n_devices)
    if tier.is_oracle:
        bw = sys.device.hbm_bw
    else:
        bw = tier.effective_bw(n, sys.n_sockets)
    save_s = (state_bytes / n) / bw if bw > 0 else 0.0
    cands = [every] if every > 0 else list(CADENCE_CANDIDATES)
    best = None
    for k in cands:
        unhidden = max(0.0, save_s - k * step) if async_saves else save_s
        overhead = unhidden / k
        lost = (k / 2.0) * step / max(mtbf_steps, 1)
        if best is None or overhead + lost < best[1] + best[2]:
            best = (k, overhead, lost)
    k, overhead, lost = best
    return CheckpointCost(system=sys.name, tier_kind=tier.kind, every=k,
                          snapshot_bytes=state_bytes, step_s=step,
                          save_s=save_s, overhead_s=overhead, lost_s=lost,
                          async_saves=async_saves)


def checkpoint_table(workloads: Dict[str, LayerDAG], systems,
                     state_bytes_of, *, mtbf_steps: int = 10_000,
                     async_saves: bool = True
                     ) -> Dict[str, Dict[str, CheckpointCost]]:
    """Per-workload checkpoint overhead across the system design points
    (the fault-tolerance analogue of :func:`speedup_table`).
    ``state_bytes_of``: workload name -> snapshot bytes."""
    out: Dict[str, Dict[str, CheckpointCost]] = {}
    for wname, dag in workloads.items():
        out[wname] = {}
        for s in systems:
            out[wname][s.name] = simulate_checkpoint(
                dag, s, state_bytes_of(wname), mtbf_steps=mtbf_steps,
                async_saves=async_saves)
    return out


# ---------------------------------------------------------------------------
def speedup_table(workloads: Dict[str, LayerDAG], systems,
                  parallel: str = "dp", baseline: str = "DC-DLA"
                  ) -> Dict[str, Dict[str, float]]:
    """Fig 13: per-workload speedup of every system over the baseline."""
    out: Dict[str, Dict[str, float]] = {}
    for wname, dag in workloads.items():
        base = simulate(dag, [s for s in systems
                              if s.name == baseline][0], parallel).total
        out[wname] = {}
        for s in systems:
            r = simulate(dag, s, parallel)
            out[wname][s.name] = base / r.total
    return out


def harmonic_mean(xs: List[float]) -> float:
    return len(xs) / sum(1.0 / x for x in xs)


# ---------------------------------------------------------------------------
# Cluster serving model (PR 7): the synthetic traffic of
# sim/workloads.generate_traffic evaluated analytically against DC/HC/MC
# tier configurations — the same placement policies the real Router runs,
# at a session count no single host can replay.


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """Per-token costs of the served model (decoder-only defaults sized
    like a 7B at bf16)."""

    flops_per_token: float = 2.0 * 7e9       # 2 * params per token
    weight_bytes: float = 14e9               # resident weights (bf16)
    kv_bytes_per_token: float = 524_288.0    # 32 layers x 2 x 4096 x bf16

    def kv_bytes(self, tokens: int) -> float:
        return self.kv_bytes_per_token * tokens


@dataclasses.dataclass
class ServingReport:
    """What one (trace, system, policy) evaluation produced."""

    policy: str
    system: str
    engines: int
    sessions: int
    finished: int
    tok_per_s: float
    ttft_mean_s: float
    ttft_p99_s: float
    slo_miss_rate: float
    mean_engine_util: float

    def rows(self):
        """Benchmark rows (name, value, note) for BENCH_router.json."""
        tag = f"{self.system}/{self.policy}"
        return [
            (f"{tag}/tok_per_s", self.tok_per_s,
             f"{self.engines} engines, {self.sessions} sessions"),
            (f"{tag}/ttft_mean_ms", self.ttft_mean_s * 1e3, "analytic"),
            (f"{tag}/ttft_p99_ms", self.ttft_p99_s * 1e3, "analytic"),
            (f"{tag}/slo_miss_rate", self.slo_miss_rate,
             "deadline classes only"),
            (f"{tag}/engine_util", self.mean_engine_util, "busy fraction"),
        ]


def simulate_serving(trace, sys: SystemConfig, *,
                     engines: int = 8,
                     placement: str = "least_loaded",
                     model: ModelProfile = ModelProfile(),
                     decode_slots: int = 16,
                     prefix_len: int = 8,
                     wire_streams: Optional[int] = None) -> ServingReport:
    """Session-level analytic replay of a synthetic trace.

    Each engine is a disaggregated pair abstracted to three resources,
    priced exactly as the step simulator prices layers: a serial prefill
    server (``max(FLOP-limited, HBM-limited)`` over the prompt), the KV
    handoff over the system's backing tier (``effective_bw`` under
    concurrent streamers, plus one DCN hop of latency — the wire), and
    ``decode_slots`` decode lanes whose per-token time is HBM-bound with
    the weight read amortized across resident lanes.  Placement reuses
    the REAL registry from serve/router.py (EngineView duck-typing), so
    the policy evaluated here is the policy the live cluster runs.

    O(N log N) in sessions: one pass in arrival order with per-engine
    finish-time heaps — a million-session day evaluates in seconds.
    """
    import heapq

    from repro.serve.router import EngineView, build_placement

    dev = sys.device
    tier = sys.backing_tier
    # every engine's handoff leg streams concurrently in the worst case;
    # the leg itself is wire_streams parallel connections, so the stripe
    # count is a third cap alongside the tier and the DCN link
    streams = sys.wire_streams if wire_streams is None else wire_streams
    handoff_bw = min(tier.effective_bw(engines, sys.n_sockets), hw.DCN_BW,
                     max(1, streams) * sys.wire_stream_bw)

    policy = build_placement(placement, **(
        {"prefix_len": prefix_len} if placement == "prefix_affinity" else {}))

    class _Probe:
        """Duck-types the Session surface placement policies touch."""

        class _Req:
            __slots__ = ("prompt",)

        def __init__(self, s):
            self.request = _Probe._Req()
            self.request.prompt = list(range(
                s.prefix_id * 1000, s.prefix_id * 1000 + prefix_len)) \
                if s.prefix_id is not None else [s.uid]

    prefill_free = [0.0] * engines
    decode_free = [[0.0] * decode_slots for _ in range(engines)]
    busy_s = [0.0] * engines
    inflight = [[] for _ in range(engines)]     # finish-time heaps
    window = decode_slots * 4                   # router-style backlog bound

    ttfts: List[float] = []
    missed = met = 0
    total_tokens = 0
    t_end = 0.0

    for s in sorted(trace, key=lambda x: (x.arrival, x.uid)):
        now = s.arrival
        for h in inflight:
            while h and h[0] <= now:
                heapq.heappop(h)
        views = [EngineView(i, len(inflight[i]),
                            window - len(inflight[i]))
                 for i in range(engines)]
        idx = policy.choose(views, _Probe(s))

        p_time = _compute_time(s.prompt_len * model.flops_per_token,
                               model.weight_bytes +
                               model.kv_bytes(s.prompt_len), sys)
        p_start = max(now, prefill_free[idx])
        p_end = p_start + p_time
        prefill_free[idx] = p_end

        handoff = model.kv_bytes(s.prompt_len) / handoff_bw \
            + hw.DCN_LATENCY_S

        lanes = decode_free[idx]
        lane = min(range(decode_slots), key=lanes.__getitem__)
        mid_len = s.prompt_len + s.decode_len / 2.0
        tok_time = max(
            model.flops_per_token / (dev.peak_flops * PE_EFFICIENCY),
            (model.weight_bytes / decode_slots +
             model.kv_bytes(int(mid_len))) / dev.hbm_bw)
        d_start = max(p_end + handoff, lanes[lane])
        first_tok = d_start + tok_time
        d_end = d_start + s.decode_len * tok_time
        lanes[lane] = d_end

        ttfts.append(first_tok - now)
        busy_s[idx] += p_time + s.decode_len * tok_time
        heapq.heappush(inflight[idx], d_end)
        total_tokens += s.decode_len
        t_end = max(t_end, d_end)

        if math.isfinite(s.slack_steps):
            deadline = now + s.slack_steps * tok_time
            if d_end <= deadline:
                met += 1
            else:
                missed += 1

    ttfts.sort()
    n = len(ttfts)
    span = max(t_end - min(s.arrival for s in trace), 1e-9) if trace else 1.0
    return ServingReport(
        policy=getattr(policy, "name", str(placement)),
        system=sys.name,
        engines=engines,
        sessions=len(trace),
        finished=n,
        tok_per_s=total_tokens / span,
        ttft_mean_s=sum(ttfts) / n if n else 0.0,
        ttft_p99_s=ttfts[min(n - 1, int(0.99 * n))] if n else 0.0,
        slo_miss_rate=missed / (met + missed) if (met + missed) else 0.0,
        mean_engine_util=sum(busy_s) / (engines * span),
    )


def serving_table(trace, systems, *, policies=("least_loaded",
                                               "prefix_affinity",
                                               "round_robin"),
                  engines: int = 8, **kwargs) -> List[ServingReport]:
    """The policy x system sweep behind BENCH_router.json."""
    return [simulate_serving(trace, sys, engines=engines,
                             placement=pol, **kwargs)
            for sys in systems for pol in policies]
