import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# §Perf hillclimbing driver: run one (arch x shape) cell under a list of
# named variants (sharding/placement/compression/accum changes), print the
# roofline terms per variant, and append the hypothesis log to a JSON.
#
#   PYTHONPATH=src python -m repro.launch.hillclimb --arch llama4-maverick-400b \
#       --shape train_4k --variants baseline,local,fp8,bits8 --out reports/hc.json
#
# Serving-wire sweep (probe_wire MB/s instead of a lowered cell):
#   PYTHONPATH=src python -m repro.launch.hillclimb --wire \
#       --variants wire-baseline,wire-streams-4,shm --out reports/hc.json

import argparse            # noqa: E402
import json                # noqa: E402
from typing import Dict    # noqa: E402

from repro.launch.dryrun import lower_cell                 # noqa: E402
from repro.launch.mesh import make_production_mesh         # noqa: E402
from repro.launch.roofline import analyze_cell             # noqa: E402

# named variants: kwargs overrides for lower_cell
VARIANTS: Dict[str, Dict] = {
    "baseline": {},                                  # paper-faithful mcdla
    "local": {"placement": "local"},
    "fp8": {"compress": "fp8"},
    "bits8": {"opt_bits": 8},
    "accum2": {"accum": 2},
    "accum4": {"accum": 4},
    "no-sp": {"seq_parallel": False},
    "auto": {"policy": "auto"},
    "oracle": {"policy": "none"},
    "local+fp8": {"placement": "local", "compress": "fp8"},
    "local+fp8+bits8": {"placement": "local", "compress": "fp8",
                        "opt_bits": 8},
    "local+bits8": {"placement": "local", "opt_bits": 8},
    "local+bits8+accum4": {"placement": "local", "opt_bits": 8, "accum": 4},
    "no-aux-stash": {"stash_aux": False},
    "bits8+accum2": {"opt_bits": 8, "accum": 2},
}

# serving-wire variants: kwargs overrides for serve/transport.probe_wire.
# Swept with ``--wire`` instead of a training cell — the wire config joins
# the same hypothesis log ahead of the global autotuner.
WIRE_VARIANTS: Dict[str, Dict] = {
    "wire-baseline": {"transport": "tcp", "streams": 1},
    "wire-bufsize-4m": {"transport": "tcp", "streams": 1,
                        "bufsize": 4 << 20},
    "wire-streams-2": {"transport": "tcp", "streams": 2},
    "wire-streams-4": {"transport": "tcp", "streams": 4},
    "wire-streams-8": {"transport": "tcp", "streams": 8},
    "wire-streams-4+int8": {"transport": "tcp", "streams": 4,
                            "codec": "int8"},
    "shm": {"transport": "shm", "streams": 1},
}


def _run_wire(args) -> list:
    from repro.serve.transport import probe_wire
    rows = []
    for name in args.variants.split(","):
        kw = WIRE_VARIANTS[name]
        try:
            r = probe_wire(payload_mb=args.payload_mb, **kw)
            rows.append({"variant": name, **r})
            print(f"[{name:>18s}] {r['mb_per_s']:8.1f} MB/s "
                  f"handoff={r['handoff_ms']:.1f}ms "
                  f"wire={int(r['wire_bytes'])}B")
        except Exception as e:  # noqa: BLE001
            rows.append({"variant": name, "error": str(e)})
            print(f"[{name:>18s}] FAILED: {e}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--variants", default="baseline,local")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--wire", action="store_true",
                    help="sweep WIRE_VARIANTS via probe_wire instead of "
                         "lowering a training cell")
    ap.add_argument("--payload-mb", type=float, default=64.0,
                    help="handoff payload for --wire probes")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    if args.wire:
        rows = _run_wire(args)
        if args.out:
            existing = []
            if os.path.exists(args.out):
                with open(args.out) as f:
                    existing = json.load(f)
            existing.append({"arch": "wire", "shape": f"{args.payload_mb}mb",
                             "rows": rows})
            with open(args.out, "w") as f:
                json.dump(existing, f, indent=1, default=str)
        return
    if not args.arch or not args.shape:
        ap.error("--arch and --shape are required without --wire")

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    rows = []
    for name in args.variants.split(","):
        kw = VARIANTS[name]
        try:
            r = lower_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                           probes=not args.no_probes, mesh=mesh, **kw)
            a = analyze_cell(r)
            rows.append({"variant": name, **a,
                         "temp_gb": r["temp_bytes_per_dev"] / 1e9,
                         "arg_gb": r["arg_bytes_per_dev"] / 1e9,
                         "collectives": r["collectives"]})
            print(f"[{name:>18s}] compute={a['compute_s']:.3f}s "
                  f"memory={a['memory_s']:.3f}s coll={a['collective_s']:.3f}s "
                  f"dom={a['dominant']:10s} frac={a['roofline_fraction']:.2%} "
                  f"args={r['arg_bytes_per_dev']/1e9:.1f}GB "
                  f"temp={r['temp_bytes_per_dev']/1e9:.1f}GB")
        except Exception as e:  # noqa: BLE001
            rows.append({"variant": name, "error": str(e)})
            print(f"[{name:>18s}] FAILED: {e}")
    if args.out:
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        existing.append({"arch": args.arch, "shape": args.shape,
                         "rows": rows})
        with open(args.out, "w") as f:
            json.dump(existing, f, indent=1, default=str)


if __name__ == "__main__":
    main()
