import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# §Perf hillclimbing driver: run one (arch x shape) cell under a list of
# named variants (sharding/placement/compression/accum changes), print the
# roofline terms per variant, and append the hypothesis log to a JSON.
#
#   PYTHONPATH=src python -m repro.launch.hillclimb --arch llama4-maverick-400b \
#       --shape train_4k --variants baseline,local,fp8,bits8 --out reports/hc.json

import argparse            # noqa: E402
import json                # noqa: E402
from typing import Dict    # noqa: E402

from repro.launch.dryrun import lower_cell                 # noqa: E402
from repro.launch.mesh import make_production_mesh         # noqa: E402
from repro.launch.roofline import analyze_cell             # noqa: E402

# named variants: kwargs overrides for lower_cell
VARIANTS: Dict[str, Dict] = {
    "baseline": {},                                  # paper-faithful mcdla
    "local": {"placement": "local"},
    "fp8": {"compress": "fp8"},
    "bits8": {"opt_bits": 8},
    "accum2": {"accum": 2},
    "accum4": {"accum": 4},
    "no-sp": {"seq_parallel": False},
    "auto": {"policy": "auto"},
    "oracle": {"policy": "none"},
    "local+fp8": {"placement": "local", "compress": "fp8"},
    "local+fp8+bits8": {"placement": "local", "compress": "fp8",
                        "opt_bits": 8},
    "local+bits8": {"placement": "local", "opt_bits": 8},
    "local+bits8+accum4": {"placement": "local", "opt_bits": 8, "accum": 4},
    "no-aux-stash": {"stash_aux": False},
    "bits8+accum2": {"opt_bits": 8, "accum": 2},
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline,local")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    rows = []
    for name in args.variants.split(","):
        kw = VARIANTS[name]
        try:
            r = lower_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                           probes=not args.no_probes, mesh=mesh, **kw)
            a = analyze_cell(r)
            rows.append({"variant": name, **a,
                         "temp_gb": r["temp_bytes_per_dev"] / 1e9,
                         "arg_gb": r["arg_bytes_per_dev"] / 1e9,
                         "collectives": r["collectives"]})
            print(f"[{name:>18s}] compute={a['compute_s']:.3f}s "
                  f"memory={a['memory_s']:.3f}s coll={a['collective_s']:.3f}s "
                  f"dom={a['dominant']:10s} frac={a['roofline_fraction']:.2%} "
                  f"args={r['arg_bytes_per_dev']/1e9:.1f}GB "
                  f"temp={r['temp_bytes_per_dev']/1e9:.1f}GB")
        except Exception as e:  # noqa: BLE001
            rows.append({"variant": name, "error": str(e)})
            print(f"[{name:>18s}] FAILED: {e}")
    if args.out:
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        existing.append({"arch": args.arch, "shape": args.shape,
                         "rows": rows})
        with open(args.out, "w") as f:
            json.dump(existing, f, indent=1, default=str)


if __name__ == "__main__":
    main()
