"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

On real TPU hardware this launches the full config against the production
mesh; on the CPU container use ``--smoke`` for the reduced same-family twin
(this is how examples/train_smollm.py trains a ~100M model end-to-end).
"""
from __future__ import annotations

import argparse
import dataclasses
import logging

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import (MemoryPlan, PipelinePlan, RunConfig,
                           SHAPES_BY_NAME, TrainConfig, get_arch)
from repro.configs.base import CheckpointPlan, MeshPlan, ShapeConfig
from repro.core.dag import build_dag
from repro.core.policy import plan_memory, summarize
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.launch.mesh import make_host_mesh, make_production_mesh, plan_for
from repro.models.model import build_model
from repro.train.chaos import ChaosMonkey, ChaosSchedule
from repro.train.elastic import ElasticController
from repro.train.fault import FaultHandler
from repro.train.loop import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + small batch on local devices")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--policy", default="mcdla")
    ap.add_argument("--placement", default="bw_aware")
    ap.add_argument("--compress", default="none")
    ap.add_argument("--opt-bits", type=int, default=32)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--pipeline", action="store_true",
                    help="run the pod axis as a pipeline of layer stages "
                         "(parallel/pipeline.py schedule registry)")
    ap.add_argument("--pipeline-schedule", default="1f1b",
                    help="registered schedule: gpipe | 1f1b")
    ap.add_argument("--n-micro", type=int, default=0,
                    help="microbatches per step (0: planner-chosen by the "
                         "bubble-vs-stall cost model)")
    ap.add_argument("--pipeline-stages", type=int, default=0,
                    help="pipeline stages (0: all local devices)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-tier", default="",
                    help="checkpoint through a tier stack: host | mcdla | "
                         "spill (empty: legacy direct writes)")
    ap.add_argument("--ckpt-codec", default="none",
                    help="snapshot codec: none | fp8 | int8 (lossy codecs "
                         "trade restore bit-exactness for pool bytes)")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint cadence in steps (0: planner-chosen "
                         "Young-Daly cadence when --ckpt-tier is set)")
    ap.add_argument("--ckpt-async", action="store_true",
                    help="double-buffered saves overlapping the next steps")
    ap.add_argument("--ckpt-shards", type=int, default=1)
    ap.add_argument("--mtbf-steps", type=int, default=10_000,
                    help="expected steps between failures (cadence planner)")
    ap.add_argument("--chaos", default="",
                    help="fault-injection schedule, e.g. "
                         "'kill@3,corrupt@5,stage_loss@7:1,preempt@9', or "
                         "'random:<seed>' for a seeded random schedule")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
        mesh = make_host_mesh()
        n = len(jax.devices())
        plan = MeshPlan((2, n // 2), ("data", "model")) if mesh is not None \
            else MeshPlan((1,), ("data",))
        batch = args.batch or max(4, n)
        seq = args.seq or 128
    else:
        n = len(jax.devices())
        need = 512 if args.multi_pod else 256
        if n >= need:
            mesh = make_production_mesh(multi_pod=args.multi_pod)
            plan = plan_for(multi_pod=args.multi_pod)
        else:
            # full-size model on whatever devices exist (CPU end-to-end
            # driver: examples/train_smollm.py)
            mesh = make_host_mesh()
            plan = MeshPlan((2, n // 2), ("data", "model")) if mesh is not \
                None else MeshPlan((1,), ("data",))
        sh = SHAPES_BY_NAME[args.shape]
        batch = args.batch or sh.global_batch
        seq = args.seq or sh.seq_len

    pipeline = PipelinePlan()
    pipe_mesh = None
    if args.pipeline:
        # the pipeline owns the pod axis: stages on a dedicated 1D mesh,
        # the model itself unsharded (stage stash placement is the tier's)
        if args.multi_pod:
            raise SystemExit("--pipeline replaces pod-DP with pipeline "
                             "stages; a multi-pod pipeline+DP composition "
                             "is not implemented (see ROADMAP)")
        devs = jax.devices()
        n_stages = args.pipeline_stages or len(devs)
        if len(devs) < n_stages:
            raise SystemExit(f"--pipeline-stages {n_stages} needs that many "
                             f"devices (have {len(devs)})")
        if n_stages > 1:
            pipe_mesh = Mesh(np.array(devs[:n_stages]), ("pod",))
        mesh = None
        plan = MeshPlan((1,), ("data",))
        pipeline = PipelinePlan(enabled=True,
                                schedule=args.pipeline_schedule,
                                n_micro=args.n_micro, n_stages=n_stages)
        pipeline.validate()

    shape = ShapeConfig("train", seq, batch, "train")
    tc = TrainConfig(total_steps=args.steps, warmup_steps=args.steps // 10,
                     learning_rate=args.lr, grad_accum=args.accum,
                     checkpoint_dir=args.ckpt_dir,
                     checkpoint_every=max(25, args.steps // 4),
                     log_every=args.log_every)
    memory = MemoryPlan(policy=args.policy, placement=args.placement,
                        compress=args.compress, opt_state_bits=args.opt_bits)
    ckpt = CheckpointPlan(enabled=bool(args.ckpt_tier),
                          tier=args.ckpt_tier or "host",
                          codec=args.ckpt_codec, every=args.ckpt_every,
                          async_saves=args.ckpt_async,
                          shards=args.ckpt_shards,
                          mtbf_steps=args.mtbf_steps)
    if ckpt.enabled:
        ckpt.validate()
    run = RunConfig(model=cfg, shape=shape, mesh=plan, memory=memory,
                    train=tc, pipeline=pipeline, ckpt=ckpt)
    model = build_model(run, mesh=mesh, pipe_mesh=pipe_mesh)
    log = logging.getLogger(__name__)
    if model.pipeline_report is not None:
        log.info("pipeline plan: %s", summarize(model.pipeline_report))
    if ckpt.enabled and ckpt.every == 0:
        # plan the save cadence (Young-Daly sweep) against the analytic
        # step time through the configured tier stack
        dag = build_dag(cfg, shape)
        opt_bytes = 4 + 2 * memory.opt_state_bits // 8
        report = plan_memory(dag, plan, memory,
                             model_state_bytes=cfg.param_count() * opt_bytes,
                             checkpoint=ckpt)
        ckpt = dataclasses.replace(ckpt, every=report.checkpoint.every)
        log.info("checkpoint plan: every=%d steps (save=%.2fs overhead="
                 "%.2fms/step lost=%.2fms/step via %s)",
                 report.checkpoint.every, report.checkpoint.save_s,
                 1e3 * report.checkpoint.overhead_s,
                 1e3 * report.checkpoint.lost_s, report.checkpoint.tier)

    chaos = None
    if args.chaos:
        if args.chaos.startswith("random:"):
            sched = ChaosSchedule.random(int(args.chaos.split(":", 1)[1]),
                                         args.steps)
        else:
            sched = ChaosSchedule.parse(args.chaos)
        chaos = ChaosMonkey(sched, seed=tc.seed)
        log.info("chaos schedule: %s", sched.spec())

    handler = FaultHandler()
    source = SyntheticLM(cfg, batch=batch, seq=seq, seed=tc.seed)
    if chaos is not None:
        # the chaos/elastic path rewinds the stream mid-run (set_state);
        # feed the loop the raw resumable source, not a prefetch queue
        # holding stale lookahead batches
        from repro.train.loop import make_manager
        mgr = make_manager(model, tc, ckpt, chaos)
        elastic = ElasticController(run, mgr, mesh=mesh, pipe_mesh=pipe_mesh)
        state, metrics = train(model, tc, source, fault_handler=handler,
                               ckpt=ckpt, chaos=chaos, elastic=elastic,
                               mgr=mgr)
        print({k: float(v) for k, v in metrics.items()})
        return
    data = Prefetcher(source)
    try:
        state, metrics = train(model, tc, iter(data), fault_handler=handler,
                               ckpt=ckpt)
        print({k: float(v) for k, v in metrics.items()})
    finally:
        data.close()


if __name__ == "__main__":
    main()
