"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

On real TPU hardware this launches the full config against the production
mesh; on the CPU container use ``--smoke`` for the reduced same-family twin
(this is how examples/train_smollm.py trains a ~100M model end-to-end).
"""
from __future__ import annotations

import argparse
import dataclasses
import logging

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import (MemoryPlan, PipelinePlan, RunConfig,
                           SHAPES_BY_NAME, TrainConfig, get_arch)
from repro.configs.base import MeshPlan, ShapeConfig
from repro.core.policy import summarize
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.launch.mesh import make_host_mesh, make_production_mesh, plan_for
from repro.models.model import build_model
from repro.train.fault import FaultHandler
from repro.train.loop import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + small batch on local devices")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--policy", default="mcdla")
    ap.add_argument("--placement", default="bw_aware")
    ap.add_argument("--compress", default="none")
    ap.add_argument("--opt-bits", type=int, default=32)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--pipeline", action="store_true",
                    help="run the pod axis as a pipeline of layer stages "
                         "(parallel/pipeline.py schedule registry)")
    ap.add_argument("--pipeline-schedule", default="1f1b",
                    help="registered schedule: gpipe | 1f1b")
    ap.add_argument("--n-micro", type=int, default=0,
                    help="microbatches per step (0: planner-chosen by the "
                         "bubble-vs-stall cost model)")
    ap.add_argument("--pipeline-stages", type=int, default=0,
                    help="pipeline stages (0: all local devices)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
        mesh = make_host_mesh()
        n = len(jax.devices())
        plan = MeshPlan((2, n // 2), ("data", "model")) if mesh is not None \
            else MeshPlan((1,), ("data",))
        batch = args.batch or max(4, n)
        seq = args.seq or 128
    else:
        n = len(jax.devices())
        need = 512 if args.multi_pod else 256
        if n >= need:
            mesh = make_production_mesh(multi_pod=args.multi_pod)
            plan = plan_for(multi_pod=args.multi_pod)
        else:
            # full-size model on whatever devices exist (CPU end-to-end
            # driver: examples/train_smollm.py)
            mesh = make_host_mesh()
            plan = MeshPlan((2, n // 2), ("data", "model")) if mesh is not \
                None else MeshPlan((1,), ("data",))
        sh = SHAPES_BY_NAME[args.shape]
        batch = args.batch or sh.global_batch
        seq = args.seq or sh.seq_len

    pipeline = PipelinePlan()
    pipe_mesh = None
    if args.pipeline:
        # the pipeline owns the pod axis: stages on a dedicated 1D mesh,
        # the model itself unsharded (stage stash placement is the tier's)
        if args.multi_pod:
            raise SystemExit("--pipeline replaces pod-DP with pipeline "
                             "stages; a multi-pod pipeline+DP composition "
                             "is not implemented (see ROADMAP)")
        devs = jax.devices()
        n_stages = args.pipeline_stages or len(devs)
        if len(devs) < n_stages:
            raise SystemExit(f"--pipeline-stages {n_stages} needs that many "
                             f"devices (have {len(devs)})")
        if n_stages > 1:
            pipe_mesh = Mesh(np.array(devs[:n_stages]), ("pod",))
        mesh = None
        plan = MeshPlan((1,), ("data",))
        pipeline = PipelinePlan(enabled=True,
                                schedule=args.pipeline_schedule,
                                n_micro=args.n_micro, n_stages=n_stages)
        pipeline.validate()

    shape = ShapeConfig("train", seq, batch, "train")
    tc = TrainConfig(total_steps=args.steps, warmup_steps=args.steps // 10,
                     learning_rate=args.lr, grad_accum=args.accum,
                     checkpoint_dir=args.ckpt_dir,
                     checkpoint_every=max(25, args.steps // 4),
                     log_every=args.log_every)
    memory = MemoryPlan(policy=args.policy, placement=args.placement,
                        compress=args.compress, opt_state_bits=args.opt_bits)
    run = RunConfig(model=cfg, shape=shape, mesh=plan, memory=memory,
                    train=tc, pipeline=pipeline)
    model = build_model(run, mesh=mesh, pipe_mesh=pipe_mesh)
    if model.pipeline_report is not None:
        logging.getLogger(__name__).info(
            "pipeline plan: %s", summarize(model.pipeline_report))
    data = Prefetcher(SyntheticLM(cfg, batch=batch, seq=seq, seed=tc.seed))
    handler = FaultHandler()
    try:
        state, metrics = train(model, tc, iter(data), fault_handler=handler)
        print({k: float(v) for k, v in metrics.items()})
    finally:
        data.close()


if __name__ == "__main__":
    main()
