"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before any jax
initialization).
"""
from __future__ import annotations

import jax

from repro.configs.base import MeshPlan, MULTI_POD, SINGLE_POD


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def plan_for(*, multi_pod: bool = False) -> MeshPlan:
    return MULTI_POD if multi_pod else SINGLE_POD


def make_host_mesh(n: int = 0):
    """Small mesh over whatever local devices exist (CPU tests/examples)."""
    devs = jax.devices()
    n = n or len(devs)
    if n == 1:
        return None
    d = 2 if n % 2 == 0 else 1
    return jax.make_mesh((d, n // d), ("data", "model"))
