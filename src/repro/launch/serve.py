"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the tier-aware serving stack (serve/engine.py facade over Scheduler /
KVCacheManager / Session) over pooled KV caches.  On the CPU container use
``--smoke`` for the reduced twin; on TPU the full config serves against
the production mesh with the cache striped across the pool.

``--batch`` / ``--max-len`` may be omitted: the cache manager then sizes
the decode slots from the serving tier's ``cache_tier_report``.  Cold KV
(preempted sessions under ``--scheduler fair/priority/srpt/deadline``)
goes to the ``--spill`` tier; with ``--page-size`` the cache is *paged* —
cold pages spill lazily, per page, through the per-tenant ``--page-codec``
— and ``--pages`` overcommits the pool below batch x pages_per_slot.
``--tenant-quota`` caps what each tenant may hold (see
serve/quota.parse_quota_spec for the grammar); ``--tenants N`` spreads the
synthetic requests over N tenant names.  The run prints the spill/page
traffic report, per-tenant usage and (for ``--scheduler deadline``, with
``--deadline-slack`` steps of slack) the deadline-miss accounting.

``--role`` disaggregates prefill from decode (serve/disagg.py):
``both`` runs the two-engine loopback in this process — prompts prefill
on a prefill-role engine, KV pages ship through the ``--transfer-tier``
(metered, printed as the transfer report with time-to-first-token), and
a decode-role engine adopts them; ``prefill`` runs the prefill worker
alone (publishes into a local queue and reports what shipped — useful to
price the transfer path); ``decode --connect HOST:PORT`` runs the decode
worker of a two-process deployment over the TCP wire transport
(serve/transport.py), adopting handoffs off the socket and streaming
RESULTs back — standalone decode without ``--connect`` is still
rejected.  Omit ``--role`` for the classic colocated engine.

``--router`` runs the cluster front-end (serve/router.py) over
``--engines`` prefill/decode pairs with ``--placement`` choosing where
sessions land; ``--transport memory|tcp`` makes engine 0 a wire pair
(every page byte-serialized through frames), ``--listen PORT`` makes it
the prefill half of a two-process pair (start the peer with ``--role
decode --connect``), ``--drain-after N`` gracefully drains
``--drain-engine`` after N router steps (the CI smoke asserts zero
dropped sessions), and ``--trace N`` replays N sessions of the synthetic
diurnal/burst/shared-prefix traffic mix (sim/workloads.py) instead of
the uniform synthetic requests.

Scale-out wire: ``--wire-streams N`` stripes each handoff page-wise over
N parallel TCP connections (both the ``--listen`` prefill half and the
``--connect`` decode worker must agree), ``--wire-bufsize`` sizes the
socket buffers, ``--transport shm`` takes the zero-copy same-host path
(payloads through a shared-memory arena, headers over the socket), and
``--peer HOST:PORT`` / ``--fed-listen PORT`` federate two router
processes so overflow admissions forward to the peer cluster.
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import numpy as np

from repro.configs import MemoryPlan, RunConfig, TrainConfig, get_arch
from repro.configs.base import MeshPlan, ShapeConfig
from repro.core.runtime import MemoryRuntime
from repro.launch.mesh import make_host_mesh, make_production_mesh, plan_for
from repro.models.model import build_model
from repro.serve.disagg import TransferQueue, build_disagg
from repro.serve.engine import Engine, Request
from repro.serve.quota import quota_from_cli
from repro.serve.scheduler import build_scheduler, registered_schedulers


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=None,
                    help="decode slots (default: auto from the tier report)")
    ap.add_argument("--max-len", type=int, default=None,
                    help="cache rows per slot (default: auto)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--stagger", type=int, default=0,
                    help="request i decodes new-tokens + i*stagger tokens "
                         "(unequal service times: lets srpt/deadline sort)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--scheduler", default="fcfs",
                    choices=registered_schedulers())
    ap.add_argument("--quantum", type=int, default=8,
                    help="fair-scheduler decode quantum")
    ap.add_argument("--spill", default="spill",
                    help="secondary tier policy for cold KV")
    ap.add_argument("--page-size", type=int, default=None,
                    help="page the KV cache (rows per page; default: "
                         "monolithic slots)")
    ap.add_argument("--pages", type=int, default=None,
                    help="page-pool size (default batch*max_len/page_size; "
                         "smaller overcommits)")
    ap.add_argument("--page-codec", default=None,
                    help="default spill codec for cold pages (fp8/int8/...)")
    ap.add_argument("--prefix-share", action="store_true",
                    help="share common prompt-prefix pages copy-on-write "
                         "across sessions (paged cache only)")
    ap.add_argument("--decode-kernel", action="store_true",
                    help="decode in place over the page table (paged "
                         "attention kernel; reads only the pages each "
                         "session holds instead of gathering the pool)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="draw the first N prompt tokens from a common "
                         "prefix so --prefix-share has something to hit")
    ap.add_argument("--tenant-quota", default=None,
                    help="per-tenant caps, e.g. 'pages=16,sessions=2' or "
                         "'a:pages=8;b:sessions=1,codec=int8'")
    ap.add_argument("--tenants", type=int, default=1,
                    help="spread requests over N tenant names t0..tN-1")
    ap.add_argument("--deadline-slack", type=int, default=None,
                    help="per-request deadline = slack + (i+1)*new-tokens "
                         "engine steps (with --scheduler deadline)")
    ap.add_argument("--role", default=None,
                    choices=("prefill", "decode", "both"),
                    help="disaggregate prefill/decode (both: in-process "
                         "two-engine loopback; default: colocated engine)")
    ap.add_argument("--transfer-tier", default="spill",
                    help="tier policy carrying KV handoffs between roles "
                         "(spill: pooled HBM->host; host: PCIe DRAM)")
    ap.add_argument("--transfer-depth", type=int, default=None,
                    help="max handoffs parked in the transfer queue "
                         "(prefill admission stalls past it)")
    ap.add_argument("--router", action="store_true",
                    help="run the cluster router over --engines pairs")
    ap.add_argument("--engines", type=int, default=2,
                    help="prefill/decode pairs behind the router")
    ap.add_argument("--placement", default="least_loaded",
                    help="placement policy "
                         "(least_loaded/prefix_affinity/round_robin)")
    ap.add_argument("--transport", default=None,
                    choices=("memory", "tcp", "shm"),
                    help="make router engine 0 a wire pair over this "
                         "byte channel (pages cross as serialized frames; "
                         "shm: zero-copy same-host arena, only headers "
                         "cross the socket)")
    ap.add_argument("--wire-streams", type=int, default=1,
                    help="stripe each wire handoff page-wise across N "
                         "parallel sub-channels (1: single stream)")
    ap.add_argument("--wire-bufsize", type=int, default=None,
                    help="SO_SNDBUF/SO_RCVBUF for wire TCP sockets "
                         "(default: kernel autotuning)")
    ap.add_argument("--peer", default=None, metavar="HOST:PORT",
                    help="router mode: federate with the router at this "
                         "address (forward admissions we cannot place)")
    ap.add_argument("--fed-listen", type=int, default=None,
                    help="router mode: accept one federation peer on this "
                         "port (0: ephemeral, printed)")
    ap.add_argument("--listen", type=int, default=None,
                    help="two-process mode: engine 0 (or --role prefill) "
                         "serves prefill over TCP on this port (0: "
                         "ephemeral, printed); peer runs --role decode "
                         "--connect")
    ap.add_argument("--connect", default=None, metavar="HOST:PORT",
                    help="with --role decode: adopt handoffs from this "
                         "prefill/router process")
    ap.add_argument("--drain-after", type=int, default=None,
                    help="router mode: gracefully drain --drain-engine "
                         "after N router steps")
    ap.add_argument("--drain-engine", type=int, default=0)
    ap.add_argument("--trace", type=int, default=None,
                    help="router mode: replay N synthetic traffic "
                         "sessions (diurnal/burst/shared-prefix mix)")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    if args.role == "decode" and args.connect is None:
        ap.error("--role decode needs a peer feeding the transfer queue; "
                 "use --role both for the in-process loopback, or pass "
                 "--connect HOST:PORT for the two-process wire")
    if (args.role is not None or args.router) and not args.page_size:
        ap.error("--role/--router ship page-shaped KV: pass --page-size")
    if args.prefix_share and not args.page_size:
        ap.error("--prefix-share reuses whole pages: pass --page-size")
    if args.prefix_share and (args.role is not None or args.router):
        ap.error("--prefix-share is a colocated-engine feature for now")
    if args.decode_kernel and not args.page_size:
        ap.error("--decode-kernel reads through the page table: pass "
                 "--page-size")
    if args.decode_kernel and (args.role is not None or args.router):
        ap.error("--decode-kernel is a colocated-engine feature for now")
    if args.listen is not None and args.batch is None:
        ap.error("--listen needs explicit --batch/--max-len (the remote "
                 "decode geometry cannot be negotiated over the wire)")
    if args.wire_streams < 1:
        ap.error("--wire-streams must be >= 1")
    if args.wire_streams > 1 and args.transport == "shm":
        ap.error("--transport shm is header-only on one control socket; "
                 "striping it is meaningless (drop --wire-streams)")
    if args.trace and (args.peer or args.fed_listen is not None):
        ap.error("--trace replays against one cluster; it does not "
                 "compose with federation (--peer/--fed-listen)")
    logging.basicConfig(level=logging.INFO)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
        mesh = make_host_mesh()
        n = len(jax.devices())
        plan = MeshPlan((2, n // 2), ("data", "model")) if mesh is not None \
            else MeshPlan((1,), ("data",))
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        plan = plan_for(multi_pod=args.multi_pod)

    shape = ShapeConfig("serve", args.max_len or 128, args.batch or 4,
                        "decode")
    run = RunConfig(model=cfg, shape=shape, mesh=plan,
                    memory=MemoryPlan(policy="none"), train=TrainConfig())
    model = build_model(run, mesh=mesh)
    params = model.init(jax.random.PRNGKey(0))

    quota = quota_from_cli(args.tenant_quota, args.page_codec)

    if args.role == "decode":
        _run_decode_worker(model, params, args)
        return
    if args.router:
        _run_router(model, params, cfg, quota, args)
        return

    sched = (build_scheduler("fair", quantum=args.quantum)
             if args.scheduler == "fair" else build_scheduler(args.scheduler))
    if args.role == "both":
        eng = build_disagg(model, params, batch=args.batch,
                           max_len=args.max_len, page_size=args.page_size,
                           pages=args.pages, transfer=args.transfer_tier,
                           max_depth=args.transfer_depth,
                           scheduler=args.scheduler,
                           decode_scheduler=sched, spill=args.spill,
                           quota=quota, temperature=args.temperature)
    elif args.role == "prefill":
        runtime = MemoryRuntime(
            model.plan,
            MemoryPlan(policy=args.transfer_tier,
                       placement=model.memory.placement),
            model.mesh, planner=model.planner)
        eng = Engine(model, params, batch=args.batch, max_len=args.max_len,
                     temperature=args.temperature, scheduler=sched,
                     spill=None, page_size=args.page_size, quota=quota,
                     role="prefill",
                     transfer=TransferQueue(runtime,
                                            max_depth=args.transfer_depth))
    else:
        eng = Engine(model, params, batch=args.batch, max_len=args.max_len,
                     temperature=args.temperature, scheduler=sched,
                     spill=args.spill, page_size=args.page_size,
                     pages=args.pages, quota=quota,
                     prefix_share=args.prefix_share,
                     decode_kernel=args.decode_kernel)
    print(eng.describe())
    rng = np.random.default_rng(0)
    shared_head = rng.integers(
        0, cfg.vocab_size,
        size=(max(0, args.shared_prefix),)).astype(np.int32)
    t0 = time.perf_counter()
    first_token_at = {}
    sessions = []
    for i in range(args.requests):
        deadline = (args.deadline_slack + (i + 1) * args.new_tokens
                    if args.deadline_slack is not None else None)
        tail_len = max(1, args.prompt_len - len(shared_head))
        prompt = np.concatenate([
            shared_head,
            rng.integers(0, cfg.vocab_size,
                         size=(tail_len,)).astype(np.int32)])
        sessions.append(eng.submit(Request(
            uid=i,
            prompt=prompt,
            max_new_tokens=args.new_tokens + i * args.stagger,
            priority=i % 3 if args.scheduler == "priority" else 0,
            tenant=f"t{i % max(1, args.tenants)}",
            deadline=deadline),
            on_token=lambda s, t: first_token_at.setdefault(
                s.uid, time.perf_counter())))
    done = eng.run()
    dt = time.perf_counter() - t0
    total_new = sum(len(s.result()) for s in sessions)
    print(f"served {len(done)} requests, {total_new} tokens "
          f"in {dt:.2f}s ({total_new / dt:.1f} tok/s)")
    if first_token_at:
        ttft = [first_token_at[s.uid] - t0 for s in sessions
                if s.uid in first_token_at]
        print(f"ttft: mean {1e3 * sum(ttft) / len(ttft):.1f}ms, "
              f"max {1e3 * max(ttft):.1f}ms")
    for s in sessions[:3]:
        print(f"  req {s.uid}: {s.finish_reason}, "
              f"preempted {s.preemptions}x, {s.result()[:8]}...")
    if args.role in ("both", "prefill"):
        trep = eng.transfer.traffic_report()
        tq = trep["transfer"]
        from repro.core.runtime import fmt_bytes
        pub = trep.get("kv_publish", {"wire_bytes": 0.0, "calls": 0})
        print(f"transfer[{eng.transfer.runtime.tier.describe()}]: "
              f"{tq['shipped_pages']} pages shipped "
              f"({fmt_bytes(pub['wire_bytes'])}), "
              f"{tq['requeued']} requeued, depth {tq['depth']}")
        if args.role == "prefill":
            return
        report = eng.decode.traffic_report()
    else:
        report = eng.traffic_report()
    if report.get("kv_stash"):
        from repro.core.runtime import fmt_bytes
        fetch = report.get("kv_fetch", {"wire_bytes": 0.0, "calls": 0})
        print(f"spill[{report['tier']}]: "
              f"stash {fmt_bytes(report['kv_stash']['wire_bytes'])}"
              f"/{report['kv_stash']['calls']}x, "
              f"fetch {fmt_bytes(fetch['wire_bytes'])}"
              f"/{fetch['calls']}x")
    if report.get("pages"):
        p = report["pages"]
        print(f"pages[{p['num_pages']}x{p['page_size']}]: "
              f"{p['evictions']} evicted, {p['refetches']} refetched, "
              f"{p['readmits_free']} readmitted copy-free, "
              f"{p['adoptions']} adopted")
    if report.get("decode_io", {}).get("in_place"):
        from repro.core.runtime import fmt_bytes
        dio = report["decode_io"]
        frac = (dio["bytes_touched"] / dio["bytes_gather_equiv"]
                if dio["bytes_gather_equiv"] else 0.0)
        print(f"decode_io[in-place]: {dio['steps']} steps read "
              f"{fmt_bytes(dio['bytes_touched'])} of KV "
              f"({frac:.1%} of the {fmt_bytes(dio['bytes_gather_equiv'])} "
              f"a full gather touches), "
              f"{dio['compressed_resident']} pages compressed-resident "
              f"({dio['compressed_adopts']} adoptions)")
    if report.get("prefix", {}).get("enabled"):
        pf = report["prefix"]
        print(f"prefix: {pf['hits']} page hits, {pf['forks']} forks, "
              f"{pf['rows_reused']}/{pf['rows_prompted']} prompt rows "
              f"reused (hit rate {pf['hit_rate']:.1%})")
    if quota is not None:
        print("tenants:", {t: u for t, u in eng.quota_report().items()})
    sched_obj = eng.decode.scheduler if args.role == "both" else eng.scheduler
    if hasattr(sched_obj, "miss_report"):
        print("deadlines:", sched_obj.miss_report())


def _run_decode_worker(model, params, args) -> None:
    """``--role decode --connect HOST:PORT``: the remote decode half."""
    from repro.core.runtime import fmt_bytes
    from repro.serve.transport import (ShmChannel, run_decode_worker,
                                       tcp_connect, tcp_connect_striped)

    host, _, port = args.connect.rpartition(":")
    host = host or "127.0.0.1"
    if args.wire_streams > 1:
        channel = tcp_connect_striped(host, int(port), args.wire_streams,
                                      bufsize=args.wire_bufsize)
    else:
        channel = tcp_connect(host, int(port), bufsize=args.wire_bufsize)
        if args.transport == "shm":
            channel = ShmChannel(channel)
    print(f"decode worker: connected to {args.connect} "
          f"({args.wire_streams} stream(s)"
          f"{', shm' if args.transport == 'shm' else ''})", flush=True)
    eng = run_decode_worker(model, params, channel, batch=args.batch,
                            max_len=args.max_len, page_size=args.page_size,
                            pages=args.pages, scheduler=args.scheduler,
                            spill=args.spill,
                            temperature=args.temperature)
    rep = eng.transfer.traffic_report()
    tq = rep["transfer"]
    wire = rep.get("kv_wire", {"wire_bytes": 0.0, "calls": 0})
    print(f"decode worker done: adopted {tq['adopted_pages']} pages "
          f"({tq['published']} handoffs), sent "
          f"{fmt_bytes(wire['wire_bytes'])} of result/ack frames")


def _run_router(model, params, cfg, quota, args) -> None:
    """``--router``: the cluster front-end over N engine pairs."""
    from repro.serve.quota import QuotaManager
    from repro.serve.router import FederatedRouter, Router, replay_trace
    from repro.serve.transport import (ShmChannel, build_wire_pair,
                                       build_wire_prefill, tcp_accept,
                                       tcp_accept_striped, tcp_connect,
                                       tcp_listen)

    shared = quota if isinstance(quota, QuotaManager) else \
        (QuotaManager(dict(quota)) if quota else None)
    pair_kw = dict(batch=args.batch, max_len=args.max_len,
                   page_size=args.page_size, pages=args.pages,
                   scheduler=args.scheduler, spill=args.spill,
                   quota=shared, temperature=args.temperature)
    pairs = []
    for i in range(args.engines):
        if i == 0 and args.listen is not None:
            listener, port = tcp_listen(port=args.listen,
                                        backlog=args.wire_streams)
            # port stays the last token: the two-process smokes (CI and
            # tests/test_router.py) scrape it off this line
            print(f"router: engine 0 [{args.wire_streams} stream(s)] "
                  f"listening on {port}", flush=True)
            if args.wire_streams > 1:
                channel = tcp_accept_striped(listener, args.wire_streams,
                                             bufsize=args.wire_bufsize)
            else:
                channel = tcp_accept(listener, bufsize=args.wire_bufsize)
                if args.transport == "shm":
                    channel = ShmChannel(channel)
            print("router: decode worker attached", flush=True)
            pairs.append(build_wire_prefill(
                model, params, channel, max_len=args.max_len,
                page_size=args.page_size, scheduler=args.scheduler,
                quota=shared, window_hint=2 * (args.batch or 4),
                temperature=args.temperature, seed=0))
        elif i == 0 and args.transport is not None:
            pairs.append(build_wire_pair(model, params,
                                         transport=args.transport,
                                         streams=args.wire_streams,
                                         seed=0, **pair_kw))
        else:
            pairs.append(build_disagg(model, params,
                                      transfer=args.transfer_tier,
                                      max_depth=args.transfer_depth,
                                      seed=2 * i, **pair_kw))
    router = Router(pairs, placement=args.placement)
    fed = None
    if args.peer is not None or args.fed_listen is not None:
        fed = FederatedRouter(router)
        if args.fed_listen is not None:
            fed_listener, fed_port = tcp_listen(port=args.fed_listen)
            print(f"federation: listening on {fed_port}", flush=True)
            fed.add_peer("peer", tcp_accept(fed_listener,
                                            bufsize=args.wire_bufsize))
        else:
            host, _, port = args.peer.rpartition(":")
            fed.add_peer("peer", tcp_connect(host or "127.0.0.1", int(port),
                                             bufsize=args.wire_bufsize))
        print(f"federation: peered ({fed.describe()})", flush=True)
    print(router.describe())

    t0 = time.perf_counter()
    first_tok_s = {}

    def on_token(sess, tok):
        first_tok_s.setdefault(sess.uid, time.perf_counter() - t0)

    driver = fed if fed is not None else router
    if args.trace:
        from repro.sim.workloads import TrafficSpec, generate_traffic
        trace = generate_traffic(TrafficSpec(sessions=args.trace,
                                             horizon_s=3600.0))
        done = replay_trace(router, trace, cfg.vocab_size,
                            arrivals_per_step=2.0,
                            on_step=_drain_hook(args, router))
    else:
        rng = np.random.default_rng(0)
        sessions = []
        for i in range(args.requests):
            sessions.append(driver.submit(Request(
                uid=i,
                prompt=rng.integers(0, cfg.vocab_size,
                                    size=(args.prompt_len,)
                                    ).astype(np.int32),
                max_new_tokens=args.new_tokens + i * args.stagger,
                tenant=f"t{i % max(1, args.tenants)}"), on_token=on_token))
        done = driver.run(on_step=_drain_hook(args, router))
    dt = time.perf_counter() - t0

    total_new = sum(len(r.out_tokens) for r in done)
    dropped = sum(1 for s in router.sessions.values() if not s.done)
    print(f"router served {len(done)}/{len(router.sessions)} sessions, "
          f"{total_new} tokens in {dt:.2f}s ({total_new / dt:.1f} tok/s), "
          f"{dropped} dropped, {router.requeues} requeued")
    by_engine = {}
    for _, idx in router.placement_log:
        by_engine[idx] = by_engine.get(idx, 0) + 1
    print(f"placement[{args.placement}]: {by_engine}; "
          f"ttft(steps): {router.ttft_report()}")
    if first_tok_s:
        vals = sorted(first_tok_s.values())
        print(f"ttft(wall): mean {1e3 * sum(vals) / len(vals):.1f}ms, "
              f"max {1e3 * vals[-1]:.1f}ms")
    if any(s.request.deadline is not None
           for s in router.sessions.values()):
        print("slo:", router.slo_report())
    if fed is not None:
        print(fed.describe())
        fed.close()
    for eng in router.engines:
        print(" ", eng.describe())
        if hasattr(eng.pair, "close"):      # wire prefill: BYE the worker
            eng.pair.close()
    if shared is not None:
        print("tenants:", dict(shared.usage()))
    assert dropped == 0, f"{dropped} sessions dropped"


def _drain_hook(args, router):
    state = {"done": False}

    def hook(_driver) -> None:
        if (args.drain_after is not None and not state["done"]
                and router.now >= args.drain_after):
            state["done"] = True
            router.drain(args.drain_engine)
            print(f"drained engine {args.drain_engine} "
                  f"at step {router.now}", flush=True)

    return hook


if __name__ == "__main__":
    main()
