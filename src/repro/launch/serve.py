"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the tier-aware serving stack (serve/engine.py facade over Scheduler /
KVCacheManager / Session) over pooled KV caches.  On the CPU container use
``--smoke`` for the reduced twin; on TPU the full config serves against
the production mesh with the cache striped across the pool.

``--batch`` / ``--max-len`` may be omitted: the cache manager then sizes
the decode slots from the serving tier's ``cache_tier_report``.  Cold KV
(preempted sessions under ``--scheduler fair/priority/srpt/deadline``)
goes to the ``--spill`` tier; with ``--page-size`` the cache is *paged* —
cold pages spill lazily, per page, through the per-tenant ``--page-codec``
— and ``--pages`` overcommits the pool below batch x pages_per_slot.
``--tenant-quota`` caps what each tenant may hold (see
serve/quota.parse_quota_spec for the grammar); ``--tenants N`` spreads the
synthetic requests over N tenant names.  The run prints the spill/page
traffic report, per-tenant usage and (for ``--scheduler deadline``, with
``--deadline-slack`` steps of slack) the deadline-miss accounting.

``--role`` disaggregates prefill from decode (serve/disagg.py):
``both`` runs the two-engine loopback in this process — prompts prefill
on a prefill-role engine, KV pages ship through the ``--transfer-tier``
(metered, printed as the transfer report with time-to-first-token), and
a decode-role engine adopts them; ``prefill`` runs the prefill worker
alone (publishes into a local queue and reports what shipped — useful to
price the transfer path); ``decode`` needs a peer feeding the queue, so
standalone it is rejected with a pointer at ``--role both``.  Omit
``--role`` for the classic colocated engine.
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import numpy as np

from repro.configs import MemoryPlan, RunConfig, TrainConfig, get_arch
from repro.configs.base import MeshPlan, ShapeConfig
from repro.core.runtime import MemoryRuntime
from repro.launch.mesh import make_host_mesh, make_production_mesh, plan_for
from repro.models.model import build_model
from repro.serve.disagg import TransferQueue, build_disagg
from repro.serve.engine import Engine, Request
from repro.serve.quota import quota_from_cli
from repro.serve.scheduler import build_scheduler, registered_schedulers


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=None,
                    help="decode slots (default: auto from the tier report)")
    ap.add_argument("--max-len", type=int, default=None,
                    help="cache rows per slot (default: auto)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--stagger", type=int, default=0,
                    help="request i decodes new-tokens + i*stagger tokens "
                         "(unequal service times: lets srpt/deadline sort)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--scheduler", default="fcfs",
                    choices=registered_schedulers())
    ap.add_argument("--quantum", type=int, default=8,
                    help="fair-scheduler decode quantum")
    ap.add_argument("--spill", default="spill",
                    help="secondary tier policy for cold KV")
    ap.add_argument("--page-size", type=int, default=None,
                    help="page the KV cache (rows per page; default: "
                         "monolithic slots)")
    ap.add_argument("--pages", type=int, default=None,
                    help="page-pool size (default batch*max_len/page_size; "
                         "smaller overcommits)")
    ap.add_argument("--page-codec", default=None,
                    help="default spill codec for cold pages (fp8/int8/...)")
    ap.add_argument("--tenant-quota", default=None,
                    help="per-tenant caps, e.g. 'pages=16,sessions=2' or "
                         "'a:pages=8;b:sessions=1,codec=int8'")
    ap.add_argument("--tenants", type=int, default=1,
                    help="spread requests over N tenant names t0..tN-1")
    ap.add_argument("--deadline-slack", type=int, default=None,
                    help="per-request deadline = slack + (i+1)*new-tokens "
                         "engine steps (with --scheduler deadline)")
    ap.add_argument("--role", default=None,
                    choices=("prefill", "decode", "both"),
                    help="disaggregate prefill/decode (both: in-process "
                         "two-engine loopback; default: colocated engine)")
    ap.add_argument("--transfer-tier", default="spill",
                    help="tier policy carrying KV handoffs between roles "
                         "(spill: pooled HBM->host; host: PCIe DRAM)")
    ap.add_argument("--transfer-depth", type=int, default=None,
                    help="max handoffs parked in the transfer queue "
                         "(prefill admission stalls past it)")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    if args.role == "decode":
        ap.error("--role decode needs a peer feeding the transfer queue; "
                 "use --role both for the in-process loopback")
    if args.role is not None and not args.page_size:
        ap.error("--role ships page-shaped KV: pass --page-size")
    logging.basicConfig(level=logging.INFO)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
        mesh = make_host_mesh()
        n = len(jax.devices())
        plan = MeshPlan((2, n // 2), ("data", "model")) if mesh is not None \
            else MeshPlan((1,), ("data",))
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        plan = plan_for(multi_pod=args.multi_pod)

    shape = ShapeConfig("serve", args.max_len or 128, args.batch or 4,
                        "decode")
    run = RunConfig(model=cfg, shape=shape, mesh=plan,
                    memory=MemoryPlan(policy="none"), train=TrainConfig())
    model = build_model(run, mesh=mesh)
    params = model.init(jax.random.PRNGKey(0))

    quota = quota_from_cli(args.tenant_quota, args.page_codec)

    sched = (build_scheduler("fair", quantum=args.quantum)
             if args.scheduler == "fair" else build_scheduler(args.scheduler))
    if args.role == "both":
        eng = build_disagg(model, params, batch=args.batch,
                           max_len=args.max_len, page_size=args.page_size,
                           pages=args.pages, transfer=args.transfer_tier,
                           max_depth=args.transfer_depth,
                           scheduler=args.scheduler,
                           decode_scheduler=sched, spill=args.spill,
                           quota=quota, temperature=args.temperature)
    elif args.role == "prefill":
        runtime = MemoryRuntime(
            model.plan,
            MemoryPlan(policy=args.transfer_tier,
                       placement=model.memory.placement),
            model.mesh, planner=model.planner)
        eng = Engine(model, params, batch=args.batch, max_len=args.max_len,
                     temperature=args.temperature, scheduler=sched,
                     spill=None, page_size=args.page_size, quota=quota,
                     role="prefill",
                     transfer=TransferQueue(runtime,
                                            max_depth=args.transfer_depth))
    else:
        eng = Engine(model, params, batch=args.batch, max_len=args.max_len,
                     temperature=args.temperature, scheduler=sched,
                     spill=args.spill, page_size=args.page_size,
                     pages=args.pages, quota=quota)
    print(eng.describe())
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    first_token_at = {}
    sessions = []
    for i in range(args.requests):
        deadline = (args.deadline_slack + (i + 1) * args.new_tokens
                    if args.deadline_slack is not None else None)
        sessions.append(eng.submit(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                size=(args.prompt_len,)).astype(np.int32),
            max_new_tokens=args.new_tokens + i * args.stagger,
            priority=i % 3 if args.scheduler == "priority" else 0,
            tenant=f"t{i % max(1, args.tenants)}",
            deadline=deadline),
            on_token=lambda s, t: first_token_at.setdefault(
                s.uid, time.perf_counter())))
    done = eng.run()
    dt = time.perf_counter() - t0
    total_new = sum(len(s.result()) for s in sessions)
    print(f"served {len(done)} requests, {total_new} tokens "
          f"in {dt:.2f}s ({total_new / dt:.1f} tok/s)")
    if first_token_at:
        ttft = [first_token_at[s.uid] - t0 for s in sessions
                if s.uid in first_token_at]
        print(f"ttft: mean {1e3 * sum(ttft) / len(ttft):.1f}ms, "
              f"max {1e3 * max(ttft):.1f}ms")
    for s in sessions[:3]:
        print(f"  req {s.uid}: {s.finish_reason}, "
              f"preempted {s.preemptions}x, {s.result()[:8]}...")
    if args.role in ("both", "prefill"):
        trep = eng.transfer.traffic_report()
        tq = trep["transfer"]
        from repro.core.runtime import fmt_bytes
        pub = trep.get("kv_publish", {"wire_bytes": 0.0, "calls": 0})
        print(f"transfer[{eng.transfer.runtime.tier.describe()}]: "
              f"{tq['shipped_pages']} pages shipped "
              f"({fmt_bytes(pub['wire_bytes'])}), "
              f"{tq['requeued']} requeued, depth {tq['depth']}")
        if args.role == "prefill":
            return
        report = eng.decode.traffic_report()
    else:
        report = eng.traffic_report()
    if report.get("kv_stash"):
        from repro.core.runtime import fmt_bytes
        fetch = report.get("kv_fetch", {"wire_bytes": 0.0, "calls": 0})
        print(f"spill[{report['tier']}]: "
              f"stash {fmt_bytes(report['kv_stash']['wire_bytes'])}"
              f"/{report['kv_stash']['calls']}x, "
              f"fetch {fmt_bytes(fetch['wire_bytes'])}"
              f"/{fetch['calls']}x")
    if report.get("pages"):
        p = report["pages"]
        print(f"pages[{p['num_pages']}x{p['page_size']}]: "
              f"{p['evictions']} evicted, {p['refetches']} refetched, "
              f"{p['readmits_free']} readmitted copy-free, "
              f"{p['adoptions']} adopted")
    if quota is not None:
        print("tenants:", {t: u for t, u in eng.quota_report().items()})
    sched_obj = eng.decode.scheduler if args.role == "both" else eng.scheduler
    if hasattr(sched_obj, "miss_report"):
        print("deadlines:", sched_obj.miss_report())


if __name__ == "__main__":
    main()
