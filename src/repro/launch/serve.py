"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the batched engine (serve/engine.py) over pooled KV caches.  On the
CPU container use ``--smoke`` for the reduced twin; on TPU the full config
serves against the production mesh with the cache striped across the pool.
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import numpy as np

from repro.configs import MemoryPlan, RunConfig, TrainConfig, get_arch
from repro.configs.base import MeshPlan, ShapeConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh, plan_for
from repro.models.model import build_model
from repro.serve.engine import Engine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
        mesh = make_host_mesh()
        n = len(jax.devices())
        plan = MeshPlan((2, n // 2), ("data", "model")) if mesh is not None \
            else MeshPlan((1,), ("data",))
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        plan = plan_for(multi_pod=args.multi_pod)

    shape = ShapeConfig("serve", args.max_len, args.batch, "decode")
    run = RunConfig(model=cfg, shape=shape, mesh=plan,
                    memory=MemoryPlan(policy="none"), train=TrainConfig())
    model = build_model(run, mesh=mesh)
    params = model.init(jax.random.PRNGKey(0))

    eng = Engine(model, params, batch=args.batch, max_len=args.max_len,
                 temperature=args.temperature)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(uid=i,
                           prompt=rng.integers(
                               0, cfg.vocab_size,
                               size=(args.prompt_len,)).astype(np.int32),
                           max_new_tokens=args.new_tokens))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    total_new = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {total_new} tokens "
          f"in {dt:.2f}s ({total_new / dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  req {r.uid}: {r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
