"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

Per (arch x shape x mesh) cell:

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

All three inputs are per-device numbers from launch/dryrun.py (cost_analysis
+ the parsed collective schedule, loop-corrected by the unrolled probes), so
the division by `chips` is already folded in.  The dominant term is the
bottleneck; the roofline fraction scores how close the cell is to the
machine:

  ideal_s    = MODEL_FLOPS / (chips x peak)     (6*N*D useful compute)
  bound_s    = max(compute, memory, collective)
  fraction   = ideal_s / bound_s

Known measurement bias (recorded per EXPERIMENTS.md §Dry-run): the CPU
backend legalizes bf16 dots to f32, so HLO_bytes over-counts what a TPU
would move by up to ~2x on matmul traffic — memory terms are upper bounds.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

from repro import hw
from repro.configs import SHAPES_BY_NAME, get_arch
from repro.core.dag import model_flops

CHIP = hw.TPU_V5E


def analyze_cell(r: Dict) -> Optional[Dict]:
    if not r.get("ok"):
        return None
    cfg = get_arch(r["arch"])
    shape = SHAPES_BY_NAME[r["shape"]]
    chips = 512 if r["mesh"] == "2x16x16" else 256

    compute_s = (r["flops_per_dev"] or 0.0) / CHIP.peak_flops
    memory_s = (r["bytes_accessed_per_dev"] or 0.0) / CHIP.hbm_bw
    coll_s = (r["collective_wire_bytes_per_dev"] or 0.0) / CHIP.link_bw

    mf = model_flops(cfg, shape)
    ideal_s = mf / (chips * CHIP.peak_flops)
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    hlo_flops_global = (r["flops_per_dev"] or 0.0) * chips
    return {
        **{k: r.get(k) for k in ("arch", "shape", "mesh", "policy",
                                 "placement", "compress", "opt_bits",
                                 "accum")},
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "model_flops": mf,
        "ideal_s": ideal_s,
        "useful_ratio": (mf / hlo_flops_global) if hlo_flops_global else 0.0,
        "roofline_fraction": (ideal_s / bound) if bound else 0.0,
        "fits_hbm": ((r.get("arg_bytes_per_dev") or 0)
                     + (r.get("temp_bytes_per_dev") or 0)) <= CHIP.hbm_bytes,
        "arg_gb": (r.get("arg_bytes_per_dev") or 0) / 1e9,
        "temp_gb": (r.get("temp_bytes_per_dev") or 0) / 1e9,
        "advice": _advice(dominant, r, shape),
    }


def _advice(dominant: str, r: Dict, shape) -> str:
    if dominant == "collective":
        return ("shrink wire bytes: local placement / fp8 stash compression "
                "/ fewer FSDP regathers (larger per-layer weight shards)")
    if dominant == "memory":
        return ("raise arithmetic intensity: larger per-device batch via "
                "lower grad-accum, fuse norms/rope (Pallas), keep bf16 "
                "end-to-end (CPU f32-dot bias inflates this term)")
    return ("compute-bound: reduce recompute (policy=auto keeps layers "
            "resident when HBM allows), cast scores bf16, bigger MXU tiles")


def analyze_file(path: str) -> List[Dict]:
    with open(path) as f:
        rows = json.load(f)
    out = []
    for r in rows:
        a = analyze_cell(r)
        if a is not None:
            out.append(a)
        elif r.get("skip"):
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "mesh": r["mesh"], "skip": r["skip"]})
        else:
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "mesh": r.get("mesh"), "error": r.get("error")})
    return out


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| 6ND/HLO | roofline frac | fits | note |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for a in rows:
        if "skip" in a:
            lines.append(f"| {a['arch']} | {a['shape']} | — | — | — | — | — "
                         f"| — | — | SKIP: {a['skip'][:40]} |")
            continue
        if "error" in a:
            lines.append(f"| {a['arch']} | {a['shape']} | — | — | — | — | — "
                         f"| — | — | ERROR: {str(a['error'])[:40]} |")
            continue
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['compute_s']:.3f} "
            f"| {a['memory_s']:.3f} | {a['collective_s']:.3f} "
            f"| **{a['dominant']}** | {a['useful_ratio']:.2f} "
            f"| {a['roofline_fraction']:.2%} "
            f"| {'y' if a['fits_hbm'] else 'NO'} "
            f"| {a['advice'][:48]} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("reports", nargs="+")
    ap.add_argument("--md", default="")
    args = ap.parse_args()
    rows = []
    for path in args.reports:
        rows.extend(analyze_file(path))
    md = to_markdown(rows)
    print(md)
    if args.md:
        with open(args.md, "w") as f:
            f.write(md + "\n")


if __name__ == "__main__":
    main()
