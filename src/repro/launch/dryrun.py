import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  This module is the multi-pod dry-run: for every
# (architecture x input shape) cell it lowers + compiles the real train /
# prefill / decode step against the production mesh with ShapeDtypeStruct
# inputs (no allocation), then extracts
#   * memory_analysis()  — bytes/device: proves the cell fits (or doesn't),
#   * cost_analysis()    — per-device HLO FLOPs / bytes for §Roofline,
#   * the collective schedule parsed from the partitioned HLO text —
#     per-type wire bytes for the §Roofline collective term.

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (ARCHS, MemoryPlan, RunConfig, SHAPES_BY_NAME,  # noqa: E402
                           TrainConfig, get_arch)
from repro.configs.registry import cells_for  # noqa: E402
from repro.launch.mesh import make_production_mesh, plan_for  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.train.loop import make_train_step  # noqa: E402
from repro.train.train_state import abstract_state, state_shardings  # noqa: E402

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f8e4m3fn": 1, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "s16": 2, "u16": 2, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(tok_dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(tok_dtype, 4)


def parse_collectives(hlo: str) -> Dict[str, float]:
    """Per-device wire bytes by collective type (ring-schedule estimate)."""
    out: Dict[str, float] = {}
    seen_done = set()
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        if "-done(" in line:
            continue                      # paired with -start; count once
        result, kind = m.group(1), m.group(2)
        shapes = _SHAPE_RE.findall(result)
        size = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        g = _GROUPS_RE.search(line)
        n = int(g.group(2)) if g else 2
        if kind == "all-gather":
            wire = (n - 1) / n * size          # result = gathered
        elif kind == "all-reduce":
            wire = 2 * (n - 1) / n * size
        elif kind == "reduce-scatter":
            wire = (n - 1) * size              # result = scattered shard
        elif kind == "all-to-all":
            wire = (n - 1) / n * size
        else:                                  # collective-permute
            wire = size
        out[kind] = out.get(kind, 0.0) + wire
    return out


# ---------------------------------------------------------------------------
def _group_unit(cfg) -> int:
    if cfg.is_hybrid:
        return cfg.hybrid_attn_every
    if cfg.is_moe and cfg.moe_every > 1:
        return cfg.moe_every
    return 1


def probe_scaled(arch: str, shape_name: str, *, multi_pod: bool,
                 policy: str, placement: str, compress: str, opt_bits: int,
                 seq_parallel: bool, mesh, n_groups_full: int,
                 stash_aux: bool = True) -> Dict:
    """Loop-aware cost measurement.

    XLA's cost_analysis counts while-loop bodies ONCE (not x trip count),
    so FLOPs / bytes / collective bytes of the scanned layer stack are
    under-reported.  We lower the SAME step with the stack fully unrolled
    at depths k=2 and k=4 groups and extrapolate the exact linear model
    f(L) = C + L*B (every per-layer cost is linear in depth).  All numbers
    still come from compiled artifacts.
    """
    import dataclasses as _dc

    from repro.models import attention as attn_mod
    from repro.models import transformer as tfm

    cfg = get_arch(arch)
    unit = _group_unit(cfg)
    vals = {}
    tfm.SCAN_UNROLL = True
    attn_mod.UNROLL_INNER = True
    shape = SHAPES_BY_NAME[shape_name]
    # bound the unrolled online-softmax body count for long sequences
    big = max(1024, shape.seq_len // 8)
    attn_mod.Q_CHUNK, attn_mod.KV_CHUNK = big, big
    try:
        for k in (1, 2):
            over = {"num_layers": k * unit}
            if cfg.is_encoder_decoder:
                over["encoder_layers"] = k
            cfg_k = _dc.replace(cfg, **over)
            r = _lower_one(cfg_k, shape_name, multi_pod=multi_pod,
                           policy=policy, placement=placement,
                           compress=compress, opt_bits=opt_bits,
                           accum=1, seq_parallel=seq_parallel,
                           stash_aux=stash_aux, mesh=mesh)
            vals[k] = r
    finally:
        tfm.SCAN_UNROLL = False
        attn_mod.UNROLL_INNER = False
        attn_mod.Q_CHUNK = attn_mod.KV_CHUNK = 1024

    def fit(key):
        f1 = vals[1].get(key) or 0.0
        f2 = vals[2].get(key) or 0.0
        b = f2 - f1
        c = f1 - b
        return max(0.0, c + n_groups_full * b)

    coll1 = vals[1]["collectives"]
    coll2 = vals[2]["collectives"]
    coll = {}
    for kind in set(coll1) | set(coll2):
        b = coll2.get(kind, 0.0) - coll1.get(kind, 0.0)
        coll[kind] = max(0.0, coll1.get(kind, 0.0) - b + n_groups_full * b)
    return {
        "flops_per_dev": fit("flops_per_dev"),
        "bytes_accessed_per_dev": fit("bytes_accessed_per_dev"),
        "collectives": coll,
        "collective_wire_bytes_per_dev": sum(coll.values()),
    }


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               policy: str = "mcdla", placement: str = "bw_aware",
               compress: str = "none", opt_bits: int = 32,
               accum: int = 1, seq_parallel: bool = True,
               stash_aux: bool = True,
               probes: bool = True, mesh=None) -> Dict:
    """Lower + compile one cell (+ the loop-aware cost probes)."""
    cfg = get_arch(arch)
    mesh = mesh if mesh is not None else make_production_mesh(
        multi_pod=multi_pod)
    res = _lower_one(cfg, shape_name, multi_pod=multi_pod, policy=policy,
                     placement=placement, compress=compress,
                     opt_bits=opt_bits, accum=accum,
                     seq_parallel=seq_parallel, stash_aux=stash_aux,
                     mesh=mesh)
    res.update({"arch": arch, "raw_flops_per_dev": res["flops_per_dev"],
                "raw_collective_wire_bytes_per_dev":
                    res["collective_wire_bytes_per_dev"]})
    if probes:
        from repro.models.transformer import arch_group
        _, n_groups = arch_group(cfg)
        p = probe_scaled(arch, shape_name, multi_pod=multi_pod,
                         policy=policy, placement=placement,
                         compress=compress, opt_bits=opt_bits,
                         seq_parallel=seq_parallel, stash_aux=stash_aux,
                         mesh=mesh, n_groups_full=n_groups)
        # probes run accum=1 over the full batch: per-step FLOPs/bytes are
        # identical for any accum (microbatches partition the same tokens);
        # only the per-microbatch weight regathers are undercounted for
        # accum>1 (noted in EXPERIMENTS.md).
        res["flops_per_dev"] = p["flops_per_dev"]
        res["bytes_accessed_per_dev"] = p["bytes_accessed_per_dev"]
        res["collectives"] = dict(p["collectives"])
        res["collective_wire_bytes_per_dev"] = \
            p["collective_wire_bytes_per_dev"]
    return res


def _lower_one(cfg, shape_name: str, *, multi_pod: bool, policy: str,
               placement: str, compress: str, opt_bits: int, accum: int,
               seq_parallel: bool, mesh, stash_aux: bool = True) -> Dict:
    shape = SHAPES_BY_NAME[shape_name]
    plan = plan_for(multi_pod=multi_pod)
    memory = MemoryPlan(policy=policy, placement=placement,
                        compress=compress, opt_state_bits=opt_bits,
                        seq_parallel=seq_parallel, stash_aux=stash_aux)
    tc = TrainConfig(grad_accum=accum)
    run = RunConfig(model=cfg, shape=shape, mesh=plan, memory=memory,
                    train=tc)
    model = build_model(run, mesh=mesh)
    model.runtime.reset_traffic()
    t0 = time.time()

    batch_sds = model.input_specs(shape)
    batch_sh = {k: NamedSharding(mesh, s)
                for k, s in model.batch_specs(shape).items()}

    with mesh:
        if shape.mode == "train":
            step = make_train_step(model, tc)
            state_sds = abstract_state(model, tc)
            state_sh = state_shardings(model, tc)
            lowered = jax.jit(
                step, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=0).lower(state_sds, batch_sds)
        elif shape.mode == "prefill":
            params_sds = model.abstract_params()
            params_sh = model.param_shardings()
            cache_sds = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            cache_sh = model.cache_shardings(shape.global_batch,
                                             shape.seq_len)
            lowered = jax.jit(
                model.prefill,
                in_shardings=(params_sh, batch_sh, cache_sh),
                out_shardings=(None, cache_sh),
                donate_argnums=2).lower(params_sds, batch_sds, cache_sds)
        else:   # decode
            params_sds = model.abstract_params()
            params_sh = model.param_shardings()
            cache_sds = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            cache_sh = model.cache_shardings(shape.global_batch,
                                             shape.seq_len)
            lowered = jax.jit(
                model.decode_step,
                in_shardings=(params_sh, batch_sh["token"],
                              batch_sh["positions"], cache_sh,
                              batch_sh["index"]),
                out_shardings=(None, cache_sh),
                donate_argnums=3,
            ).lower(params_sds, batch_sds["token"], batch_sds["positions"],
                    cache_sds, batch_sds["index"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):          # older jax returns [dict]
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    # per-tier stash/fetch traffic metered while tracing the step (counts
    # are per traced layer group; scan bodies trace once — see
    # MemoryRuntime.traffic_report)
    traffic = model.runtime.traffic_report()
    res = {
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "policy": policy, "placement": placement, "compress": compress,
        "opt_bits": opt_bits, "accum": accum, "ok": True,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "arg_bytes_per_dev": getattr(ma, "argument_size_in_bytes", None),
        "temp_bytes_per_dev": getattr(ma, "temp_size_in_bytes", None),
        "out_bytes_per_dev": getattr(ma, "output_size_in_bytes", None),
        "flops_per_dev": ca.get("flops"),
        "bytes_accessed_per_dev": ca.get("bytes accessed"),
        "collectives": colls,
        "collective_wire_bytes_per_dev": sum(colls.values()),
        "tier": traffic["tier"],
        "traffic": traffic,
    }
    return res


# ---------------------------------------------------------------------------
def pipeline_cell(arch: str, shape_name: str, *, multi_pod: bool,
                  policy: str, placement: str, compress: str, opt_bits: int,
                  pipeline) -> Dict:
    """Analytic stage-tier report for one cell: the planner's joint
    n_micro x KEEP/POOL/RECOMPUTE verdict plus the per-stage act traffic
    the 1F1B schedule would push through the pipeline stage tier.  (The
    pipelined step itself is a shard_map over a dedicated stage mesh —
    the dry-run surfaces the tier contract, not a second compile.)"""
    from repro.core.dag import build_dag
    from repro.core.policy import micro_candidates, plan_memory
    from repro.core.tiers import build_stage_tier
    from repro.parallel.sharding import ShardingPlanner

    cfg = get_arch(arch)
    shape = SHAPES_BY_NAME[shape_name]
    plan = plan_for(multi_pod=multi_pod)
    memory = MemoryPlan(policy=policy, placement=placement,
                        compress=compress, opt_state_bits=opt_bits)
    planner = ShardingPlanner(plan)
    tier = build_stage_tier(memory, planner, None,
                            n_stages=pipeline.n_stages)
    report = plan_memory(
        build_dag(cfg, shape), plan, memory, tier=tier, pipeline=pipeline,
        n_micro_candidates=micro_candidates(shape.global_batch,
                                            pipeline.n_stages))
    pd = report.pipeline
    return {
        "schedule": pd.schedule, "n_stages": pd.n_stages,
        "n_micro": pd.n_micro, "bubble_s": pd.bubble_s,
        "stall_s": pd.stall_s, "act_wire_bytes": pd.act_wire_bytes,
        "act_wire_bytes_per_stage":
            pd.act_wire_bytes / max(pd.n_stages, 1),
        "tier": tier.describe(),
    }


# ---------------------------------------------------------------------------
def checkpoint_cell(arch: str, shape_name: str, *, multi_pod: bool,
                    policy: str, placement: str, compress: str,
                    opt_bits: int, ckpt) -> Dict:
    """Analytic checkpoint-traffic report for one cell: the Young-Daly
    cadence verdict through the configured CheckpointTier stack plus the
    per-snapshot wire bytes the pooled backing store absorbs.  (Pure tier
    arithmetic — no compile; the in-process metered path is
    train/checkpoint.py.)"""
    from repro.core.dag import build_dag
    from repro.core.policy import plan_memory

    cfg = get_arch(arch)
    shape = SHAPES_BY_NAME[shape_name]
    plan = plan_for(multi_pod=multi_pod)
    memory = MemoryPlan(policy=policy, placement=placement,
                        compress=compress, opt_state_bits=opt_bits)
    opt_bytes = 4 + 2 * opt_bits // 8
    report = plan_memory(build_dag(cfg, shape), plan, memory,
                         model_state_bytes=cfg.param_count() * opt_bytes,
                         checkpoint=ckpt)
    d = report.checkpoint
    return {
        "tier": d.tier, "every": d.every,
        "snapshot_bytes": d.snapshot_bytes,
        "save_s": d.save_s,
        "overhead_s_per_step": d.overhead_s,
        "lost_s_per_step": d.lost_s,
        "async": d.async_saves,
        "ckpt_wire_bytes_per_step": d.snapshot_bytes / max(d.every, 1),
    }


# ---------------------------------------------------------------------------
def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--policy", default="mcdla",
                    choices=["none", "host", "mcdla", "auto"])
    ap.add_argument("--placement", default="bw_aware",
                    choices=["bw_aware", "local"])
    ap.add_argument("--compress", default="none", choices=["none", "fp8"])
    ap.add_argument("--opt-bits", type=int, default=32, choices=[32, 8])
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--pipeline", action="store_true",
                    help="attach the analytic pipeline stage-tier report "
                         "(bubble-vs-stall verdict + per-stage traffic)")
    ap.add_argument("--pipeline-schedule", default="1f1b")
    ap.add_argument("--pipeline-stages", type=int, default=2)
    ap.add_argument("--n-micro", type=int, default=0)
    ap.add_argument("--ckpt", action="store_true",
                    help="attach the analytic checkpoint-traffic report "
                         "(Young-Daly cadence + pooled snapshot bytes)")
    ap.add_argument("--ckpt-tier", default="host")
    ap.add_argument("--ckpt-codec", default="none")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-async", action="store_true")
    ap.add_argument("--mtbf-steps", type=int, default=10_000)
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--no-probes", action="store_true",
                    help="skip the loop-aware cost probes (faster)")
    ap.add_argument("--out", default="")
    ap.add_argument("--include-skipped", action="store_true")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    results = []
    for arch in archs:
        for cfg, shape, status in cells_for(get_arch(arch)):
            if args.shape != "all" and shape.name != args.shape:
                continue
            if status != "run" and not args.include_skipped:
                results.append({"arch": arch, "shape": shape.name,
                                "mesh": "2x16x16" if args.multi_pod
                                else "16x16", "ok": None, "skip": status})
                print(f"[skip] {arch} x {shape.name}: {status}")
                continue
            tag = f"{arch} x {shape.name} x " \
                  f"{'2x16x16' if args.multi_pod else '16x16'}"
            try:
                r = lower_cell(arch, shape.name, multi_pod=args.multi_pod,
                               policy=args.policy, placement=args.placement,
                               compress=args.compress, accum=args.accum,
                               seq_parallel=not args.no_seq_parallel,
                               probes=not args.no_probes,
                               opt_bits=args.opt_bits, mesh=mesh)
                if args.pipeline and shape.mode == "train":
                    from repro.configs.base import PipelinePlan
                    r["pipeline"] = pipeline_cell(
                        arch, shape.name, multi_pod=args.multi_pod,
                        policy=args.policy, placement=args.placement,
                        compress=args.compress, opt_bits=args.opt_bits,
                        pipeline=PipelinePlan(
                            enabled=True, schedule=args.pipeline_schedule,
                            n_micro=args.n_micro,
                            n_stages=args.pipeline_stages))
                if args.ckpt and shape.mode == "train":
                    from repro.configs.base import CheckpointPlan
                    r["checkpoint"] = checkpoint_cell(
                        arch, shape.name, multi_pod=args.multi_pod,
                        policy=args.policy, placement=args.placement,
                        compress=args.compress, opt_bits=args.opt_bits,
                        ckpt=CheckpointPlan(
                            enabled=True, tier=args.ckpt_tier,
                            codec=args.ckpt_codec, every=args.ckpt_every,
                            async_saves=args.ckpt_async,
                            mtbf_steps=args.mtbf_steps))
                results.append(r)
                tr = r.get("traffic", {})
                print(f"[ok]   {tag}: compile={r['compile_s']}s "
                      f"args={r['arg_bytes_per_dev']/1e9:.2f}GB "
                      f"temp={r['temp_bytes_per_dev']/1e9:.2f}GB "
                      f"flops/dev={r['flops_per_dev']:.3e} "
                      f"coll/dev={r['collective_wire_bytes_per_dev']/1e9:.3f}GB "
                      f"tier[{tr.get('tier', '?')}]="
                      f"{tr.get('wire_bytes_total', 0.0)/1e9:.3f}GB/group")
                if "pipeline" in r:
                    p = r["pipeline"]
                    print(f"       pipeline[{p['schedule']} "
                          f"S={p['n_stages']}]: n_micro={p['n_micro']} "
                          f"bubble={p['bubble_s']*1e3:.2f}ms "
                          f"stall={p['stall_s']*1e3:.2f}ms "
                          f"act/stage="
                          f"{p['act_wire_bytes_per_stage']/1e9:.3f}GB "
                          f"tier[{p['tier']}]")
                if "checkpoint" in r:
                    c = r["checkpoint"]
                    print(f"       checkpoint[{c['tier']}]: "
                          f"every={c['every']} "
                          f"snap={c['snapshot_bytes']/1e9:.3f}GB "
                          f"save={c['save_s']:.2f}s "
                          f"overhead={c['overhead_s_per_step']*1e3:.2f}ms"
                          f"/step lost={c['lost_s_per_step']*1e3:.2f}ms"
                          f"/step{' async' if c['async'] else ''}")
            except Exception as e:  # noqa: BLE001 — a failed cell is a bug
                results.append({"arch": arch, "shape": shape.name,
                                "mesh": "2x16x16" if args.multi_pod
                                else "16x16", "ok": False,
                                "error": f"{type(e).__name__}: {e}"})
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
                traceback.print_exc()
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    failed = [r for r in results if r.get("ok") is False]
    print(f"\n{len([r for r in results if r.get('ok')])} ok, "
          f"{len(failed)} failed, "
          f"{len([r for r in results if r.get('ok') is None])} skipped")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
