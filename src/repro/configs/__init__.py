from repro.configs.base import (
    CheckpointPlan,
    MemoryPlan,
    MeshPlan,
    ModelConfig,
    MULTI_POD,
    PipelinePlan,
    RunConfig,
    ShapeConfig,
    SHAPES,
    SHAPES_BY_NAME,
    SINGLE_POD,
    TrainConfig,
)
from repro.configs.registry import ARCHS, get_arch, list_archs, cells_for

__all__ = [
    "CheckpointPlan", "MemoryPlan", "MeshPlan", "ModelConfig", "MULTI_POD",
    "PipelinePlan", "RunConfig", "ShapeConfig", "SHAPES", "SHAPES_BY_NAME",
    "SINGLE_POD", "TrainConfig", "ARCHS", "get_arch", "list_archs",
    "cells_for",
]
