"""h2o-danube-1.8b [dense] — llama+mistral mix, sliding-window attention.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000 [arXiv:2401.16818; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab_size=32_000,
    attention="swa",
    window=4096,
    rope_theta=10_000.0,
    act="silu",
    norm="rmsnorm",
    sub_quadratic=True,       # SWA -> long_500k runs
)
