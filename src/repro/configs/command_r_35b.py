"""command-r-35b [dense] — GQA, no-bias.

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256_000,
    attention="full",
    rope_theta=8_000_000.0,
    act="silu",
    norm="layernorm",         # cohere uses LayerNorm (no bias)
    tie_embeddings=True,      # command-r ties input/output embeddings
    parallel_block=True,      # cohere parallel attention + FFN block
    sub_quadratic=False,      # pure full attention -> skip long_500k
)
