"""starcoder2-7b [dense] — GQA, RoPE, bias in qkv (starcoder2 uses bias).

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152 [arXiv:2402.19173; hf]

36 heads is not divisible by the 16-way model axis, so attention activations
use sequence-parallel sharding instead of head sharding (see parallel/sharding).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49_152,
    attention="full",
    rope_theta=100_000.0,
    use_qkv_bias=True,
    act="gelu",
    norm="layernorm",
    sub_quadratic=False,
)
