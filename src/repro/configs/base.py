"""Configuration dataclasses for the repro framework.

Every architecture in ``repro.configs`` is described by a ``ModelConfig``;
runtime behaviour (parallelism, the paper's memory technique, training and
serving) is described by the companion dataclasses below.  Configs are plain
frozen dataclasses so they can be hashed, printed, and diffed in logs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class MemoryPlan:
    """First-class configuration of the paper's technique (MC-DLA).

    policy:
      - "none":   oracle DC-DLA(O) — keep everything resident (infinite-memory
                  baseline of the paper, only valid for small models).
      - "host":   DC-DLA — virtualize against host memory (PCIe path).  Uses
                  ``memory_kind='pinned_host'`` when the backend supports it.
      - "mcdla":  paper-faithful MC-DLA — stash every layer's input feature map
                  (the residual stream) to the pooled memory tier after its
                  last forward use; recompute cheap intermediates (footnote 4).
      - "auto":   beyond-paper — cost-model driven: stash only what is needed
                  to fit the per-device HBM budget, prefer recompute when the
                  recompute time is below the fetch time.
      - "spill":  pooled HBM until the pool's capacity contract is spent,
                  host DRAM past it (core.tiers.SpillTier; the serving
                  stack's default secondary store for cold KV slots).
      - "pipeline": the pipeline-stage tier (core.tiers.PipelineStageTier
                  over pooled HBM): per-stage activation stash for 1F1B
                  schedules, priced as the DCN stage hop in series with the
                  backing store.  Training with ``--pipeline`` builds this
                  tier implicitly over whatever backing policy is set.
    placement: "bw_aware" stripes a stash across *both* mesh axes (paper
      Fig. 10 BW_AWARE, maximum link utilization); "local" stripes across the
      model axis only (LOCAL: one neighbour, half the links).
    compress: optional stash compression — the memory-node's "optional
      encryption/compression ASIC" of §III-A ("fp8"/"int8" halve stash
      bytes; codecs are registry-extensible via core.tiers.register_codec).
    """

    policy: str = "mcdla"            # none | host | mcdla | auto | spill
    placement: str = "bw_aware"      # bw_aware | local
    compress: str = "none"           # none | fp8 | int8
    recompute_cheap: bool = True     # paper footnote 4
    seq_parallel: bool = True        # sequence-parallel residual stream
    stash_aux: bool = True           # pool big float aux (enc states) too
    hbm_budget_gb: float = 16.0      # TPU v5e HBM per chip
    pool_params: bool = True         # FSDP-style weight pooling (ZeRO-3)
    opt_state_bits: int = 32         # 32 | 8  (8-bit Adam moments, beyond-paper)

    def validate(self) -> None:
        # policies and codecs are extensible (core.tiers registries) — the
        # registry, not a frozen list here, is the source of truth
        from repro.core.tiers import registered_codecs, registered_policies
        assert self.policy in registered_policies(), (
            self.policy, registered_policies())
        assert self.placement in ("bw_aware", "local"), self.placement
        assert self.compress in ("none",) + registered_codecs(), (
            self.compress, registered_codecs())
        assert self.opt_state_bits in (32, 8), self.opt_state_bits


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    """Pipeline-parallel training over the pod axis (parallel/pipeline.py).

    schedule: a registered pipeline schedule — "gpipe" (all microbatch
      activations implicitly live per stage) or "1f1b" (in-flight bounded
      by the stage count; stage inputs stashed through the
      PipelineStageTier).  Registry-extensible via
      parallel.pipeline.register_schedule.
    n_micro: microbatches per step; 0 lets the planner pick it by trading
      the bubble term (S-1)/(M+S-1) against predicted stash stalls
      (core.policy.plan_memory).
    n_stages: pipeline stages; 0 resolves to the pipe mesh's axis size.
    """

    enabled: bool = False
    schedule: str = "1f1b"           # gpipe | 1f1b (registry-extensible)
    n_micro: int = 0                 # 0 -> planner-chosen
    n_stages: int = 0                # 0 -> pipe mesh axis size
    axis_name: str = "pod"

    def validate(self) -> None:
        from repro.parallel.pipeline import registered_schedules
        assert self.schedule in registered_schedules(), (
            self.schedule, registered_schedules())
        assert self.n_micro >= 0 and self.n_stages >= 0


@dataclasses.dataclass(frozen=True)
class CheckpointPlan:
    """Checkpoint-as-a-tier: snapshots flow through the same metered
    backing stores as KV spill and activation stash (train/checkpoint.py).

    tier: backing policy for the snapshot leg — "host" (DC-DLA: pinned
      host DRAM), "mcdla" (the pooled-HBM tier), or "spill" (pool until
      the capacity contract is spent, host past it).  Resolved through
      the tier registry and wrapped in a ``CheckpointTier``
      (core.tiers.build_ckpt_tier), so snapshots are metered as
      ``ckpt_save``/``ckpt_load`` in the runtime's ``traffic_report``.
    codec: stash codec for the snapshot payload ("fp8"/"int8" halve the
      bytes; lossy — bit-identical resume requires "none", the default).
    every: save cadence in steps; 0 lets the planner pick it by the
      Young–Daly trade (core.policy.plan_checkpoint): amortized unhidden
      save time against expected replay at the assumed MTBF.
    async_saves: double-buffered background writes — the device→host
      gather is synchronous (donated buffers), the encode+write+commit
      overlaps the next train steps.
    shards: snapshot shard files per checkpoint (manifest carries a CRC
      per shard; the chaos harness corrupts exactly one).
    mtbf_steps: assumed mean steps between failures for the cadence
      model and the dryrun/sim overhead reports.
    """

    enabled: bool = False
    tier: str = "host"               # host | mcdla | spill
    codec: str = "none"              # none | fp8 | int8
    every: int = 0                   # 0 -> planner-chosen (Young–Daly)
    async_saves: bool = False
    shards: int = 1
    mtbf_steps: int = 10_000

    def validate(self) -> None:
        from repro.core.tiers import registered_codecs, registered_policies
        assert self.tier in registered_policies(), (
            self.tier, registered_policies())
        assert self.codec in ("none",) + registered_codecs(), (
            self.codec, registered_codecs())
        assert self.every >= 0 and self.shards >= 1 and self.mtbf_steps >= 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description.  Dims are the *full* published config; use
    ``reduced()`` for CPU smoke twins."""

    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 → d_model // num_heads

    # --- attention ---
    attention: str = "full"          # full | swa | none
    window: int = 4096               # sliding-window size when attention == "swa"
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE half-dim sections
    use_qkv_bias: bool = False
    logit_softcap: float = 0.0
    parallel_block: bool = False     # cohere-style parallel attn+FFN

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_every: int = 1               # every k-th layer is MoE (1 → all layers)
    shared_experts: int = 0
    capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv_width: int = 4
    ssm_groups: int = 1

    # --- hybrid (zamba2): one *shared* attention block every k SSM blocks ---
    hybrid_attn_every: int = 0

    # --- encoder/decoder (whisper) ---
    encoder_layers: int = 0
    is_encoder_decoder: bool = False
    max_target_positions: int = 448

    # --- frontends (stubs per assignment: precomputed embeddings) ---
    frontend: str = "none"           # none | audio_stub | vision_stub
    frontend_tokens: int = 256       # patches / frames provided by the stub

    # --- misc ---
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"                # silu | gelu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    vocab_pad_to: int = 256
    sub_quadratic: bool = False      # eligible for long_500k decode

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_to)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.attention == "none" and self.ssm_state > 0

    @property
    def is_hybrid(self) -> bool:
        return self.hybrid_attn_every > 0

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Total parameters (analytic), used for 6·N·D roofline terms."""
        V, D, F, L = self.padded_vocab, self.d_model, self.d_ff, self.num_layers
        H, KV, hd = self.num_heads, self.num_kv_heads, self.resolved_head_dim
        n = V * D                                   # embedding
        if not self.tie_embeddings:
            n += V * D
        attn = D * H * hd + 2 * D * KV * hd + H * hd * D
        ffn_dense = 3 * D * F if self.act in ("silu",) else 2 * D * F
        if self.is_ssm or self.is_hybrid:
            di, N = self.d_inner, self.ssm_state
            G = self.ssm_groups
            ssm = (D * (2 * di + 2 * G * N + self.ssm_heads)   # in_proj
                   + self.ssm_conv_width * (di + 2 * G * N)    # conv
                   + di * D + di                               # out_proj + norm
                   + 2 * self.ssm_heads)                       # A, D
            if self.is_hybrid:
                shared = attn + ffn_dense + 2 * D
                n += L * ssm + shared
            else:
                n += L * ssm
            return n
        per_layer = attn + 2 * D
        if self.is_moe:
            n_moe = L // self.moe_every
            n_dense = L - n_moe
            moe_ffn = self.num_experts * 3 * D * F + D * self.num_experts
            moe_ffn += self.shared_experts * 3 * D * F
            n += n_moe * (per_layer + moe_ffn) + n_dense * (per_layer + ffn_dense)
        else:
            total_layers = L + self.encoder_layers
            n += total_layers * (per_layer + ffn_dense)
            if self.is_encoder_decoder:   # cross-attention in decoder layers
                n += L * attn
        return n

    def active_param_count(self) -> int:
        """Active parameters per token (MoE uses top_k of num_experts)."""
        if not self.is_moe:
            return self.param_count()
        V, D, F, L = self.padded_vocab, self.d_model, self.d_ff, self.num_layers
        H, KV, hd = self.num_heads, self.num_kv_heads, self.resolved_head_dim
        n = V * D * (1 if self.tie_embeddings else 2)
        attn = D * H * hd + 2 * D * KV * hd + H * hd * D
        ffn_dense = 3 * D * F
        n_moe = L // self.moe_every
        n_dense = L - n_moe
        active_ffn = (self.top_k + self.shared_experts) * 3 * D * F
        n += n_moe * (attn + 2 * D + active_ffn) + n_dense * (attn + 2 * D + ffn_dense)
        return n

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family twin for CPU smoke tests."""
        small = dict(
            num_layers=min(self.num_layers, 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            vocab_pad_to=64,
            num_experts=min(self.num_experts, 4),
            top_k=min(self.top_k, 2),
            shared_experts=min(self.shared_experts, 1),
            encoder_layers=min(self.encoder_layers, 2),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=16,
            hybrid_attn_every=2 if self.hybrid_attn_every else 0,
            window=min(self.window, 64),
            frontend_tokens=min(self.frontend_tokens, 8),
            mrope_sections=(8, 4, 4) if self.mrope_sections else (),
            name=self.name + "-smoke",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assignment: 4 per architecture)."""

    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    mode: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    grad_accum: int = 1
    seed: int = 0
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    checkpoint_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    grad_compress: str = "none"      # none | int8  (error-feedback all-reduce)
    remat: bool = True


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """Logical mesh description; physical mesh is built in launch/mesh.py."""

    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axes

    def axis_size(self, name: str) -> int:
        if name not in self.axes:
            return 1
        return self.shape[self.axes.index(name)]

    @property
    def num_devices(self) -> int:
        return math.prod(self.shape)

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in ("pod", "data") if a in self.axes)


SINGLE_POD = MeshPlan((16, 16), ("data", "model"))
MULTI_POD = MeshPlan((2, 16, 16), ("pod", "data", "model"))
HOST_TEST = MeshPlan((2, 2), ("data", "model"))     # for CPU multi-device tests


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshPlan = SINGLE_POD
    memory: MemoryPlan = MemoryPlan()
    train: TrainConfig = TrainConfig()
    pipeline: PipelinePlan = PipelinePlan()
    ckpt: CheckpointPlan = CheckpointPlan()
