"""Registry of the 10 assigned architectures and their dry-run cells."""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES

from repro.configs.command_r_35b import CONFIG as _command_r
from repro.configs.h2o_danube_1_8b import CONFIG as _danube
from repro.configs.starcoder2_7b import CONFIG as _starcoder2
from repro.configs.smollm_135m import CONFIG as _smollm
from repro.configs.whisper_medium import CONFIG as _whisper
from repro.configs.llama4_maverick_400b import CONFIG as _llama4
from repro.configs.mixtral_8x7b import CONFIG as _mixtral
from repro.configs.zamba2_2_7b import CONFIG as _zamba2
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2vl
from repro.configs.mamba2_370m import CONFIG as _mamba2

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _command_r, _danube, _starcoder2, _smollm, _whisper,
        _llama4, _mixtral, _zamba2, _qwen2vl, _mamba2,
    )
}


def get_arch(name: str) -> ModelConfig:
    if name in ARCHS:
        return ARCHS[name]
    # tolerate hyphen/underscore + prefix matches for CLI ergonomics
    norm = name.replace("_", "-").lower()
    for key, cfg in ARCHS.items():
        if key.lower() == norm or key.lower().startswith(norm):
            return cfg
    raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")


def list_archs() -> List[str]:
    return sorted(ARCHS)


def cells_for(arch: ModelConfig) -> List[Tuple[ModelConfig, ShapeConfig, str]]:
    """All (arch, shape, status) dry-run cells.  status is "run" or a skip
    reason (skips are sanctioned by the assignment and noted in DESIGN.md)."""
    cells = []
    for shape in SHAPES:
        status = "run"
        if shape.name == "long_500k" and not arch.sub_quadratic:
            status = "skip: pure full-attention arch (needs sub-quadratic)"
        cells.append((arch, shape, status))
    return cells


def all_cells() -> List[Tuple[ModelConfig, ShapeConfig, str]]:
    out = []
    for name in list_archs():
        out.extend(cells_for(ARCHS[name]))
    return out
