"""llama4-maverick-400b-a17b [moe] — interleaved dense/MoE, 128 experts top-1,
one shared expert, early fusion (vision stubbed).

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1
[hf:meta-llama/Llama-4-Scout-17B-16E family; unverified]

Every other layer is MoE (moe_every=2), matching Maverick's interleaved
MoE schedule; ~400B total / ~17B active.  This is the arch whose training
state (params + Adam moments ~5.6 TB) CANNOT fit a pod without the paper's
pooled-memory technique — see EXPERIMENTS.md §Dry-run.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    attention="full",
    rope_theta=500_000.0,
    act="silu",
    norm="rmsnorm",
    num_experts=128,
    top_k=1,
    moe_every=2,
    shared_experts=1,
    sub_quadratic=False,      # chunked-attention variant not modeled; skip 500k
)
