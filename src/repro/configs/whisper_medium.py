"""whisper-medium [audio] — encoder-decoder transformer backbone.

24L d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=51865 [arXiv:2212.04356]

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (batch, frames, d_model).  24 encoder + 24
decoder layers (whisper-medium).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,            # decoder layers
    encoder_layers=24,
    is_encoder_decoder=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51_865,
    attention="full",
    use_qkv_bias=True,
    act="gelu",
    norm="layernorm",
    frontend="audio_stub",
    frontend_tokens=1500,     # whisper encoder frames (30 s @ 50 Hz)
    max_target_positions=448,
    sub_quadratic=False,
)
