"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks.

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf]

54 Mamba2 blocks; one *shared-weight* transformer block (full attention +
MLP) is applied every 6 SSM blocks (Zamba's shared-block design: the same
weights are reused at every application site).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32_000,
    attention="full",         # the shared block uses full attention
    rope_theta=10_000.0,
    act="gelu",
    norm="rmsnorm",
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    hybrid_attn_every=6,
    sub_quadratic=True,       # hybrid (SSM decode state is O(1)) -> 500k runs
)
