"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.

48L d_model=1024 (attn-free) d_ff=0 vocab=50280, ssm_state=128
[arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    attention="none",
    norm="rmsnorm",
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    tie_embeddings=True,
    sub_quadratic=True,       # SSM -> long_500k runs (decode state is O(1))
)
