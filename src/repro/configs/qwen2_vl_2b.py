"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution (vision frontend stubbed).

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936 [arXiv:2409.12191; hf]

The vision tower is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (batch, patches, d_model) plus their positions;
text+vision positions drive 3-section M-RoPE (temporal/height/width).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151_936,
    attention="full",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),   # halves of head_dim: 16+24+24 = 64
    use_qkv_bias=True,
    act="silu",
    norm="rmsnorm",
    tie_embeddings=True,
    frontend="vision_stub",
    frontend_tokens=1024,
    sub_quadratic=False,
)
