"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8e top-2
[arXiv:2401.04088; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32_000,
    attention="swa",
    window=4096,
    rope_theta=1_000_000.0,
    act="silu",
    norm="rmsnorm",
    num_experts=8,
    top_k=2,
    moe_every=1,
    sub_quadratic=True,       # SWA -> long_500k runs
)
