"""Explicit collectives: ring all-reduce + int8 error-feedback compression.

XLA already maps ``jax.lax`` collectives to near-optimal ICI ring schedules,
so the *models* use plain psum/all_gather (DESIGN.md §2: do not emulate
NCCL).  This module exists for the two places explicit control is the
feature, not a detail:

* ``ring_all_reduce`` — a reduce-scatter + all-gather ring written with
  ``ppermute``, the textbook schedule the paper's NCCL-based systems use
  (Fig. 4/5).  It is bit-identical to psum and is used by the tests and the
  ring-latency benchmark (paper Fig. 9) to validate the simulator's latency
  model against an executable implementation.

* ``compressed_all_reduce`` — int8 wire traffic with fp32 accumulation and
  error feedback (the 'compressing DMA engine' the paper cites as [56]):
  each hop quantizes its outgoing chunk; the quantization residual is
  carried to the next step by the caller (``CompressionState``).
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro import compat
from repro.compat import shard_map

from repro.core.compress import INT8_MAX


def _ring_perm(n: int, shift: int = 1):
    return [(i, (i + shift) % n) for i in range(n)]


def ring_all_reduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Reduce-scatter + all-gather ring all-reduce over ``axis_name``.

    Call inside shard_map.  x: identical shape on every member; the leading
    dim must be divisible by the axis size.
    """
    n = compat.axis_size(axis_name)
    if n == 1:
        return x
    assert x.shape[0] % n == 0, (x.shape, n)
    chunks = x.reshape((n, x.shape[0] // n) + x.shape[1:])
    me = jax.lax.axis_index(axis_name)
    perm = _ring_perm(n)

    # reduce-scatter: at step k node i sends its partial of chunk (i-k) and
    # accumulates the received partial of chunk (i-k-1).  After n-1 steps
    # node i owns the complete sum of chunk (i+1) mod n.
    acc = chunks
    for k in range(n - 1):
        buf = acc[(me - k) % n]
        buf = jax.lax.ppermute(buf, axis_name, perm)
        acc = acc.at[(me - k - 1) % n].add(buf)

    # all-gather: circulate the complete chunks around the ring.
    mine_idx = (me + 1) % n
    buf = acc[mine_idx]
    out = jnp.zeros_like(chunks)
    out = out.at[mine_idx].set(buf)
    for k in range(n - 1):
        buf = jax.lax.ppermute(buf, axis_name, perm)
        out = out.at[(me - k) % n].set(buf)
    return out.reshape(x.shape)


# ---------------------------------------------------------------------------
def _quant(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)) / INT8_MAX, 1e-30)
    q = jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


def compressed_all_reduce(x: jax.Array, err: jax.Array, axis_name: str
                          ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 all-reduce (inside shard_map).

    Wire bytes: int8 payload + one fp32 scale per hop (4x less traffic than
    fp32).  Accumulation stays fp32 on-chip.  Returns (mean-reduced value,
    new local error).  Convergence: the residual err is added before
    quantization next call (EF-SGD).
    """
    n = compat.axis_size(axis_name)
    corrected = x.astype(jnp.float32) + err
    q, scale = _quant(corrected)
    sent = q.astype(jnp.float32) * scale
    new_err = corrected - sent

    if n == 1:
        return sent, new_err

    acc = sent
    buf_q, buf_s = q, scale
    for _ in range(n - 1):
        buf_q = jax.lax.ppermute(buf_q, axis_name, _ring_perm(n))
        buf_s = jax.lax.ppermute(buf_s, axis_name, _ring_perm(n))
        acc = acc + buf_q.astype(jnp.float32) * buf_s
    return acc / n, new_err


def compressed_tree_all_reduce(grads, errs, axis_name: str = "data"):
    """Pytree version of compressed_all_reduce (call inside shard_map):
    per-device local grad tree + error tree -> (mean grads, new errors)."""
    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errs)
    out = [compressed_all_reduce(g, e, axis_name)
           for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tree, [o[0] for o in out]),
            jax.tree.unflatten(tree, [o[1] for o in out]))
