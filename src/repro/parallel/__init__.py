from repro.parallel.pipeline import (PipelineSchedule, accumulate_microbatches,
                                     get_schedule, make_pipelined,
                                     pipeline_apply, register_schedule,
                                     registered_schedules)
from repro.parallel.sharding import Axes, ShardingPlanner, logical_to_spec
