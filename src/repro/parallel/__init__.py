from repro.parallel.sharding import Axes, ShardingPlanner, logical_to_spec
