"""Divisibility-aware sharding planner.

JAX/GSPMD rejects uneven shards (dim % axis_size must be 0), so every spec in
this framework is produced through :class:`ShardingPlanner`, which drops an
axis assignment when the dim is not divisible and records the fallback.  This
is what makes one code path serve all 10 architectures (36-head starcoder2 and
9-head smollm simply fall back to sequence-parallel activations).

Logical axes used throughout the codebase:
  "batch"  -> physical ("pod", "data")        DP / FSDP batch shard
  "fsdp"   -> physical ("data",)              weight pooling (ZeRO-3)
  "tensor" -> physical ("model",)             Megatron TP
  "expert" -> physical ("model",)             expert parallelism
  "pool"   -> paper's memory-node striping (see core/pool.py)
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import MeshPlan

log = logging.getLogger(__name__)

AxisAssignment = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class Axes:
    """Logical→physical axis translation for a mesh plan."""

    plan: MeshPlan

    @property
    def batch(self) -> Tuple[str, ...]:
        return self.plan.batch_axes            # ("pod","data") or ("data",)

    @property
    def fsdp(self) -> Tuple[str, ...]:
        return ("data",) if "data" in self.plan.axes else ()

    @property
    def tensor(self) -> Tuple[str, ...]:
        return ("model",) if "model" in self.plan.axes else ()

    expert = tensor

    def size(self, axes: Tuple[str, ...]) -> int:
        n = 1
        for a in axes:
            n *= self.plan.axis_size(a)
        return n


def _flatten(assignment: AxisAssignment) -> Tuple[str, ...]:
    if assignment is None:
        return ()
    if isinstance(assignment, str):
        return (assignment,)
    return tuple(assignment)


class ShardingPlanner:
    """Builds PartitionSpecs, silently dropping non-divisible assignments."""

    def __init__(self, plan: MeshPlan):
        self.plan = plan
        self.axes = Axes(plan)
        self.fallbacks: Dict[str, str] = {}

    def spec(self, shape: Sequence[int], assignment: Sequence[AxisAssignment],
             name: str = "?") -> P:
        assert len(shape) == len(assignment), (name, shape, assignment)
        parts = []
        for dim, want in zip(shape, assignment):
            ax = _flatten(want)
            # keep the largest prefix of axes whose product divides dim
            kept: Tuple[str, ...] = ()
            size = 1
            for a in ax:
                if a not in self.plan.axes:
                    continue
                nxt = size * self.plan.axis_size(a)
                if dim % nxt == 0:
                    kept = kept + (a,)
                    size = nxt
                else:
                    self.fallbacks[f"{name}[{dim}]"] = (
                        f"dropped axis {a!r} (dim {dim} % {nxt} != 0)")
            if not kept:
                parts.append(None)
            elif len(kept) == 1:
                parts.append(kept[0])
            else:
                parts.append(kept)
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def named(self, mesh: Mesh, shape: Sequence[int],
              assignment: Sequence[AxisAssignment], name: str = "?") -> NamedSharding:
        return NamedSharding(mesh, self.spec(shape, assignment, name))


def logical_to_spec(planner: ShardingPlanner, shape: Sequence[int],
                    logical: Sequence[Optional[str]], name: str = "?") -> P:
    """Translate logical dim roles into a PartitionSpec.

    Roles: "batch", "fsdp", "tensor", "expert", "seq", None.
    "seq" is unsharded by default (sequence parallelism is applied explicitly
    through constraint helpers in the model code / core.pool).
    """
    ax = planner.axes
    table: Dict[Optional[str], AxisAssignment] = {
        None: None,
        "batch": ax.batch,
        "fsdp": ax.fsdp,
        "tensor": ax.tensor,
        "expert": ax.expert,
        "seq": None,
    }
    return planner.spec(shape, [table[r] for r in logical], name)


def constrain(x: jax.Array, mesh: Mesh, spec: P) -> jax.Array:
    """with_sharding_constraint that is a no-op off-mesh (single device)."""
    if mesh is None or mesh.size == 1:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
