"""Pod-axis pipeline parallelism as a *schedule registry* over the tier API.

The production mesh runs data-parallel over the 'pod' axis by default
(gradient all-reduce over DCN only — the paper's intra-node scope maps to
in-pod traffic, MPI/IB maps to DCN).  For models whose *state* exceeds one
pod even pooled, the pod axis can instead run a pipeline: each pod owns a
contiguous stage of layers and microbatches stream through via ``ppermute``
over DCN.

Schedules are registry-pluggable (like the serving scheduler and codec
registries) and differ in how a stage's saved activations are *placed*:

* ``gpipe`` — the classic schedule: every stage keeps all M microbatch
  activations implicitly live until its backward runs (peak activation
  memory grows with M).
* ``1f1b``  — one-forward-one-backward: in steady state a stage holds at
  most S in-flight microbatches; each stage input is routed through the
  :class:`~repro.core.tiers.PipelineStageTier` stash/fetch hooks
  (``MemoryRuntime.wrap_stage``, metered as ``act_stash``/``act_fetch``)
  instead of staying implicitly live, so device-resident activations are
  bounded by the in-flight window and the rest ride the pool.

Under SPMD autodiff both schedules execute the same forward tick loop
(T = M + S - 1 ticks, bubble fraction (S-1)/(M+S-1)); the schedule object
carries the placement policy (stash hooks) and the analytic contract
(``inflight``, ``bubble_fraction``) that ``core.policy.plan_memory`` and
``sim/`` trade against pool traffic.  Gradient accumulation is the
degenerate single-stage schedule (:func:`accumulate_microbatches`) — the
one microbatching code path ``train/loop.py`` uses.

``pipeline_apply`` is the generic combinator (stage_fn is any layer-stack
function, inputs may be pytrees); it is exercised by tests/test_pipeline.py
on a toy stack and wired into launch/train.py behind ``--pipeline``.
"""
from __future__ import annotations

import abc
from typing import Any, Callable, Dict, Optional, Tuple, Type, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro import compat
from repro.compat import shard_map

Pytree = Any

# metrics accumulated as SUMS across microbatches; everything else is a mean
SUM_METRICS = ("tokens",)


# ---------------------------------------------------------------------------
class PipelineSchedule(abc.ABC):
    """One pipeline schedule: the tick loop + the activation-placement policy.

    ``runtime`` is the stage :class:`~repro.core.runtime.MemoryRuntime`
    (tier = :class:`~repro.core.tiers.PipelineStageTier`); schedules that
    stash route every stage input through it.  With ``runtime=None`` the
    schedule still runs — only the placement hooks are disabled — so the
    analytic contract (``inflight``/``bubble_fraction``) is usable without
    building tiers (``core.policy`` does exactly that).
    """

    name: str = "abstract"
    #: route stage inputs through the stage tier (vs implicitly live)
    stash_saved: bool = False

    def __init__(self, runtime=None):
        self.runtime = runtime

    # -- analytic contract (consumed by core.policy / sim) ------------------
    def inflight(self, n_stages: int, n_micro: int) -> int:
        """Max microbatch activations live on one stage at once."""
        return n_micro

    def bubble_fraction(self, n_stages: int, n_micro: int) -> float:
        """Idle fraction of the (M + S - 1)-tick schedule: (S-1)/(M+S-1)."""
        s, m = n_stages, n_micro
        return (s - 1) / (m + s - 1) if (m + s - 1) > 0 else 0.0

    # -- placement hooks ----------------------------------------------------
    def wrap_stage(self, stage_fn: Callable, name: str = "stage") -> Callable:
        if not self.stash_saved or self.runtime is None or \
                not self.runtime.offloads:
            return stage_fn
        return self.runtime.wrap_stage(stage_fn, name=name)

    # -- the degenerate single-stage path (outside shard_map) ---------------
    def run_local(self, stage_fn: Callable, stage_params: Pytree, x: Pytree,
                  n_micro: int) -> Pytree:
        """S=1 schedule on one device group: M microbatches scanned
        sequentially through the (possibly stash-wrapped) stage, so a
        planner-chosen ``n_micro`` still delivers its per-microbatch
        activation footprint without a stage mesh."""
        fn = self.wrap_stage(stage_fn, name=f"{self.name}_stage")
        M = max(1, n_micro)
        leaves = jax.tree_util.tree_leaves(x)
        if M <= 1 or not leaves or leaves[0].shape[0] % M:
            return fn(stage_params, x)
        micro = jax.tree.map(
            lambda l: l.reshape((M, l.shape[0] // M) + l.shape[1:]), x)

        def body(_, xm):
            return None, fn(stage_params, xm)

        _, outs = jax.lax.scan(body, None, micro)
        return jax.tree.map(lambda o, l: o.reshape(l.shape), outs, x)

    # -- the tick loop ------------------------------------------------------
    def run(self, stage_fn: Callable, stage_params: Pytree, x: Pytree,
            n_micro: int, axis_name: str = "pod") -> Pytree:
        """Run the schedule *inside shard_map* over ``axis_name``.

        stage_fn(params, x) -> y, applied by each member to its own stage;
        x may be a pytree of arrays sharing the leading (batch) dim — every
        member enters with the same x; member 0's stage consumes it first.

        With S stages and M microbatches the loop runs T = M + S - 1 ticks.
        At each tick a member runs its stage on the microbatch it received
        and passes the activation to the next member over DCN.  The SPMD
        emulation is *dense*: every member executes every tick, including
        its S-1 fill/drain ticks whose inputs are garbage (masked out of
        the output), so wall-clock and stash work scale with M + S - 1
        while the analytic contract prices exactly the M real microbatches.
        """
        S = compat.axis_size(axis_name)
        fn = self.wrap_stage(stage_fn, name=f"{self.name}_stage")
        if S == 1:
            return fn(stage_params, x)
        me = jax.lax.axis_index(axis_name)
        M = n_micro
        leaves = jax.tree_util.tree_leaves(x)
        assert leaves and leaves[0].shape[0] % M == 0, \
            (M, [l.shape for l in leaves])
        micro = jax.tree.map(
            lambda l: l.reshape((M, l.shape[0] // M) + l.shape[1:]), x)
        perm = [(i, (i + 1) % S) for i in range(S)]

        T = M + S - 1
        buf = jax.tree.map(lambda l: jnp.zeros_like(l[0]), micro)
        outs = jax.tree.map(jnp.zeros_like, micro)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (if any); others use what arrived
            inject = jax.tree.map(lambda l: l[jnp.clip(t, 0, M - 1)], micro)
            x_in = jax.tree.map(lambda a, b: jnp.where(me == 0, a, b),
                                inject, buf)
            y = fn(stage_params, x_in)
            # last stage records its result for microbatch (t - (S-1))
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            write = jnp.logical_and(me == S - 1, t >= S - 1)
            outs = jax.tree.map(
                lambda o, yy: jnp.where(
                    write,
                    jax.lax.dynamic_update_index_in_dim(o, yy, out_idx, 0),
                    o),
                outs, y)
            buf = jax.tree.map(
                lambda l: jax.lax.ppermute(l, axis_name, perm), y)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(T))
        # results live on the last stage; broadcast them to every member so
        # the caller sees a replicated output (loss is computed everywhere).
        outs = jax.tree.map(
            lambda o: jax.lax.psum(
                jnp.where(me == S - 1, o, jnp.zeros_like(o)), axis_name),
            outs)
        return jax.tree.map(lambda o, l: o.reshape(l.shape), outs, x)


class GPipeSchedule(PipelineSchedule):
    """GPipe: all-forward then all-backward; every stage holds all M
    microbatch activations implicitly live (zero pool traffic, peak
    activation memory grows with M)."""

    name = "gpipe"
    stash_saved = False


class OneFOneBSchedule(PipelineSchedule):
    """1F1B: steady-state in-flight activations bounded by min(S, M) per
    stage; stage inputs are stashed through the stage tier and fetched in
    backward (``act_stash``/``act_fetch`` in the traffic report)."""

    name = "1f1b"
    stash_saved = True

    def inflight(self, n_stages: int, n_micro: int) -> int:
        return min(n_stages, n_micro)


# ---------------------------------------------------------------------------
# schedule registry (mirrors the scheduler/codec registries)
_SCHEDULE_REGISTRY: Dict[str, Type[PipelineSchedule]] = {}


def register_schedule(name: str, cls: Type[PipelineSchedule]) -> None:
    _SCHEDULE_REGISTRY[name] = cls


def registered_schedules() -> Tuple[str, ...]:
    return tuple(sorted(_SCHEDULE_REGISTRY))


def get_schedule(name: str, runtime=None) -> PipelineSchedule:
    if name not in _SCHEDULE_REGISTRY:
        raise KeyError(f"unknown pipeline schedule {name!r}; "
                       f"registered: {registered_schedules()}")
    return _SCHEDULE_REGISTRY[name](runtime)


register_schedule("gpipe", GPipeSchedule)
register_schedule("1f1b", OneFOneBSchedule)


# ---------------------------------------------------------------------------
def pipeline_apply(stage_fn: Callable, stage_params: Pytree, x: Pytree,
                   n_micro: int, axis_name: str = "pod",
                   schedule: Union[str, PipelineSchedule] = "gpipe"
                   ) -> Pytree:
    """Run a pipeline schedule over ``axis_name`` *inside shard_map*."""
    if isinstance(schedule, str):
        schedule = get_schedule(schedule)
    return schedule.run(stage_fn, stage_params, x, n_micro, axis_name)


def make_pipelined(mesh: Mesh, stage_fn: Callable, n_micro: int,
                   axis_name: str = "pod",
                   stage_param_spec: Optional[P] = None,
                   schedule: Union[str, PipelineSchedule] = "gpipe",
                   runtime=None) -> Callable:
    """shard_map wrapper: (stacked stage params, x) -> y."""
    if stage_param_spec is None:
        stage_param_spec = P(axis_name)
    if isinstance(schedule, str):
        schedule = get_schedule(schedule, runtime=runtime)

    def inner(stage_params, x):
        sp = jax.tree.map(lambda l: l[0], stage_params)  # my stage (size-1)
        return schedule.run(stage_fn, sp, x, n_micro, axis_name)

    return shard_map(inner, mesh=mesh,
                     in_specs=(stage_param_spec, P()),
                     out_specs=P(),
                     check_vma=False)


# ---------------------------------------------------------------------------
def accumulate_microbatches(loss_fn: Callable, params: Pytree, batch: Pytree,
                            n_micro: int):
    """The degenerate single-stage schedule: gradient accumulation.

    Splits the batch's leading dim into ``n_micro`` microbatches scanned
    sequentially — the S=1, DCN-free corner of the schedule space (no
    bubble, no stage tier, activation memory divided by M).  Returns
    ``(grads, loss, metrics)`` with grads/loss averaged and *every* metric
    the loss_fn reports accumulated across microbatches: token counters
    (:data:`SUM_METRICS`) are summed, losses averaged.
    """
    n = n_micro

    def micro(i):
        return jax.tree.map(
            lambda v: v.reshape((n, v.shape[0] // n) + v.shape[1:])[i]
            if getattr(v, "ndim", 0) >= 1 and v.shape[0] % n == 0 else v,
            batch)

    # metric keys are static: shape-infer them from one microbatch
    m0 = jax.eval_shape(lambda p, b: loss_fn(p, b)[1], params, micro(0))

    def body(carry, i):
        acc, msum, ltot = carry
        (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, micro(i))
        acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
        msum = {k: msum[k] + jnp.float32(m[k]) for k in msum}
        return (acc, msum, ltot + l), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    zeros_m = {k: jnp.float32(0) for k in m0}
    (g, msum, ltot), _ = jax.lax.scan(
        body, (zeros, zeros_m, jnp.float32(0)), jnp.arange(n))
    g = jax.tree.map(lambda v: v / n, g)
    metrics = {k: (v if k in SUM_METRICS else v / n)
               for k, v in msum.items()}
    return g, ltot / n, metrics
