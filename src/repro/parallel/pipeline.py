"""Pod-axis pipeline parallelism (optional alternative to pod-DP).

The production mesh runs data-parallel over the 'pod' axis by default
(gradient all-reduce over DCN only — the paper's intra-node scope maps to
in-pod traffic, MPI/IB maps to DCN).  For models whose *state* exceeds one
pod even pooled, the pod axis can instead run a GPipe-style pipeline: each
pod owns a contiguous stage of layers and microbatches stream through via
``ppermute`` over DCN.

``pipeline_apply`` is the generic combinator (stage_fn is any layer-stack
function); it is exercised by tests/test_pipeline.py on a toy stack and is
wired into launch/train.py behind ``--pipeline``.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro import compat
from repro.compat import shard_map

Pytree = Any


def pipeline_apply(stage_fn: Callable, stage_params: Pytree, x: jax.Array,
                   n_micro: int, axis_name: str = "pod") -> jax.Array:
    """Run a pipeline over ``axis_name`` *inside shard_map*.

    stage_fn(params, x) -> y, applied by each member to its own stage.
    stage_params: this member's stage weights (already sharded by stage).
    x: (n_micro * mb, ...) microbatchable input — every member enters with
    the same x; member 0's stage consumes it first.

    GPipe schedule with S stages and M microbatches: T = M + S - 1 ticks.
    At each tick a member runs its stage on the microbatch it received and
    passes the activation to the next member.  Bubble fraction
    (S-1)/(M+S-1) — pick n_micro >> n_stages.
    """
    S = compat.axis_size(axis_name)
    me = jax.lax.axis_index(axis_name)
    if S == 1:
        return stage_fn(stage_params, x)
    M = n_micro
    assert x.shape[0] % M == 0
    micro = x.reshape((M, x.shape[0] // M) + x.shape[1:])
    perm = [(i, (i + 1) % S) for i in range(S)]

    T = M + S - 1
    buf = jnp.zeros_like(micro[0])
    outs = jnp.zeros_like(micro)

    def tick(t, carry):
        buf, outs = carry
        # stage 0 injects microbatch t (if any); others use what arrived
        inject = micro[jnp.clip(t, 0, M - 1)]
        x_in = jnp.where(me == 0, inject, buf)
        y = stage_fn(stage_params, x_in)
        # last stage records its result for microbatch (t - (S-1))
        out_idx = jnp.clip(t - (S - 1), 0, M - 1)
        write = jnp.logical_and(me == S - 1, t >= S - 1)
        outs = jax.lax.cond(
            write,
            lambda o: jax.lax.dynamic_update_index_in_dim(o, y, out_idx, 0),
            lambda o: o, outs)
        buf = jax.lax.ppermute(y, axis_name, perm)
        return buf, outs

    buf, outs = jax.lax.fori_loop(0, T, tick, (buf, outs))
    # results live on the last stage; broadcast them to every member so the
    # caller sees a replicated output (loss is computed everywhere).
    outs = jax.lax.psum(jnp.where(me == S - 1, outs, jnp.zeros_like(outs)),
                        axis_name)
    return outs.reshape(x.shape)


def make_pipelined(mesh: Mesh, stage_fn: Callable, n_micro: int,
                   axis_name: str = "pod",
                   stage_param_spec: P = P("pod")) -> Callable:
    """shard_map wrapper: (stacked stage params, x) -> y."""

    def inner(stage_params, x):
        sp = jax.tree.map(lambda l: l[0], stage_params)  # my stage (size-1)
        return pipeline_apply(stage_fn, sp, x, n_micro, axis_name)

    return shard_map(inner, mesh=mesh,
                     in_specs=(stage_param_spec, P()),
                     out_specs=P(),
                     check_vma=False)
