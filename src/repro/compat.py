"""Version-compat shims for the installed jax.

One shared location for every API that moved between jax releases, so the
rest of the codebase imports from here instead of guessing:

* ``shard_map`` — promoted from ``jax.experimental.shard_map`` to
  ``jax.shard_map`` in newer releases; older jaxlibs only ship the
  experimental path.  The replication-check kwarg was also renamed
  (``check_rep`` -> ``check_vma``); this wrapper accepts either spelling
  and forwards whichever the installed jax understands.
"""
from __future__ import annotations

import functools
import inspect

try:                                    # newer jax exports it directly
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:                     # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


@functools.wraps(_shard_map)
def shard_map(*args, **kwargs):
    if "check_vma" in kwargs and "check_vma" not in _PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    elif "check_rep" in kwargs and "check_rep" not in _PARAMS:
        kwargs["check_vma"] = kwargs.pop("check_rep")
    return _shard_map(*args, **kwargs)


def axis_size(axis_name) -> int:
    """``jax.lax.axis_size`` appeared in newer jax; fall back to the mesh
    axis env lookup that works everywhere (psum of 1 is constant-folded)."""
    import jax
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


__all__ = ["shard_map", "axis_size"]

