"""The pooled-memory tier (the paper's memory-nodes, §III-A) on a TPU mesh.

Hardware adaptation (DESIGN.md §2): a TPU pod has no DDR4 boards hanging off
the ICI — the TPU-native realization of "a pool of capacity-optimized memory
on the device-side interconnect" is the *aggregate HBM of the mesh*: a tensor
that is stashed to the pool is re-sharded so that each chip keeps only
1/pool_size of it, and is fetched back (all-gathered over ICI) right before
its backward use.  Capacity expands exactly like the paper's memory-nodes
(256 chips pool 4 TB of HBM) and the fetch traffic travels over the same
class of links (ICI ~ NVLINK).

Placement policies (paper Fig. 10):

* ``bw_aware`` — the stash is striped over **all** mesh axes: the sharded
  dim spans ('pod','data','model'), so the fetch collective moves traffic
  over *both* torus dimensions' links simultaneously (the analogue of
  splitting an allocation round-robin across the left *and* right
  memory-node: all N links active, 2x fetch bandwidth).
* ``local`` — the stash is sharded over the 'model' axis only; the fetch
  all-gathers over a single mesh dimension (one neighbour's links).

Capacity accounting mirrors the paper's boot-time memory map (Fig. 10):
``PoolAccountant`` tracks bytes-per-device for device_local vs pooled
allocations against the HBM budget.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import MemoryPlan, MeshPlan
from repro.parallel.sharding import ShardingPlanner


@dataclasses.dataclass(frozen=True)
class PoolAxes:
    """Which mesh axes form the pool for each placement policy."""

    plan: MeshPlan

    @property
    def bw_aware(self) -> Tuple[str, ...]:
        # stripe across every device-side axis (paper: left+right nodes).
        return tuple(a for a in self.plan.axes)

    @property
    def local(self) -> Tuple[str, ...]:
        # a single mesh dimension (paper: one neighbour memory-node).
        return ("model",) if "model" in self.plan.axes else self.plan.axes[-1:]

    def axes_for(self, placement: str) -> Tuple[str, ...]:
        return self.bw_aware if placement == "bw_aware" else self.local

    def pool_size(self, placement: str) -> int:
        return math.prod(self.plan.axis_size(a) for a in self.axes_for(placement))


def pool_spec(shape: Sequence[int], planner: ShardingPlanner,
              placement: str = "bw_aware",
              batch_dim: Optional[int] = None,
              name: str = "stash") -> P:
    """PartitionSpec for a stashed tensor.

    Only XLA-*efficient* reshards from the compute layout (batch dim on the
    data axes) are emitted — moving the 'data' axis off the batch dim makes
    current SPMD fall back to full rematerialization, which would replicate
    the activation on every chip (fatal at 32k seq).  The efficient set:

    * ``local``    — batch keeps its data-parallel axes; the largest
      divisible non-batch dim is sharded over 'model'.  Stash is a pure
      local slice + neighbour permute; fetch is one all-gather over the
      model-dim ICI ring.
    * ``bw_aware`` — additionally *extends the batch dim hierarchically*
      over the model axis when divisible (P(('pod','data','model'),...)).
      The stash collective is then a cheap collective-permute of half a
      shard per hop and every chip of the pool holds a distinct block.
      When batch is not divisible it falls back to the ``local`` layout.

    Hardware-adaptation note (DESIGN.md §2): on a 2D torus with DP pinned to
    one axis, fetch traffic can only ride the model-dim links; the paper's
    LOCAL-vs-BW_AWARE 2x-link contrast (Fig. 10) does not transfer 1:1 — the
    data-dim links are instead kept busy by the concurrent FSDP gradient
    collectives, which is the same "use all N links" end state MC-DLA(B)
    argues for.  The Fig. 10 effect itself is reproduced in ``sim/``.
    Per-device capacity expansion is identical (the full pool) either way.
    """
    plan = planner.plan
    model_axes = ("model",) if "model" in plan.axes else plan.axes[-1:]
    model_size = math.prod(plan.axis_size(a) for a in model_axes)
    batch_axes = planner.axes.batch
    batch_size = math.prod(plan.axis_size(a) for a in batch_axes)

    assignment: list = [None] * len(shape)
    if batch_dim is not None and batch_dim < len(shape):
        assignment[batch_dim] = batch_axes

    if placement == "bw_aware" and batch_dim is not None and \
            batch_dim < len(shape) and \
            shape[batch_dim] % (batch_size * model_size) == 0:
        # hierarchical batch stripe: every chip holds a distinct block
        assignment[batch_dim] = tuple(batch_axes) + tuple(model_axes)
        return planner.spec(shape, assignment, name=name)

    # local layout (also the bw_aware fallback): the FIRST divisible
    # non-batch dim (the sequence dim of a (B,S,D) residual) over the model
    # axis — this matches the sequence-parallel residual layout, so the
    # stash constraint composes with it instead of fighting it (sharding a
    # different dim makes GSPMD emit a cross-dim reshard per layer).
    order = [i for i in range(len(shape)) if i != batch_dim]
    order.sort(key=lambda i: (i != 1, -shape[i]))      # prefer dim 1, then size
    for i in order:
        if shape[i] > 0 and shape[i] % model_size == 0:
            assignment[i] = model_axes
            break
    return planner.spec(shape, assignment, name=name)


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PoolAccountant:
    """Boot-time memory map: device_local vs pooled bytes per chip.

    Used by core.policy to decide KEEP vs POOL vs RECOMPUTE and by the
    dry-run report to explain ``memory_analysis()`` numbers.
    """

    plan: MeshPlan
    memory: MemoryPlan
    local_bytes: float = 0.0          # resident per-device bytes
    pooled_bytes: float = 0.0         # per-device share of pooled tensors
    host_bytes: float = 0.0           # per-device share parked in host DRAM
                                      # (no HBM cost)

    @property
    def pool_devices(self) -> int:
        return PoolAxes(self.plan).pool_size(self.memory.placement)

    @property
    def budget(self) -> float:
        return self.memory.hbm_budget_gb * 1e9

    def alloc_local(self, nbytes: float) -> None:
        self.local_bytes += nbytes

    def alloc_pooled(self, nbytes: float) -> None:
        # a pooled tensor of `nbytes` costs nbytes/pool_size per chip
        self.pooled_bytes += nbytes / max(self.pool_devices, 1)

    def alloc_host(self, nbytes: float) -> None:
        # host-tier stash: occupies DRAM, not HBM (DC-DLA baseline)
        self.host_bytes += nbytes

    @property
    def per_device(self) -> float:
        return self.local_bytes + self.pooled_bytes

    @property
    def fits(self) -> bool:
        return self.per_device <= self.budget

    @property
    def headroom(self) -> float:
        return self.budget - self.per_device

    def system_capacity(self) -> float:
        """Total pooled capacity exposed to one device (paper's 'tens of
        TBs'): its own HBM plus its share of every other chip's."""
        return self.budget * self.pool_devices


def pool_report(plan: MeshPlan, memory: MemoryPlan) -> str:
    axes = PoolAxes(plan)
    n = axes.pool_size(memory.placement)
    cap = memory.hbm_budget_gb * n / 1e3
    return (f"pool[{memory.placement}] axes={axes.axes_for(memory.placement)} "
            f"devices={n} capacity={cap:.1f}TB")
