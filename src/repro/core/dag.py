"""Layer DAG + reuse-distance analysis (paper §II-B).

The paper's runtime leverages "the user-level DNN topology graph as means to
extract compile-time data dependency information ... to derive the DNN data
reuse distance to schedule performance-aware data copy operations".  This
module is that graph: a sequence of :class:`LayerNode` with analytic
FLOP/byte costs, from which we derive

* the **reuse distance** of each saved feature map (forward position i is
  re-used at backward position 2L-i, so the stash->prefetch window spans the
  compute of layers i+1..L plus the backward of L..i+1), and
* the stash/prefetch **schedule** with available overlap per transfer —
  consumed by ``core.policy`` (KEEP/POOL/RECOMPUTE) and by ``sim/`` (the
  paper's Fig. 11 latency breakdown).

Builders exist for the 10 assigned architectures (from ``ModelConfig``) and
the paper's own 8 workloads (``sim/workloads.py``).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class LayerNode:
    """One forward layer.  Sizes are *global* (whole batch), in elements or
    FLOPs; bytes are derived with the training dtype width."""

    name: str
    flops_fwd: float                 # forward FLOPs for the global batch
    saved_bytes: float               # feature maps saved for backward (X)
    weight_bytes: float              # parameter bytes (for sync sizing: dW)
    cheap: bool = False              # paper footnote 4: recompute, don't stash
    fc: bool = False                 # FC/recurrent layer (model-parallelizable
                                     # under Krizhevsky's one-weird-trick)

    @property
    def flops_bwd(self) -> float:
        # dX and dW each cost ~one forward's FLOPs (standard 2x)
        return 2.0 * self.flops_fwd


@dataclasses.dataclass
class LayerDAG:
    layers: List[LayerNode]

    @property
    def num_layers(self) -> int:
        return len(self.layers)

    def total_flops(self) -> float:
        return sum(l.flops_fwd + l.flops_bwd for l in self.layers)

    def total_saved_bytes(self) -> float:
        return sum(l.saved_bytes for l in self.layers)

    def total_weight_bytes(self) -> float:
        return sum(l.weight_bytes for l in self.layers)

    def reuse_distance(self, i: int) -> float:
        """FLOPs executed between layer i's last forward use and its
        backward use — the window available to hide the stash+prefetch."""
        fwd_after = sum(l.flops_fwd for l in self.layers[i + 1:])
        bwd_before = sum(l.flops_bwd for l in self.layers[i + 1:])
        return fwd_after + bwd_before

    def schedule(self) -> List[Tuple[int, float, float]]:
        """[(layer, stash_bytes, overlap_flops)] for non-cheap layers, the
        paper's memory-overlaying schedule."""
        out = []
        for i, l in enumerate(self.layers):
            if l.cheap or l.saved_bytes == 0:
                continue
            out.append((i, l.saved_bytes, self.reuse_distance(i)))
        return out


# ---------------------------------------------------------------------------
def build_dag(cfg: ModelConfig, shape: ShapeConfig,
              dtype_bytes: int = 2) -> LayerDAG:
    """Analytic per-layer DAG for an assigned architecture x shape cell.

    Saved bytes per transformer layer = the residual-stream input (B,S,D) —
    the unit the offload runtime stashes; intermediates are recomputed
    (footnote-4 behaviour is built into the vjp recompute).
    """
    B, S = shape.global_batch, shape.seq_len
    D, F, L = cfg.d_model, cfg.d_ff, cfg.num_layers
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    T = B * S
    layers: List[LayerNode] = []

    # embedding
    layers.append(LayerNode(
        "embed", flops_fwd=0.0, saved_bytes=0.0,
        weight_bytes=cfg.padded_vocab * D * dtype_bytes, cheap=True))

    def attn_flops(seq: int) -> float:
        proj = 2.0 * T * D * (H * hd + 2 * KV * hd) + 2.0 * T * H * hd * D
        if cfg.attention == "none":
            return 0.0
        span = min(seq, cfg.window) if cfg.attention == "swa" and cfg.window else seq
        # causal: average attended span is ~span/2 for full, ~window for swa
        eff = span / 2 if cfg.attention == "full" else span
        score = 2.0 * B * H * seq * eff * hd * 2  # qk^T and pv
        return proj + score

    def ffn_flops(f: int) -> float:
        mults = 3 if cfg.act == "silu" else 2
        return 2.0 * T * D * f * mults

    def ssm_flops() -> float:
        di, N = cfg.d_inner, cfg.ssm_state
        G = cfg.ssm_groups
        proj = 2.0 * T * D * (2 * di + 2 * G * N + cfg.ssm_heads) + 2.0 * T * di * D
        # SSD chunked: intra-chunk quadratic + state update, per head
        c = cfg.ssm_chunk
        nh, p = cfg.ssm_heads, cfg.ssm_head_dim
        intra = 2.0 * B * (S * c) * nh * p          # (c x c) scores x values
        state = 4.0 * B * S * nh * p * N            # B^T x + C state reads
        return proj + intra + state

    resid_bytes = T * D * dtype_bytes

    for i in range(L):
        if cfg.is_ssm or (cfg.is_hybrid and
                          (cfg.hybrid_attn_every == 0 or
                           (i + 1) % cfg.hybrid_attn_every != 0)):
            layers.append(LayerNode(
                f"ssm_{i}", flops_fwd=ssm_flops(), saved_bytes=resid_bytes,
                weight_bytes=(cfg.param_count() / max(L, 1)) * dtype_bytes))
            if cfg.is_hybrid and cfg.hybrid_attn_every and \
                    (i + 1) % cfg.hybrid_attn_every == 0:
                layers.append(LayerNode(
                    f"shared_attn_{i}",
                    flops_fwd=attn_flops(S) + ffn_flops(F),
                    saved_bytes=resid_bytes,
                    weight_bytes=0.0))  # shared weights counted once
            continue
        if cfg.is_hybrid:
            continue
        a = attn_flops(S)
        if cfg.is_moe and (i % cfg.moe_every == cfg.moe_every - 1):
            f = ffn_flops(F) * (cfg.top_k + cfg.shared_experts)
            w = (2 * D * (H + 2 * KV) * hd +
                 cfg.num_experts * 3 * D * F) * dtype_bytes
        else:
            f = ffn_flops(F) if F else 0.0
            w = (2 * D * (H + 2 * KV) * hd + 3 * D * F) * dtype_bytes
        layers.append(LayerNode(
            f"layer_{i}", flops_fwd=a + f, saved_bytes=resid_bytes,
            weight_bytes=w))

    if cfg.encoder_layers:
        Te = B * cfg.frontend_tokens
        enc_resid = Te * D * dtype_bytes
        for i in range(cfg.encoder_layers):
            proj = 2.0 * Te * D * (H * hd + 2 * KV * hd) + 2.0 * Te * H * hd * D
            score = 2.0 * B * H * cfg.frontend_tokens ** 2 * hd * 2
            layers.append(LayerNode(
                f"enc_{i}", flops_fwd=proj + score + 2.0 * Te * D * F * 2,
                saved_bytes=enc_resid,
                weight_bytes=(2 * D * (H + 2 * KV) * hd + 2 * D * F) * dtype_bytes))

    # lm head (chunked CE keeps logits out of live memory; cheap to recompute)
    layers.append(LayerNode(
        "lm_head", flops_fwd=2.0 * T * D * cfg.padded_vocab,
        saved_bytes=0.0,
        weight_bytes=0.0 if cfg.tie_embeddings else
        cfg.padded_vocab * D * dtype_bytes))
    return LayerDAG(layers)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6*N*D tokens (dense) / 6*N_active*D (MoE) — the §Roofline
    'useful compute' yardstick."""
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    n = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    if shape.mode == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens          # inference: forward only
