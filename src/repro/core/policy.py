"""KEEP / POOL / RECOMPUTE planner — the cost model behind `policy="auto"`.

The paper stashes *every* layer's feature maps (to maximally stress the
interconnect, §IV) and recomputes only cheap layers (footnote 4).  That is
the faithful `policy="mcdla"` mode.  `policy="auto"` is the beyond-paper
mode: a per-layer cost model decides, under the per-device HBM budget,

  KEEP      — leave the saved tensor resident (zero traffic) while the
              budget allows;
  POOL      — stash to the backing tier; predicted stall is
              max(0, stash_time + fetch_time - overlap_window);
  RECOMPUTE — if re-running the layer forward is cheaper than the fetch
              (footnote 4 generalized by the cost model).

Decisions are taken largest-reuse-distance-first: the tensor that stays idle
longest is the best candidate to evict, and its transfer has the widest
overlap window — the same intuition the paper's memory-overlaying scheduler
uses.

The planner costs candidate placements through the
:class:`~repro.core.tiers.MemoryTier` contract — ``tier.bandwidth()`` prices
the transfer, ``tier.account()``/``tier.capacity()`` maintain the boot-time
memory map — so a new tier (host+pool spill, zstd codec, ...) is priced
without touching this module.

Pipeline training extends the same cost model: given a
:class:`~repro.configs.base.PipelinePlan`, :func:`plan_memory` jointly
chooses ``n_micro`` and the per-stage KEEP/POOL/RECOMPUTE split by adding
the schedule's bubble term ``(S-1)/(M+S-1) * step_time`` against the
predicted stash stalls of M per-microbatch transfers (each paying the DCN
hop latency) — one cost model for the whole bubble-vs-pool-traffic trade.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro import hw
from repro.configs.base import MemoryPlan, MeshPlan, PipelinePlan
from repro.core.dag import LayerDAG
from repro.core.pool import PoolAccountant
from repro.core.tiers import MemoryTier, build_tier
from repro.parallel.sharding import ShardingPlanner


@dataclasses.dataclass(frozen=True)
class Decision:
    layer: int
    action: str                  # keep | pool | recompute
    saved_bytes: float           # global bytes affected
    est_stall_s: float           # predicted unhidden transfer time


@dataclasses.dataclass(frozen=True)
class PipelineDecision:
    """The planner's bubble-vs-stall verdict for one pipeline run."""

    schedule: str
    n_stages: int
    n_micro: int                 # chosen (or forced) microbatch count
    bubble_s: float              # (S-1)/(M+S-1) * step_time
    stall_s: float               # predicted unhidden stage stash/fetch time
    act_wire_bytes: float = 0.0  # stash+fetch bytes through the stage tier

    @property
    def total_s(self) -> float:
        return self.bubble_s + self.stall_s


@dataclasses.dataclass(frozen=True)
class CheckpointDecision:
    """The planner's cadence verdict for the checkpoint tier.

    Per-step cost = ``overhead_s`` (unhidden save time amortized over the
    cadence) + ``lost_s`` (expected replay: a failure loses every/2 steps
    on average, paid at rate 1/mtbf_steps).  ``every = 0`` sweeps
    candidates and keeps the minimizer — the discrete Young–Daly optimum
    ``sqrt(2 · MTBF · save_time)`` against the actual step time.
    """

    tier: str
    every: int                   # chosen save cadence (steps)
    snapshot_bytes: float        # wire bytes of one snapshot
    save_s: float                # one snapshot through the tier
    overhead_s: float            # amortized unhidden save time per step
    lost_s: float                # expected replay time per step
    async_saves: bool = False

    @property
    def total_s(self) -> float:
        return self.overhead_s + self.lost_s


@dataclasses.dataclass
class MemoryPlanReport:
    decisions: List[Decision]
    resident_bytes_per_dev: float
    pooled_bytes_per_dev: float
    budget_bytes: float
    tier: str = "pooled_hbm"
    host_bytes: float = 0.0
    pipeline: Optional[PipelineDecision] = None
    checkpoint: Optional[CheckpointDecision] = None

    @property
    def fits(self) -> bool:
        return (self.resident_bytes_per_dev + self.pooled_bytes_per_dev
                <= self.budget_bytes)

    def count(self, action: str) -> int:
        return sum(1 for d in self.decisions if d.action == action)

    def total_stall(self) -> float:
        return sum(d.est_stall_s for d in self.decisions)


def fetch_bandwidth(plan: MeshPlan, memory: MemoryPlan,
                    chip: hw.Chip = hw.TPU_V5E) -> float:
    """Per-device stash/fetch bandwidth of the configured backing tier.

    Deprecated shim: dispatches through the tier registry — use
    ``tier.bandwidth(plan, chip)`` (or ``MemoryRuntime``) directly.
    """
    return build_tier(memory, ShardingPlanner(plan)).bandwidth(plan, chip)


def micro_candidates(global_batch: int, n_stages: int,
                     cap: int = 16) -> List[int]:
    """Feasible n_micro values: divisors of the global batch (a microbatch
    must tile the batch dim) of at least ``n_stages`` — fewer microbatches
    than stages leaves stages idle most of the schedule — largest ``cap``
    of them.  Falls back to all divisors when none reach the stage count."""
    divs = [m for m in range(1, max(1, global_batch) + 1)
            if global_batch % m == 0]
    divs = [m for m in divs if m >= max(1, n_stages)] or divs
    return divs[-cap:] if len(divs) > cap else divs


# checkpoint cadence sweep when CheckpointPlan.every == 0: a coarse
# logarithmic grid — the Young–Daly optimum is flat around its minimum, so
# a grid hit within ~2x of sqrt(2·MTBF·save_s) costs almost nothing extra.
CADENCE_CANDIDATES: Sequence[int] = (1, 2, 5, 10, 25, 50, 100, 250, 500,
                                     1000)


def plan_checkpoint(state_bytes: float, step_time_s: float,
                    tier: MemoryTier, plan: MeshPlan,
                    chip: hw.Chip = hw.TPU_V5E, *,
                    every: int = 0, async_saves: bool = False,
                    mtbf_steps: int = 10_000,
                    candidates: Optional[Sequence[int]] = None
                    ) -> CheckpointDecision:
    """Cost a checkpoint cadence against step time through the tier contract.

    state_bytes: global params+optimizer bytes of one snapshot (raw).
    every: force a cadence, or 0 to sweep ``candidates`` and keep the
    minimizer of amortized-save + expected-replay — the discrete form of
    Young–Daly ``sqrt(2 · MTBF · save_time)``.  Async saves hide up to
    ``every · step_time`` of the drain behind the next steps.
    """
    bw = tier.bandwidth(plan, chip)
    n_dev = max(plan.num_devices, 1)
    snap = state_bytes * tier.payload_ratio()
    save_s = snap / (bw * n_dev) if bw > 0 else 0.0
    cands = [every] if every > 0 else list(candidates or CADENCE_CANDIDATES)
    best = None
    for k in cands:
        unhidden = max(0.0, save_s - k * step_time_s) if async_saves \
            else save_s
        overhead = unhidden / k
        lost = (k / 2.0) * step_time_s / max(mtbf_steps, 1)
        if best is None or overhead + lost < best[1] + best[2]:
            best = (k, overhead, lost)
    k, overhead, lost = best
    return CheckpointDecision(tier.describe(), k, snap, save_s, overhead,
                              lost, async_saves)


def plan_memory(dag: LayerDAG, plan: MeshPlan, memory: MemoryPlan,
                chip: hw.Chip = hw.TPU_V5E,
                model_state_bytes: float = 0.0,
                tier: Optional[MemoryTier] = None,
                pipeline: Optional[PipelinePlan] = None,
                n_micro_candidates: Optional[Sequence[int]] = None,
                checkpoint=None,
                ckpt_tier: Optional[MemoryTier] = None
                ) -> MemoryPlanReport:
    """Run the planner over a layer DAG.

    model_state_bytes: global bytes of params+optimizer state (FSDP-sharded
    over the pool, so they cost /pool_size per device).
    tier: the backing store to cost POOL decisions against; resolved from
    ``memory`` via the tier registry when not provided.  Pipeline runs pass
    the :class:`~repro.core.tiers.PipelineStageTier` here.
    pipeline: when given (and enabled), sweep ``n_micro_candidates`` (or the
    forced ``pipeline.n_micro``) and pick the microbatch count minimizing
    bubble + stash stalls; the verdict lands in ``report.pipeline``.
    checkpoint: a :class:`~repro.configs.base.CheckpointPlan` — when given
    (and enabled), cost the save cadence against the planned step time
    (compute + pipeline penalty + stash stalls) through ``ckpt_tier`` (or
    the plan's :func:`~repro.core.tiers.build_ckpt_tier` stack); the
    verdict lands in ``report.checkpoint``.
    """
    if tier is None:
        tier = build_tier(memory, ShardingPlanner(plan))
    n_dev = plan.num_devices
    bw = tier.bandwidth(plan, chip)
    ratio = tier.payload_ratio()
    eff_flops = n_dev * chip.peak_flops

    sched = dag.schedule()
    # largest reuse distance first — best eviction victims
    order = sorted(range(len(sched)), key=lambda j: -sched[j][2])
    stash_all = tier.stash_all and tier.offloads
    per_dev_saved = [b / n_dev for (_, b, _) in sched]

    def run_pass(n_micro: int = 1, inflight_frac: float = 0.0,
                 hop_lat: float = 0.0, force_keep: bool = False):
        """One KEEP/POOL/RECOMPUTE pass.

        Non-pipelined (the defaults): one transfer per layer, hidden
        inside the reuse-distance window — exactly the original model.
        Pipelined (``n_micro > 1`` or ``hop_lat > 0``): M per-microbatch
        transfers, each paying ``hop_lat`` twice (stash+fetch over the
        stage hop) and each hiding only behind the layer's own
        per-microbatch compute — the steady-state 1F1B tick, where the
        full-step reuse window no longer exists.  ``inflight_frac`` of a
        pooled activation stays device-resident (the schedule's in-flight
        window).
        """
        acct = PoolAccountant(plan, memory)
        # state (params + moments) is pooled via FSDP
        acct.alloc_local(model_state_bytes / (acct.pool_devices
                                              if memory.pool_params else 1))
        decisions: List[Decision] = []
        # Pass 1: keep everything resident, then evict until it fits
        # (auto), or stash everything (mcdla/host — the paper's
        # stress-test policies).
        for b in per_dev_saved:
            acct.alloc_local(b)
        M = max(1, n_micro)
        pipelined = n_micro > 1 or hop_lat > 0.0
        for j in order:
            i, bytes_g, window_flops = sched[j]
            if force_keep or (not stash_all and acct.fits):
                decisions.append(Decision(i, "keep", bytes_g, 0.0))
                continue
            layer = dag.layers[i]
            # stash + fetch, per microbatch (latency paid per transfer)
            xfer_micro = (2.0 * (bytes_g * ratio) / (M * bw * n_dev)
                          + 2.0 * hop_lat)
            if pipelined:
                # steady-state tick: the transfer hides behind the layer's
                # own fwd+bwd compute for one microbatch
                window_micro = 3.0 * layer.flops_fwd / (M * eff_flops)
            else:
                window_micro = window_flops / (M * eff_flops)
            recomp = layer.flops_fwd / eff_flops
            if memory.recompute_cheap and recomp < M * xfer_micro:
                decisions.append(Decision(i, "recompute", bytes_g, 0.0))
                acct.alloc_local(-per_dev_saved[j])
            else:
                stall = M * max(0.0, xfer_micro - window_micro)
                decisions.append(Decision(i, "pool", bytes_g, stall))
                acct.alloc_local(-per_dev_saved[j] * (1.0 - inflight_frac))
                tier.account(acct, bytes_g)
        decisions.sort(key=lambda d: d.layer)
        return decisions, acct

    def attach_checkpoint(report: MemoryPlanReport) -> MemoryPlanReport:
        if checkpoint is None or not getattr(checkpoint, "enabled", False):
            return report
        from repro.core.tiers import build_ckpt_tier
        ct = ckpt_tier or build_ckpt_tier(
            memory, ShardingPlanner(plan), backing=checkpoint.tier,
            codec=checkpoint.codec)
        step_time = dag.total_flops() / eff_flops + report.total_stall()
        if report.pipeline is not None:
            step_time += report.pipeline.total_s
        report.checkpoint = plan_checkpoint(
            model_state_bytes, step_time, ct, plan, chip,
            every=checkpoint.every, async_saves=checkpoint.async_saves,
            mtbf_steps=checkpoint.mtbf_steps)
        return report

    if pipeline is None or not pipeline.enabled:
        decisions, acct = run_pass()
        return attach_checkpoint(
            MemoryPlanReport(decisions, acct.local_bytes,
                             acct.pooled_bytes, acct.budget,
                             tier=tier.describe(),
                             host_bytes=acct.host_bytes))

    # ---- joint n_micro x placement sweep (bubble vs stash stalls) --------
    from repro.parallel.pipeline import get_schedule
    sch = get_schedule(pipeline.schedule)
    S = max(1, pipeline.n_stages)
    step_time = dag.total_flops() / eff_flops
    if pipeline.n_micro > 0:
        candidates = [pipeline.n_micro]
    else:
        # no batch info -> sweep powers-of-two multiples of the stage count
        candidates = sorted({max(1, m)
                             for m in (n_micro_candidates
                                       or [S * 2 ** k for k in range(6)])})
    best = None
    # non-stashing schedules (gpipe): decisions are M-independent — every
    # microbatch activation stays implicitly live, no stage-tier traffic,
    # the whole cost is the bubble.  One pass serves the whole sweep.
    keep_pass = None if sch.stash_saved else run_pass(force_keep=True)
    for M in candidates:
        if sch.stash_saved:
            decisions, acct = run_pass(
                n_micro=M, inflight_frac=sch.inflight(S, M) / M,
                hop_lat=hw.DCN_LATENCY_S)
            stall = sum(d.est_stall_s for d in decisions)
        else:
            decisions, acct = keep_pass
            stall = 0.0
        bubble = sch.bubble_fraction(S, M) * step_time
        wire = 2.0 * ratio * sum(d.saved_bytes for d in decisions
                                 if d.action == "pool")
        verdict = PipelineDecision(pipeline.schedule, S, M, bubble, stall,
                                   act_wire_bytes=wire)
        if best is None or verdict.total_s < best[0].total_s:
            best = (verdict, decisions, acct)
    verdict, decisions, acct = best
    return attach_checkpoint(
        MemoryPlanReport(decisions, acct.local_bytes, acct.pooled_bytes,
                         acct.budget, tier=tier.describe(),
                         host_bytes=acct.host_bytes, pipeline=verdict))


def summarize(report: MemoryPlanReport) -> str:
    s = (f"tier={report.tier} "
         f"keep={report.count('keep')} pool={report.count('pool')} "
         f"recompute={report.count('recompute')} "
         f"resident={report.resident_bytes_per_dev/1e9:.2f}GB "
         f"pooled={report.pooled_bytes_per_dev/1e9:.2f}GB "
         f"budget={report.budget_bytes/1e9:.0f}GB fits={report.fits} "
         f"stall={report.total_stall()*1e3:.2f}ms")
    if report.pipeline is not None:
        p = report.pipeline
        s += (f" pipeline[{p.schedule} S={p.n_stages}] n_micro={p.n_micro} "
              f"bubble={p.bubble_s*1e3:.2f}ms stall={p.stall_s*1e3:.2f}ms "
              f"act_wire={p.act_wire_bytes/1e9:.2f}GB")
    if report.checkpoint is not None:
        c = report.checkpoint
        s += (f" ckpt[{c.tier}] every={c.every} "
              f"snap={c.snapshot_bytes/1e9:.2f}GB save={c.save_s:.2f}s "
              f"overhead={c.overhead_s*1e3:.2f}ms/step "
              f"lost={c.lost_s*1e3:.2f}ms/step"
              f"{' async' if c.async_saves else ''}")
    return s
