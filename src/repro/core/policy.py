"""KEEP / POOL / RECOMPUTE planner — the cost model behind `policy="auto"`.

The paper stashes *every* layer's feature maps (to maximally stress the
interconnect, §IV) and recomputes only cheap layers (footnote 4).  That is
the faithful `policy="mcdla"` mode.  `policy="auto"` is the beyond-paper
mode: a per-layer cost model decides, under the per-device HBM budget,

  KEEP      — leave the saved tensor resident (zero traffic) while the
              budget allows;
  POOL      — stash to the backing tier; predicted stall is
              max(0, stash_time + fetch_time - overlap_window);
  RECOMPUTE — if re-running the layer forward is cheaper than the fetch
              (footnote 4 generalized by the cost model).

Decisions are taken largest-reuse-distance-first: the tensor that stays idle
longest is the best candidate to evict, and its transfer has the widest
overlap window — the same intuition the paper's memory-overlaying scheduler
uses.

The planner costs candidate placements through the
:class:`~repro.core.tiers.MemoryTier` contract — ``tier.bandwidth()`` prices
the transfer, ``tier.account()``/``tier.capacity()`` maintain the boot-time
memory map — so a new tier (host+pool spill, zstd codec, ...) is priced
without touching this module.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro import hw
from repro.configs.base import MemoryPlan, MeshPlan
from repro.core.dag import LayerDAG
from repro.core.pool import PoolAccountant
from repro.core.tiers import MemoryTier, build_tier
from repro.parallel.sharding import ShardingPlanner


@dataclasses.dataclass(frozen=True)
class Decision:
    layer: int
    action: str                  # keep | pool | recompute
    saved_bytes: float           # global bytes affected
    est_stall_s: float           # predicted unhidden transfer time


@dataclasses.dataclass
class MemoryPlanReport:
    decisions: List[Decision]
    resident_bytes_per_dev: float
    pooled_bytes_per_dev: float
    budget_bytes: float
    tier: str = "pooled_hbm"
    host_bytes: float = 0.0

    @property
    def fits(self) -> bool:
        return (self.resident_bytes_per_dev + self.pooled_bytes_per_dev
                <= self.budget_bytes)

    def count(self, action: str) -> int:
        return sum(1 for d in self.decisions if d.action == action)

    def total_stall(self) -> float:
        return sum(d.est_stall_s for d in self.decisions)


def fetch_bandwidth(plan: MeshPlan, memory: MemoryPlan,
                    chip: hw.Chip = hw.TPU_V5E) -> float:
    """Per-device stash/fetch bandwidth of the configured backing tier.

    Deprecated shim: dispatches through the tier registry — use
    ``tier.bandwidth(plan, chip)`` (or ``MemoryRuntime``) directly.
    """
    return build_tier(memory, ShardingPlanner(plan)).bandwidth(plan, chip)


def plan_memory(dag: LayerDAG, plan: MeshPlan, memory: MemoryPlan,
                chip: hw.Chip = hw.TPU_V5E,
                model_state_bytes: float = 0.0,
                tier: Optional[MemoryTier] = None) -> MemoryPlanReport:
    """Run the planner over a layer DAG.

    model_state_bytes: global bytes of params+optimizer state (FSDP-sharded
    over the pool, so they cost /pool_size per device).
    tier: the backing store to cost POOL decisions against; resolved from
    ``memory`` via the tier registry when not provided.
    """
    if tier is None:
        tier = build_tier(memory, ShardingPlanner(plan))
    n_dev = plan.num_devices
    acct = PoolAccountant(plan, memory)
    bw = tier.bandwidth(plan, chip)
    ratio = tier.payload_ratio()
    eff_flops = n_dev * chip.peak_flops

    # state (params + moments) is pooled via FSDP
    state_per_dev = model_state_bytes / (acct.pool_devices
                                         if memory.pool_params else 1)
    acct.alloc_local(state_per_dev)
    decisions: List[Decision] = []

    sched = dag.schedule()
    # largest reuse distance first — best eviction victims
    order = sorted(range(len(sched)), key=lambda j: -sched[j][2])
    stash_all = tier.stash_all and tier.offloads

    # Pass 1: keep everything resident, then evict until it fits (auto), or
    # stash everything (mcdla/host — the paper's stress-test policies).
    per_dev_saved = [b / n_dev for (_, b, _) in sched]
    for b in per_dev_saved:
        acct.alloc_local(b)

    for j in order:
        i, bytes_g, window_flops = sched[j]
        if not stash_all and acct.fits:
            decisions.append(Decision(i, "keep", bytes_g, 0.0))
            continue
        layer = dag.layers[i]
        xfer = 2.0 * (bytes_g * ratio) / (bw * n_dev)     # stash + fetch
        recomp = layer.flops_fwd / eff_flops
        window = window_flops / eff_flops
        if memory.recompute_cheap and recomp < xfer:
            decisions.append(Decision(i, "recompute", bytes_g, 0.0))
            acct.alloc_local(-per_dev_saved[j])
        else:
            stall = max(0.0, xfer - window)
            decisions.append(Decision(i, "pool", bytes_g, stall))
            acct.alloc_local(-per_dev_saved[j])
            tier.account(acct, bytes_g)

    decisions.sort(key=lambda d: d.layer)
    return MemoryPlanReport(decisions, acct.local_bytes, acct.pooled_bytes,
                            acct.budget, tier=tier.describe(),
                            host_bytes=acct.host_bytes)


def summarize(report: MemoryPlanReport) -> str:
    return (f"tier={report.tier} "
            f"keep={report.count('keep')} pool={report.count('pool')} "
            f"recompute={report.count('recompute')} "
            f"resident={report.resident_bytes_per_dev/1e9:.2f}GB "
            f"pooled={report.pooled_bytes_per_dev/1e9:.2f}GB "
            f"budget={report.budget_bytes/1e9:.0f}GB fits={report.fits} "
            f"stall={report.total_stall()*1e3:.2f}ms")
