"""Stash compression — the memory-node's "optional compression ASIC" (§III-A).

The paper's memory-node architecture (Fig. 6) reserves a slot for an ASIC
"that handles encryption or compression".  On TPU the analogue is a fused
quantize-and-pack executed *before* the stash collective, halving (fp8) the
bytes that cross the ICI and that occupy the pool.

This module owns the **codec registry**: every stash codec is a
:class:`Codec` carrying four twins of the same transform —

  ``compress``/``decompress``   pure-jnp per-tensor scale (the default data
                                path and the kernel oracle)
  ``pack``/``unpack``           blockwise Pallas kernel twins
                                (``kernels/offload_pack.py``), plus their
                                pure-jnp references ``pack_ref``/``unpack_ref``
                                (``kernels/ref.py``)

so a consumer (``CompressedTier``, the paged KV spill path, tests) can pick
the granularity/backend it needs and the test suite can assert kernel ≡ ref
for *every* registered codec without naming them.  New codecs are one
:func:`register_codec` call; ``core.tiers`` re-exports the registry for
back-compat.

Also provides int8 error-feedback quantization for compressed gradient
all-reduce (beyond-paper distributed-optimization trick; cf. the paper's
§V-B citation of the Compressing-DMA-Engine work [56] as a traffic
reduction technique).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

FP8_MAX = 448.0                 # float8_e4m3fn dynamic range
INT8_MAX = 127.0


# ---------------------------------------------------------------------------
# fp8 stash compression (per-tensor scale; kernels/offload_pack fuses this)
def fp8_compress(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x -> (fp8 payload, fp32 scale).  Halves stash bytes vs bf16."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(absmax / FP8_MAX, 1e-12)
    q = (x.astype(jnp.float32) / scale).astype(jnp.float8_e4m3fn)
    return q, scale


def fp8_decompress(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# int8 stash compression (per-tensor scale; kernels/offload_pack has the
# blockwise Pallas twin) — registered as a stash codec below
def int8_compress(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x -> (int8 payload, fp32 scale).  Halves stash bytes vs bf16."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(absmax / INT8_MAX, 1e-30)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# block-sparse stash compression: int8 quantization + magnitude pruning.
# Entries below absmax/BLOCKSPARSE_TAU become EXACT zeros, so the payload
# is dense-shaped but zero-run-rich — what a wire-side run-length/entropy
# stage (the memory node's compression ASIC slot, §III-A) feeds on.
# Decode needs no sparsity metadata: zeros dequantize to zero.
# Must equal kernels/offload_pack.BLOCKSPARSE_TAU (mirrored here, like
# FP8_MAX/INT8_MAX, to keep pallas out of core's import path; the codec
# tests pin the two constants together).
BLOCKSPARSE_TAU = 32.0


def blocksparse_compress(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x -> (magnitude-pruned int8 payload, fp32 scale)."""
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(absmax / INT8_MAX, 1e-30)
    q = jnp.clip(jnp.round(xf / scale), -INT8_MAX, INT8_MAX)
    keep = jnp.abs(xf) >= absmax / BLOCKSPARSE_TAU
    return jnp.where(keep, q, 0.0).astype(jnp.int8), scale


#: pruned zeros dequantize to zero — decode IS the int8 decode
blocksparse_decompress = int8_decompress


# ---------------------------------------------------------------------------
# int8 error-feedback gradient compression
def int8_ef_quantize(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize gradient+carried error to int8 with a per-tensor scale.

    Returns (int8 payload, scale, new_error).  The residual (quantization
    error) is fed back into the next step — guarantees convergence of the
    compressed all-reduce (error-feedback SGD).
    """
    corrected = g.astype(jnp.float32) + err
    absmax = jnp.max(jnp.abs(corrected))
    scale = jnp.maximum(absmax / INT8_MAX, 1e-30)
    q = jnp.clip(jnp.round(corrected / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    new_err = corrected - q.astype(jnp.float32) * scale
    return q, scale, new_err


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# codec registry — the memory-node's "optional compression ASIC" (§III-A)
@dataclasses.dataclass(frozen=True)
class Codec:
    """One stash codec: the ref transform plus its optional kernel twins.

    ``pack``/``unpack`` take ``(x_2d, *, block_rows, interpret)`` /
    ``(q_2d, scales, *, block_rows, dtype, interpret)`` — the
    kernels/offload_pack signature; ``pack_ref``/``unpack_ref`` are the
    pure-jnp blockwise twins the tests assert against.  Codecs without a
    kernel twin leave them ``None``.
    """

    name: str
    ratio: float                                   # stashed bytes per raw byte
    compress: Callable[[jax.Array], Tuple[jax.Array, jax.Array]]
    decompress: Callable[..., jax.Array]           # (q, scale, dtype) -> x
    pack: Optional[Callable[..., Tuple[jax.Array, jax.Array]]] = None
    unpack: Optional[Callable[..., jax.Array]] = None
    pack_ref: Optional[Callable[..., Tuple[jax.Array, jax.Array]]] = None
    unpack_ref: Optional[Callable[..., jax.Array]] = None

    def applies_to(self, x: jax.Array) -> bool:
        return jnp.issubdtype(x.dtype, jnp.floating)

    @property
    def has_kernel(self) -> bool:
        return self.pack is not None and self.unpack is not None


_CODECS: Dict[str, Codec] = {}


def register_codec(codec: Codec) -> None:
    _CODECS[codec.name] = codec


def get_codec(name: str) -> Codec:
    if name not in _CODECS:
        raise KeyError(f"unknown stash codec {name!r}; "
                       f"registered: {sorted(_CODECS)}")
    return _CODECS[name]


def registered_codecs() -> Tuple[str, ...]:
    return tuple(sorted(_CODECS))


def _register_builtin_codecs() -> None:
    # runs at import time; the function only keeps the module namespace
    # clean.  Pulling in repro.kernels here is free of new dependencies —
    # pallas ships inside jax — and pallas code is only *executed* when a
    # kernel twin is actually called.
    from repro.kernels import offload_pack as kp
    from repro.kernels import ref as kref
    register_codec(Codec("fp8", 0.5, fp8_compress, fp8_decompress,
                         pack=kp.fp8_pack, unpack=kp.fp8_unpack,
                         pack_ref=kref.fp8_pack_ref,
                         unpack_ref=kref.fp8_unpack_ref))
    register_codec(Codec("int8", 0.5, int8_compress, int8_decompress,
                         pack=kp.int8_pack, unpack=kp.int8_unpack,
                         pack_ref=kref.int8_pack_ref,
                         unpack_ref=kref.int8_unpack_ref))
    register_codec(Codec("blocksparse", 0.5,
                         blocksparse_compress, blocksparse_decompress,
                         pack=kp.blocksparse_pack,
                         unpack=kp.blocksparse_unpack,
                         pack_ref=kref.blocksparse_pack_ref,
                         unpack_ref=kref.blocksparse_unpack_ref))


_register_builtin_codecs()


# ---------------------------------------------------------------------------
# whole-tensor encode/decode through a codec — the per-page spill path.
# kernel=True routes through the Pallas twin as ONE block (page-granular
# scale, bit-identical to the ref per-tensor path by construction).
def encode_tensor(codec: Codec, x: jax.Array, *, kernel: bool = False,
                  interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Quantize ``x`` (any shape) with one per-tensor scale.

    Returns ``(q, scale)`` with ``q.shape == x.shape``.  ``kernel=True``
    uses the codec's Pallas pack twin on the flattened 2D view.
    """
    if kernel and codec.has_kernel:
        x2 = x.reshape(-1, x.shape[-1])
        q2, scales = codec.pack(x2, block_rows=x2.shape[0],
                                interpret=interpret)
        return q2.reshape(x.shape), scales[0]
    return codec.compress(x)


def decode_tensor(codec: Codec, q: jax.Array, scale: jax.Array,
                  dtype=jnp.bfloat16, *, kernel: bool = False,
                  interpret: bool = True) -> jax.Array:
    if kernel and codec.has_kernel:
        q2 = q.reshape(-1, q.shape[-1])
        x2 = codec.unpack(q2, scale.reshape(1), block_rows=q2.shape[0],
                          dtype=dtype, interpret=interpret)
        return x2.reshape(q.shape)
    return codec.decompress(q, scale, dtype)


def compress_ratio(kind: str) -> float:
    """Bytes multiplier vs bf16 (used by the cost model and the simulator)."""
    if kind == "none":
        return 1.0
    return get_codec(kind).ratio
