"""Stash compression — the memory-node's "optional compression ASIC" (§III-A).

The paper's memory-node architecture (Fig. 6) reserves a slot for an ASIC
"that handles encryption or compression".  On TPU the analogue is a fused
quantize-and-pack executed *before* the stash collective, halving (fp8) the
bytes that cross the ICI and that occupy the pool.  The Pallas kernel twin
lives in ``kernels/offload_pack.py``; this module is the pure-jnp
implementation used as the default path and as the kernel oracle.

Also provides int8 error-feedback quantization for compressed gradient
all-reduce (beyond-paper distributed-optimization trick; cf. the paper's
§V-B citation of the Compressing-DMA-Engine work [56] as a traffic
reduction technique).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

FP8_MAX = 448.0                 # float8_e4m3fn dynamic range
INT8_MAX = 127.0


# ---------------------------------------------------------------------------
# fp8 stash compression (per-tensor scale; kernels/offload_pack fuses this)
def fp8_compress(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x -> (fp8 payload, fp32 scale).  Halves stash bytes vs bf16."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(absmax / FP8_MAX, 1e-12)
    q = (x.astype(jnp.float32) / scale).astype(jnp.float8_e4m3fn)
    return q, scale


def fp8_decompress(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# int8 stash compression (per-tensor scale; kernels/offload_pack has the
# blockwise Pallas twin) — registered as a stash codec in core.tiers
def int8_compress(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x -> (int8 payload, fp32 scale).  Halves stash bytes vs bf16."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(absmax / INT8_MAX, 1e-30)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# int8 error-feedback gradient compression
def int8_ef_quantize(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize gradient+carried error to int8 with a per-tensor scale.

    Returns (int8 payload, scale, new_error).  The residual (quantization
    error) is fed back into the next step — guarantees convergence of the
    compressed all-reduce (error-feedback SGD).
    """
    corrected = g.astype(jnp.float32) + err
    absmax = jnp.max(jnp.abs(corrected))
    scale = jnp.maximum(absmax / INT8_MAX, 1e-30)
    q = jnp.clip(jnp.round(corrected / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    new_err = corrected - q.astype(jnp.float32) * scale
    return q, scale, new_err


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_ratio(kind: str) -> float:
    """Bytes multiplier vs bf16 (used by the cost model and the simulator)."""
    return {"none": 1.0, "fp8": 0.5, "int8": 0.5}[kind]
