"""The paper's primary contribution: transparent memory-capacity expansion
over the device-side interconnect (MC-DLA), realised in JAX.

* pool      — the pooled-HBM tier + BW_AWARE/LOCAL placement (Fig. 10)
* offload   — stash/fetch memory-overlaying as custom_vjp autodiff surgery
* dag       — layer DAG + reuse-distance schedule (§II-B)
* policy    — KEEP/POOL/RECOMPUTE cost-model planner (footnote 4 + auto)
* vdnn      — policy-driven layer wrapper used by all model code
* compress  — fp8 stash / int8 error-feedback grads (the memory-node 'ASIC')
"""
from repro.core.compress import (fp8_compress, fp8_decompress,
                                 int8_ef_quantize, int8_dequantize)
from repro.core.dag import LayerDAG, LayerNode, build_dag, model_flops
from repro.core.offload import maybe_offload, offload_layer, stash, fetch
from repro.core.policy import plan_memory, fetch_bandwidth, summarize
from repro.core.pool import PoolAxes, PoolAccountant, pool_spec, pool_report
from repro.core.vdnn import VdnnContext, stash_fraction, split_layers

__all__ = [
    "fp8_compress", "fp8_decompress", "int8_ef_quantize", "int8_dequantize",
    "LayerDAG", "LayerNode", "build_dag", "model_flops",
    "maybe_offload", "offload_layer", "stash", "fetch",
    "plan_memory", "fetch_bandwidth", "summarize",
    "PoolAxes", "PoolAccountant", "pool_spec", "pool_report",
    "VdnnContext", "stash_fraction", "split_layers",
]
