"""The paper's primary contribution: transparent memory-capacity expansion
over the device-side interconnect (MC-DLA), realised in JAX.

* tiers     — pluggable MemoryTier backing stores (device / pooled / host /
              compressed) behind one registry (DESIGN.md §3)
* runtime   — MemoryRuntime facade: planner + mesh + tier + wrap_layer +
              per-call traffic accounting
* pool      — the pooled-HBM placement helpers (BW_AWARE/LOCAL, Fig. 10)
* offload   — deprecated stash/fetch shims over the tier API
* dag       — layer DAG + reuse-distance schedule (§II-B)
* policy    — KEEP/POOL/RECOMPUTE cost-model planner (footnote 4 + auto),
              priced through the tier contract
* vdnn      — deprecated wrapper shim over MemoryRuntime
* compress  — fp8 stash / int8 error-feedback grads (the memory-node 'ASIC')
"""
from repro.core.compress import (fp8_compress, fp8_decompress,
                                 int8_ef_quantize, int8_dequantize)
from repro.core.dag import LayerDAG, LayerNode, build_dag, model_flops
from repro.core.offload import maybe_offload, offload_layer, stash, fetch
from repro.core.policy import (PipelineDecision, fetch_bandwidth,
                               micro_candidates, plan_memory, summarize)
from repro.core.pool import PoolAxes, PoolAccountant, pool_spec, pool_report
from repro.core.runtime import MemoryRuntime, TierTraffic
from repro.core.tiers import (Codec, CompressedTier, DeviceTier, HostTier,
                              MemoryTier, PipelineStageTier, PooledHbmTier,
                              TierSpec, TransferHints, build_stage_tier,
                              build_tier, get_codec, register_codec,
                              register_tier, registered_policies)
from repro.core.vdnn import VdnnContext, stash_fraction, split_layers

__all__ = [
    "fp8_compress", "fp8_decompress", "int8_ef_quantize", "int8_dequantize",
    "LayerDAG", "LayerNode", "build_dag", "model_flops",
    "maybe_offload", "offload_layer", "stash", "fetch",
    "PipelineDecision", "plan_memory", "fetch_bandwidth", "micro_candidates",
    "summarize",
    "PoolAxes", "PoolAccountant", "pool_spec", "pool_report",
    "MemoryRuntime", "TierTraffic",
    "Codec", "CompressedTier", "DeviceTier", "HostTier", "MemoryTier",
    "PipelineStageTier", "PooledHbmTier", "TierSpec", "TransferHints",
    "build_stage_tier", "build_tier", "get_codec",
    "register_codec", "register_tier", "registered_policies",
    "VdnnContext", "stash_fraction", "split_layers",
]
