"""stash/fetch — deprecated shims over the MemoryTier / MemoryRuntime API.

The memory-overlaying machinery that used to live here (custom_vjp autodiff
surgery around each layer, §III-B) moved to
:class:`repro.core.runtime.MemoryRuntime`, and the per-backing-store data
paths moved to :mod:`repro.core.tiers`.  These wrappers keep the historical
signatures alive for examples and external callers; new code should build a
``MemoryRuntime`` once and call ``wrap_layer`` on it.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import MemoryPlan
from repro.core.runtime import MemoryRuntime
from repro.core.tiers import TransferHints, build_tier
from repro.parallel.sharding import ShardingPlanner


def _runtime(planner: ShardingPlanner, mesh: Optional[Mesh],
             memory: MemoryPlan) -> MemoryRuntime:
    return MemoryRuntime(planner.plan, memory, mesh, planner=planner)


# one tier per (memory, planner, mesh) triple — a paired stash/fetch must
# see the same tier instance, and per-traced-call construction is waste
_TIER_CACHE: dict = {}


def _tier(planner: ShardingPlanner, mesh: Optional[Mesh],
          memory: MemoryPlan):
    key = (memory, id(planner), id(mesh))
    if key not in _TIER_CACHE:
        _TIER_CACHE[key] = build_tier(memory, planner, mesh)
    return _TIER_CACHE[key]


# ---------------------------------------------------------------------------
def stash(x: jax.Array, planner: ShardingPlanner, mesh: Optional[Mesh],
          memory: MemoryPlan, batch_dim: int = 0, allow_compress: bool = True):
    """Deprecated: copy-out to the configured tier.  Returns an opaque
    payload.  Use ``MemoryRuntime.stash`` / ``tier.stash`` instead."""
    return _tier(planner, mesh, memory).stash(
        x, TransferHints(batch_dim=batch_dim, allow_compress=allow_compress))


def fetch(payload: Tuple[jax.Array, Optional[jax.Array]],
          planner: ShardingPlanner, mesh: Optional[Mesh], memory: MemoryPlan,
          compute_spec, dtype) -> jax.Array:
    """Deprecated: prefetch back from the configured tier.  Use
    ``MemoryRuntime.fetch`` / ``tier.fetch`` instead."""
    return _tier(planner, mesh, memory).fetch(
        payload, TransferHints(compute_spec=compute_spec, dtype=dtype))


# ---------------------------------------------------------------------------
def offload_layer(layer_fn: Callable, planner: ShardingPlanner,
                  mesh: Optional[Mesh], memory: MemoryPlan,
                  compute_spec: Optional[P] = None,
                  batch_dim: int = 0) -> Callable:
    """Deprecated: wrap ``layer_fn(params, x, *aux) -> y`` so the
    saved-for-backward copy of ``x`` lives in the configured tier.
    Delegates to ``MemoryRuntime.wrap_layer``."""
    return _runtime(planner, mesh, memory).wrap_layer(
        layer_fn, compute_spec=compute_spec, batch_dim=batch_dim)


def maybe_offload(layer_fn: Callable, planner: ShardingPlanner,
                  mesh: Optional[Mesh], memory: MemoryPlan,
                  compute_spec: Optional[P] = None,
                  batch_dim: int = 0) -> Callable:
    """Deprecated: policy dispatch now lives in the tier registry — a
    non-offloading tier (``policy='none'``) returns the plain layer."""
    return _runtime(planner, mesh, memory).wrap_layer(
        layer_fn, compute_spec=compute_spec, batch_dim=batch_dim)
