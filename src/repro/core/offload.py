"""stash/fetch — the paper's memory-overlaying runtime, as autodiff surgery.

The paper's vDNN-style runtime (§III-B) pushes each layer's input feature
map to the backing store after its last forward use and prefetches it ahead
of its backward use, overlapped with compute.  In JAX the "saved for
backward" set *is* the residual set of autodiff, so the mechanism becomes a
``jax.custom_vjp`` around the layer:

  forward:  y = layer(params, x)            (compute uses the exact x)
            stash = compress(pool(x))       (copy-out to the pooled tier)
  residual: (params, stash, aux)            (x itself is NOT saved)
  backward: x' = fetch(decompress(stash))   (all-gather over ICI)
            recompute layer vjp from x'

This is bit-faithful to the paper: the device-local copy is used for the
forward math, the pooled copy is a DMA'd duplicate, cheap intermediates are
re-computed during backward (footnote 4) because the vjp recomputes the
layer body from x'.  Under ``jax.lax.scan`` over layers, XLA's latency
hiding scheduler overlaps the stash collective of layer *i* with the compute
of layer *i+1* — the TPU analogue of the paper's DMA/compute overlap.

``host`` policy (the DC-DLA baseline) keeps the same structure but moves the
stash to host memory via ``jax.device_put(..., TransferToMemoryKind)`` where
the backend supports it (TPU does; the CPU test backend silently no-ops, and
the DC/HC/MC comparison is reproduced in ``sim/``).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import MemoryPlan
from repro.core import compress as comp
from repro.core.pool import pool_spec
from repro.parallel.sharding import ShardingPlanner


def _constrain(x: jax.Array, mesh: Optional[Mesh], spec: P) -> jax.Array:
    if mesh is None or mesh.size <= 1:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _to_host(x: jax.Array) -> jax.Array:
    """Move to host memory space (TPU pinned_host); no-op if unsupported."""
    try:
        from jax._src.sharding_impls import TransferToMemoryKind  # noqa
        return jax.device_put(x, TransferToMemoryKind("pinned_host"))
    except Exception:
        return x


def _from_host(x: jax.Array) -> jax.Array:
    try:
        from jax._src.sharding_impls import TransferToMemoryKind  # noqa
        return jax.device_put(x, TransferToMemoryKind("device"))
    except Exception:
        return x


# ---------------------------------------------------------------------------
def stash(x: jax.Array, planner: ShardingPlanner, mesh: Optional[Mesh],
          memory: MemoryPlan, batch_dim: int = 0, allow_compress: bool = True):
    """Copy-out to the backing store.  Returns an opaque stash payload."""
    if memory.policy == "host":
        payload = _to_host(x)
        return (payload, None)
    if allow_compress and memory.compress == "fp8" and \
            jnp.issubdtype(x.dtype, jnp.floating):
        q, scale = comp.fp8_compress(x)
        spec = pool_spec(q.shape, planner, memory.placement, batch_dim)
        return (_constrain(q, mesh, spec), scale)
    spec = pool_spec(x.shape, planner, memory.placement, batch_dim)
    return (_constrain(x, mesh, spec), None)


def fetch(payload: Tuple[jax.Array, Optional[jax.Array]],
          planner: ShardingPlanner, mesh: Optional[Mesh], memory: MemoryPlan,
          compute_spec, dtype) -> jax.Array:
    """Prefetch back from the backing store (all-gather over the pool).

    compute_spec: a PartitionSpec, a callable shape->PartitionSpec, or None.
    """
    q, scale = payload
    if memory.policy == "host":
        return _from_host(q)
    if scale is not None:
        x = comp.fp8_decompress(q, scale, dtype)
    else:
        x = q
    if compute_spec is not None:
        spec = compute_spec(x.shape) if callable(compute_spec) else compute_spec
        x = _constrain(x, mesh, spec)
    return x


# ---------------------------------------------------------------------------
def _split_aux(aux: Sequence[Any]):
    """Partition aux leaves into differentiable / non-differentiable."""
    flags = tuple(
        isinstance(a, (jax.Array, jnp.ndarray)) and
        jnp.issubdtype(jnp.result_type(a), jnp.inexact)
        for a in aux)
    return flags


def offload_layer(layer_fn: Callable, planner: ShardingPlanner,
                  mesh: Optional[Mesh], memory: MemoryPlan,
                  compute_spec: Optional[P] = None,
                  batch_dim: int = 0) -> Callable:
    """Wrap ``layer_fn(params, x, *aux) -> y`` so the saved-for-backward copy
    of ``x`` lives in the pooled tier (possibly fp8-compressed).

    * params and aux are saved by reference (params are live anyway under the
      optimizer; aux are small: positions, cache indices, ...).
    * float aux receive real cotangents (e.g. encoder states feeding
      cross-attention); integer aux receive None.
    """

    AUX_STASH_NDIM = 3      # big float aux (e.g. encoder states) pool too

    @jax.custom_vjp
    def f(params, x, *aux):
        return layer_fn(params, x, *aux)

    def f_fwd(params, x, *aux):
        y = layer_fn(params, x, *aux)
        payload = stash(x, planner, mesh, memory, batch_dim)
        witness = jnp.zeros((), x.dtype)        # dtype token (residuals must
        flags = _split_aux(aux)                 # be JAX types)
        saved_aux = tuple(
            stash(a, planner, mesh, memory, batch_dim, allow_compress=False)
            if (memory.stash_aux and fl and
                getattr(a, "ndim", 0) >= AUX_STASH_NDIM) else a
            for a, fl in zip(aux, flags))
        return y, (params, payload, witness, saved_aux)

    def f_bwd(res, gy):
        params, payload, witness, saved_aux = res
        x = fetch(payload, planner, mesh, memory, compute_spec, witness.dtype)
        aux = tuple(
            fetch(sa, planner, mesh, memory, compute_spec, None)
            if isinstance(sa, tuple) else sa
            for sa in saved_aux)
        flags = _split_aux(aux)
        diff_aux = tuple(a for a, fl in zip(aux, flags) if fl)

        def call(p, xx, *da):
            it = iter(da)
            full = tuple(next(it) if fl else a for a, fl in zip(aux, flags))
            return layer_fn(p, xx, *full)

        _, vjp = jax.vjp(call, params, x, *diff_aux)
        grads = vjp(gy)
        dp, dx, d_diff = grads[0], grads[1], list(grads[2:])
        if compute_spec is not None:
            # constrain the residual-stream cotangent to the same layout as
            # the primal: GSPMD can then turn the TP backward all-reduces
            # into reduce-scatters (Megatron-SP transposition; §Perf)
            spec = compute_spec(dx.shape) if callable(compute_spec) \
                else compute_spec
            dx = _constrain(dx, mesh, spec)
        d_aux = tuple(d_diff.pop(0) if fl else None for fl in flags)
        return (dp, dx) + d_aux

    f.defvjp(f_fwd, f_bwd)
    return f


def maybe_offload(layer_fn: Callable, planner: ShardingPlanner,
                  mesh: Optional[Mesh], memory: MemoryPlan,
                  compute_spec: Optional[P] = None,
                  batch_dim: int = 0) -> Callable:
    """Policy dispatch: 'none' -> plain layer (oracle DC-DLA(O));
    'mcdla'/'auto'/'host' -> offload-wrapped layer."""
    if memory.policy == "none":
        return layer_fn
    return offload_layer(layer_fn, planner, mesh, memory, compute_spec,
                         batch_dim)
