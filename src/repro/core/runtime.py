"""``MemoryRuntime`` — the single facade over the memory-tier machinery.

The paper's runtime (§III-B) is one object: it knows the mesh, the backing
store, and the stash/prefetch schedule, and the model simply runs layers.
This module is that object for the repro.  Built once from
``(MeshPlan, MemoryPlan)``, it owns the sharding planner, the mesh handle
and the :class:`~repro.core.tiers.MemoryTier` stack, and exposes the one
``wrap_layer`` entry point the rest of the codebase uses:

  forward:  y = layer(params, x)            (compute uses the exact x)
            payload = tier.stash(x)         (copy-out to the backing store)
  residual: (params, payload, aux)          (x itself is NOT saved)
  backward: x' = tier.fetch(payload)        (prefetch ahead of use)
            recompute layer vjp from x'

Under ``jax.lax.scan`` over layers, XLA's latency-hiding scheduler overlaps
the stash collective of layer *i* with the compute of layer *i+1* — the TPU
analogue of the paper's DMA/compute overlap.  Cheap intermediates are
recomputed in backward (footnote 4) because the vjp re-runs the layer body.

Every stash/fetch is metered at trace time: :meth:`traffic_report` gives
per-tier logical and wire bytes plus an estimated transfer time against the
tier's bandwidth contract — surfaced by ``launch/dryrun.py`` next to XLA's
``memory_analysis()`` numbers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import hw
from repro.configs.base import MemoryPlan, MeshPlan
from repro.core import policy as policy_mod
from repro.core.dag import LayerDAG, build_dag
from repro.core.tiers import MemoryTier, TransferHints, build_tier
from repro.parallel.sharding import ShardingPlanner

# big float aux (e.g. encoder states feeding cross-attention) pool too
AUX_STASH_NDIM = 3


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TierTraffic:
    """Trace-time transfer meter for one direction through the tier.

    Counts are per *traced* call: a layer wrapped inside ``jax.lax.scan``
    traces its body once, so multiply by the trip count (the dry-run's
    group count) for whole-step totals.
    """

    calls: int = 0
    raw_bytes: float = 0.0        # tensor bytes before compression
    wire_bytes: float = 0.0       # bytes that actually cross the interconnect

    def add(self, raw: float, wire: float) -> None:
        self.calls += 1
        self.raw_bytes += raw
        self.wire_bytes += wire


class MemoryRuntime:
    """Facade: planner + mesh + tier + per-call accounting.

    Everything the old call sites hand-threaded — ``(planner, mesh, memory,
    compute_spec, batch_dim)`` — lives here; model code asks for
    ``wrap_layer`` and nothing else.
    """

    def __init__(self, plan: MeshPlan, memory: MemoryPlan,
                 mesh: Optional[Mesh] = None,
                 planner: Optional[ShardingPlanner] = None,
                 chip: hw.Chip = hw.TPU_V5E,
                 tier: Optional[MemoryTier] = None):
        self.plan = plan
        self.memory = memory
        self.mesh = mesh
        self.chip = chip
        self.planner = planner if planner is not None else ShardingPlanner(plan)
        # ``tier`` overrides the registry resolution — used for runtimes
        # whose tier is built out-of-band (the pipeline stage runtime wraps
        # the configured backing store in a PipelineStageTier).
        self.tier: MemoryTier = tier if tier is not None \
            else build_tier(memory, self.planner, mesh)
        self._traffic: Dict[str, TierTraffic] = {}

    # ------------------------------------------------------------------
    # traits
    @property
    def offloads(self) -> bool:
        """Whether wrapped layers actually move their saved tensors."""
        return self.tier.offloads

    def describe(self) -> str:
        return (f"runtime[tier={self.tier.describe()} "
                f"mesh={'x'.join(map(str, self.plan.shape))}]")

    # ------------------------------------------------------------------
    # layout defaults
    def residual_spec(self, name: str = "resid") -> Callable[[Sequence[int]], P]:
        """Shape-aware compute layout of the residual stream: batch axes on
        dim 0, sequence-parallel dim 1 over the tensor axes when enabled."""

        def spec(shape):
            roles: list = [self.planner.axes.batch] + [None] * (len(shape) - 1)
            if self.memory.seq_parallel and len(shape) >= 3:
                roles[1] = self.planner.axes.tensor
            return self.planner.spec(shape, roles, name=name)

        return spec

    def _aux_spec(self, compute_spec, shape) -> Optional[P]:
        """Layout for a fetched *aux* tensor.

        Aux tensors (encoder states, caches, ...) generally differ in
        rank/shape from the residual stream, so a static residual
        ``compute_spec`` must NOT be applied to them — derive a layout from
        the planner instead (shape-aware callables already do)."""
        if callable(compute_spec):
            return compute_spec(shape)
        roles = [self.planner.axes.batch] + [None] * (len(shape) - 1)
        return self.planner.spec(shape, roles, name="aux_fetch")

    # ------------------------------------------------------------------
    # accounting
    def _meter(self, direction: str, x: jax.Array,
               hints: Optional[TransferHints] = None) -> None:
        raw = float(x.size) * jnp.dtype(x.dtype).itemsize
        wire = raw * self.tier.wire_ratio(x, hints or TransferHints())
        self._traffic.setdefault(direction, TierTraffic()).add(raw, wire)

    def meter_transfer(self, direction: str, raw_bytes: float,
                       wire_bytes: float, calls: int = 1) -> None:
        """Account an out-of-band transfer in this runtime's report.

        ``stash``/``fetch`` meter tier traffic implicitly; transfers that
        bypass the tier stack — e.g. serialized wire frames in
        serve/transport.py, metered as ``kv_wire`` with the exact frame
        byte count — record themselves here so ``traffic_report()`` stays
        the single reconciliation point for every byte that moved."""
        t = self._traffic.setdefault(direction, TierTraffic())
        t.calls += calls
        t.raw_bytes += raw_bytes
        t.wire_bytes += wire_bytes

    def reset_traffic(self) -> None:
        self._traffic = {}

    def traffic_report(self) -> Dict[str, Any]:
        """Per-tier byte/stall accounting of every metered stash/fetch."""
        bw = self.tier.bandwidth(self.plan, self.chip)
        n_dev = max(self.plan.num_devices, 1)
        report: Dict[str, Any] = {
            "tier": self.tier.describe(),
            "bandwidth_per_dev": bw,
        }
        total_wire = 0.0
        for direction, t in sorted(self._traffic.items()):
            report[direction] = {
                "calls": t.calls, "raw_bytes": t.raw_bytes,
                "wire_bytes": t.wire_bytes,
            }
            total_wire += t.wire_bytes
        report["wire_bytes_total"] = total_wire
        # global bytes stream through n_dev links in parallel
        report["est_transfer_s"] = (total_wire / (bw * n_dev)
                                    if bw > 0 and total_wire else 0.0)
        return report

    def traffic_summary(self) -> str:
        r = self.traffic_report()
        per = {d: f"{fmt_bytes(v['wire_bytes'])}/{v['calls']}x"
               for d, v in r.items() if isinstance(v, dict)}
        return (f"tier={r['tier']} wire={fmt_bytes(r['wire_bytes_total'])} "
                f"est_transfer={r['est_transfer_s']*1e3:.2f}ms {per}")

    # ------------------------------------------------------------------
    # data path (metered tier passthrough).  ``direction`` labels the
    # traffic-report bucket: training residuals use the default
    # "stash"/"fetch", the serving KVCacheManager meters its cold-slot
    # traffic as "kv_stash"/"kv_fetch" so a report tells the two apart.
    def stash(self, x: jax.Array, hints: Optional[TransferHints] = None,
              direction: str = "stash"):
        hints = hints or TransferHints()
        if self.offloads:
            self._meter(direction, x, hints)
        return self.tier.stash(x, hints)

    def fetch(self, payload, hints: Optional[TransferHints] = None,
              direction: str = "fetch"):
        hints = hints or TransferHints()
        x = self.tier.fetch(payload, hints)
        if self.offloads:
            self._meter(direction, x, hints)
        return x

    # ------------------------------------------------------------------
    # snapshots (checkpoint-as-a-tier).  Unlike stash/fetch these meter the
    # *actual* payload bytes — the manifest the CheckpointManager commits
    # accounts the same bytes, so `traffic_report["ckpt_save"]` is checkable
    # against on-disk truth for any codec stack.
    def _payload_bytes(self, payload) -> float:
        return sum(float(leaf.size) * jnp.dtype(leaf.dtype).itemsize
                   for leaf in jax.tree_util.tree_leaves(payload)
                   if hasattr(leaf, "size"))

    def snapshot(self, x: jax.Array, hints: Optional[TransferHints] = None,
                 direction: str = "ckpt_save"):
        """Stash one snapshot leaf through the tier, metered ``ckpt_save``."""
        hints = hints or TransferHints()
        payload = self.tier.stash(x, hints)
        raw = float(x.size) * jnp.dtype(x.dtype).itemsize
        self._traffic.setdefault(direction, TierTraffic()).add(
            raw, self._payload_bytes(payload))
        return payload

    def restore_snapshot(self, payload,
                         hints: Optional[TransferHints] = None,
                         direction: str = "ckpt_load") -> jax.Array:
        """Fetch one snapshot leaf back, metered ``ckpt_load``."""
        hints = hints or TransferHints()
        wire = self._payload_bytes(payload)
        x = self.tier.fetch(payload, hints)
        raw = float(x.size) * jnp.dtype(x.dtype).itemsize
        self._traffic.setdefault(direction, TierTraffic()).add(raw, wire)
        return x

    def discard(self, payload) -> None:
        """Release a parked payload's capacity-contract charge.

        Serving paths (cold-KV slots, spilled pages, disaggregated KV
        handoffs) park payloads in the tier and drop them out of band; a
        :class:`~repro.core.tiers.SpillTier` leg in the stack gets its
        budget back here.  No-op for tiers without a byte ledger."""
        from repro.core.tiers import SpillTier
        tier = self.tier
        while tier is not None:
            if isinstance(tier, SpillTier):
                tier.discard(payload)
                return
            tier = getattr(tier, "inner", None)

    # ------------------------------------------------------------------
    # the one wrapper
    def wrap_layer(self, layer_fn: Callable,
                   compute_spec: Optional[object] = "auto",
                   batch_dim: int = 0,
                   name: str = "layer") -> Callable:
        """Wrap ``layer_fn(params, x, *aux) -> y`` so the saved-for-backward
        copy of ``x`` lives in this runtime's tier.

        * ``compute_spec``: the layout to restore on fetch — a static
          PartitionSpec, a shape-aware callable, None, or the default
          ``"auto"`` (the residual-stream layout for this memory plan).
        * params and small aux are saved by reference; float aux with
          ndim >= 3 are stashed too (uncompressed — they must round-trip
          bit-exactly for the cotangent path).
        """
        if not self.offloads:
            return layer_fn
        if compute_spec == "auto":
            compute_spec = self.residual_spec(name)
        tier = self.tier
        runtime = self

        def hints_for(dtype=None, allow_compress=True) -> TransferHints:
            return TransferHints(compute_spec=compute_spec,
                                 batch_dim=batch_dim, dtype=dtype,
                                 allow_compress=allow_compress, name=name)

        @jax.custom_vjp
        def f(params, x, *aux):
            return layer_fn(params, x, *aux)

        def f_fwd(params, x, *aux):
            y = layer_fn(params, x, *aux)
            payload = runtime.stash(x, hints_for())
            witness = jnp.zeros((), x.dtype)    # dtype token (residuals must
            flags = _split_aux(aux)             # be JAX types)
            saved_aux = []
            for a, fl in zip(aux, flags):
                if (runtime.memory.stash_aux and fl
                        and getattr(a, "ndim", 0) >= AUX_STASH_NDIM):
                    saved_aux.append(runtime.stash(
                        a, hints_for(allow_compress=False)))
                else:
                    saved_aux.append(a)
            return y, (params, payload, witness, tuple(saved_aux))

        def f_bwd(res, gy):
            params, payload, witness, saved_aux = res
            x = runtime.fetch(payload, hints_for(dtype=witness.dtype))
            aux = []
            for sa in saved_aux:
                if isinstance(sa, tuple):
                    # aux tensors differ in rank/shape from the residual —
                    # they derive their own fetch layout (never the static
                    # residual compute_spec).  The payload's first array
                    # leaf carries the stashed shape (tier payloads may
                    # wrap it, e.g. SpillTier's leg-routing node).
                    shape = jax.tree_util.tree_leaves(sa)[0].shape
                    aux.append(runtime.fetch(sa, TransferHints(
                        compute_spec=runtime._aux_spec(compute_spec, shape),
                        batch_dim=batch_dim, dtype=witness.dtype,
                        allow_compress=False, name=f"{name}_aux")))
                else:
                    aux.append(sa)
            aux = tuple(aux)
            flags = _split_aux(aux)
            diff_aux = tuple(a for a, fl in zip(aux, flags) if fl)

            def call(p, xx, *da):
                it = iter(da)
                full = tuple(next(it) if fl else a
                             for a, fl in zip(aux, flags))
                return layer_fn(p, xx, *full)

            _, vjp = jax.vjp(call, params, x, *diff_aux)
            grads = vjp(gy)
            dp, dx, d_diff = grads[0], grads[1], list(grads[2:])
            if compute_spec is not None:
                # constrain the residual-stream cotangent to the same layout
                # as the primal: GSPMD can then turn the TP backward
                # all-reduces into reduce-scatters (Megatron-SP; §Perf)
                spec = compute_spec(dx.shape) if callable(compute_spec) \
                    else compute_spec
                dx = tier._constrain(dx, spec)
            d_aux = tuple(d_diff.pop(0) if fl else None for fl in flags)
            return (dp, dx) + d_aux

        f.defvjp(f_fwd, f_bwd)
        return f

    # ------------------------------------------------------------------
    # pipeline stages: whole stage-input pytrees through the stage tier
    def wrap_stage(self, stage_fn: Callable, name: str = "stage") -> Callable:
        """Wrap ``stage_fn(params, tree) -> tree`` so every float leaf of
        the input tree is saved-for-backward through this runtime's tier.

        The pipeline-schedule analogue of :meth:`wrap_layer`: a 1F1B stage
        stashes its microbatch input when it runs the forward and fetches
        it right before the backward, metered as ``act_stash`` /
        ``act_fetch`` so :meth:`traffic_report` covers training pipelines.
        The stage body is recomputed from the fetched input (same
        footnote-4 behaviour as the layer wrapper)."""
        if not self.offloads:
            return stage_fn
        runtime = self

        def hints_for(dtype=None) -> TransferHints:
            return TransferHints(compute_spec=None, dtype=dtype, name=name)

        def is_float(leaf) -> bool:
            return (isinstance(leaf, (jax.Array, jnp.ndarray)) and
                    jnp.issubdtype(jnp.result_type(leaf), jnp.inexact))

        @jax.custom_vjp
        def f(params, tree):
            return stage_fn(params, tree)

        def f_fwd(params, tree):
            y = stage_fn(params, tree)
            saved = jax.tree.map(
                lambda leaf: StashedLeaf(
                    runtime.stash(leaf, hints_for(), direction="act_stash"),
                    jnp.zeros((), leaf.dtype)) if is_float(leaf) else leaf,
                tree)
            return y, (params, saved)

        def f_bwd(res, gy):
            params, saved = res
            tree = jax.tree.map(
                lambda leaf: runtime.fetch(
                    leaf.payload, hints_for(dtype=leaf.witness.dtype),
                    direction="act_fetch")
                if isinstance(leaf, StashedLeaf) else leaf,
                saved, is_leaf=lambda l: isinstance(l, StashedLeaf))
            _, vjp = jax.vjp(stage_fn, params, tree)
            return vjp(gy)

        f.defvjp(f_fwd, f_bwd)
        return f

    # ------------------------------------------------------------------
    # planning (KEEP/POOL/RECOMPUTE through the tier cost contract)
    def plan_report(self, dag: LayerDAG,
                    model_state_bytes: float = 0.0,
                    pipeline=None, n_micro_candidates=None,
                    checkpoint=None, ckpt_tier=None):
        return policy_mod.plan_memory(dag, self.plan, self.memory,
                                      chip=self.chip,
                                      model_state_bytes=model_state_bytes,
                                      tier=self.tier, pipeline=pipeline,
                                      n_micro_candidates=n_micro_candidates,
                                      checkpoint=checkpoint,
                                      ckpt_tier=ckpt_tier)

    def stash_fraction(self, dag: LayerDAG,
                       model_state_bytes: float = 0.0) -> float:
        """Fraction of layers this runtime stashes: 0 when the tier keeps
        everything resident, 1 for stash-all tiers, cost-model-derived
        otherwise."""
        if not self.offloads:
            return 0.0
        if self.tier.stash_all:
            return 1.0
        report = self.plan_report(dag, model_state_bytes=model_state_bytes)
        pooled = report.count("pool") + report.count("recompute")
        return pooled / max(len(report.decisions), 1)

    def resolve_stash_groups(self, cfg, shape, n_groups: int) -> int:
        """Number of scanned layer groups to stash (largest reuse distance
        first, matching the planner's eviction order)."""
        if not self.offloads:
            return 0
        if self.tier.stash_all:
            return n_groups
        dag = build_dag(cfg, shape)
        opt_bytes = 2 + (8 if self.memory.opt_state_bits == 32 else 2) + 4
        frac = self.stash_fraction(
            dag, model_state_bytes=cfg.param_count() * opt_bytes)
        k = int(round(n_groups * frac))
        return max(0, min(n_groups, k))


# ---------------------------------------------------------------------------
class StashedLeaf:
    """Residual marker for one stage-tier-stashed tensor: the tier payload
    plus a zero-size dtype witness (residuals must be JAX types).  A pytree
    node, so custom_vjp residual trees carry the stashed/raw distinction
    structurally."""

    __slots__ = ("payload", "witness")

    def __init__(self, payload, witness):
        self.payload = payload
        self.witness = witness


jax.tree_util.register_pytree_node(
    StashedLeaf,
    lambda s: ((s.payload, s.witness), None),
    lambda _, children: StashedLeaf(*children))


# ---------------------------------------------------------------------------
def fmt_bytes(n: float) -> str:
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if n >= div:
            return f"{n/div:.2f}{unit}"
    return f"{n:.0f}B"


# ---------------------------------------------------------------------------
def _split_aux(aux: Sequence[Any]):
    """Partition aux leaves into differentiable / non-differentiable."""
    return tuple(
        isinstance(a, (jax.Array, jnp.ndarray)) and
        jnp.issubdtype(jnp.result_type(a), jnp.inexact)
        for a in aux)
