"""Pluggable ``MemoryTier`` backing stores — the paper's memory hierarchy as
a first-class API.

The paper's central claim (§III) is *transparent* memory-capacity expansion:
the runtime decides where a tensor lives — device HBM, pooled HBM over the
device-side interconnect, or host DRAM — without the model knowing.  This
module is that decision surface.  Each backing store is a :class:`MemoryTier`
with a uniform contract:

  ``stash(x, hints)``      copy-out to the tier; returns an opaque payload
  ``fetch(payload, hints)`` prefetch back, restored to the compute layout
  ``bandwidth(plan, chip)`` per-device stash/fetch bandwidth (cost model)
  ``capacity(accountant)``  bytes one device can address through the tier
  ``account(acct, nbytes)`` charge a stashed tensor to the boot-time map

Shipped tiers (DESIGN.md §3):

* :class:`DeviceTier`     — KEEP / the oracle DC-DLA(O): nothing leaves HBM.
* :class:`PooledHbmTier`  — MC-DLA: the aggregate HBM of the mesh reached
  over ICI, BW_AWARE or LOCAL placement (core/pool.py, paper Fig. 10).
* :class:`HostTier`       — DC-DLA baseline: pinned host memory over PCIe.
* :class:`CompressedTier` — decorator adding the memory-node's "optional
  compression ASIC" (§III-A) to any tier; codecs are registry-extensible
  (fp8 ships; int8/zstd-style codecs slot in via :func:`register_codec`).
* :class:`SpillTier`      — decorator: primary tier until its capacity
  contract is spent, then overflow to a cheaper store.
* :class:`PipelineStageTier` — decorator: per-stage activation stash for
  pipeline schedules (1F1B), priced as the DCN stage hop in series with
  the backing store (ROADMAP "pipeline-parallel stage tier").
* :class:`CheckpointTier`  — decorator: the durable snapshot leg — the
  ``CheckpointManager`` writes through it, metered as ``ckpt_save`` /
  ``ckpt_load`` and priced as the DCN drain in series with the backing
  store (ROADMAP "checkpoint-as-a-tier").

Policies map to tiers through :func:`build_tier` — the ONLY place in the
codebase that branches on ``MemoryPlan.policy`` strings.  Everything else
(models, train, serve, sim, the planner) dispatches through the tier object
or the :class:`repro.core.runtime.MemoryRuntime` facade.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import hw
from repro.configs.base import MemoryPlan, MeshPlan
from repro.core import compress as comp
from repro.core.pool import PoolAccountant, PoolAxes, pool_spec
from repro.parallel.sharding import ShardingPlanner

# (data, optional codec scale) — the opaque unit a tier hands back from stash
Payload = Tuple[jax.Array, Optional[jax.Array]]


# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TransferHints:
    """Per-call context a tier may consult when placing a tensor.

    compute_spec: the layout the *compute* wants the tensor back in — a
      static PartitionSpec, a shape-aware callable ``shape -> spec``, or
      None (leave the tier's layout in place).
    batch_dim: index of the global-batch dim (pool placement stripes it).
    dtype: dtype to restore on fetch (codecs decompress into it).
    allow_compress: False for tensors that must round-trip bit-exactly
      (e.g. aux residuals validated against an uncompressed oracle).
    name: label for sharding-planner fallbacks and traffic accounting.
    """

    compute_spec: object = None
    batch_dim: int = 0
    dtype: Optional[jnp.dtype] = None
    allow_compress: bool = True
    name: str = "stash"

    def resolved_spec(self, shape) -> Optional[P]:
        if self.compute_spec is None:
            return None
        if callable(self.compute_spec):
            return self.compute_spec(shape)
        return self.compute_spec


# ---------------------------------------------------------------------------
class MemoryTier(abc.ABC):
    """One backing store of the memory hierarchy.

    Tiers are built once per run by :func:`build_tier` and threaded through
    :class:`repro.core.runtime.MemoryRuntime`; they hold the (planner, mesh)
    pair so call sites never hand-thread sharding state again.
    """

    #: short id used in reports and the registry
    kind: str = "abstract"

    def __init__(self, planner: ShardingPlanner, mesh: Optional[Mesh],
                 memory: MemoryPlan, *, stash_all: bool = True):
        self.planner = planner
        self.mesh = mesh
        self.memory = memory
        # policy trait: stash every layer (paper's stress-test mode) vs let
        # the KEEP/POOL/RECOMPUTE planner choose a stash fraction.
        self.stash_all = stash_all

    # -- data path ---------------------------------------------------------
    @abc.abstractmethod
    def stash(self, x: jax.Array, hints: TransferHints) -> Payload:
        """Copy-out ``x`` to the tier; returns an opaque payload."""

    @abc.abstractmethod
    def fetch(self, payload: Payload, hints: TransferHints) -> jax.Array:
        """Prefetch a payload back into the compute layout."""

    # -- cost contract -----------------------------------------------------
    @abc.abstractmethod
    def bandwidth(self, plan: MeshPlan, chip: hw.Chip = hw.TPU_V5E) -> float:
        """Per-device stash/fetch bandwidth in bytes/s (cost-model input)."""

    @abc.abstractmethod
    def capacity(self, accountant: PoolAccountant) -> float:
        """Bytes one device can address through this tier (paper Fig. 10
        boot-time memory map)."""

    def account(self, accountant: PoolAccountant, nbytes: float) -> None:
        """Charge a stashed tensor of global ``nbytes`` to the memory map."""
        accountant.alloc_pooled(nbytes)

    # -- traits ------------------------------------------------------------
    @property
    def offloads(self) -> bool:
        """False when stashing is a no-op (tensors stay resident)."""
        return True

    def payload_ratio(self) -> float:
        """Stashed bytes per raw byte (codecs shrink this below 1)."""
        return 1.0

    def wire_ratio(self, x: jax.Array, hints: TransferHints) -> float:
        """Actual bytes-per-raw-byte for THIS transfer — unlike
        ``payload_ratio`` it accounts for tensors a codec would skip
        (non-float dtypes, ``allow_compress=False``)."""
        return 1.0

    def describe(self) -> str:
        return self.kind

    # -- helpers -----------------------------------------------------------
    def _constrain(self, x: jax.Array, spec: Optional[P]) -> jax.Array:
        if spec is None or self.mesh is None or self.mesh.size <= 1:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


# ---------------------------------------------------------------------------
class DeviceTier(MemoryTier):
    """KEEP / oracle tier: the tensor never leaves device HBM.

    ``stash`` is the identity — this is DC-DLA(O), the paper's
    infinite-memory baseline, and the KEEP arm of the auto planner.
    """

    kind = "device"

    def stash(self, x: jax.Array, hints: TransferHints) -> Payload:
        return (x, None)

    def fetch(self, payload: Payload, hints: TransferHints) -> jax.Array:
        return payload[0]

    def bandwidth(self, plan: MeshPlan, chip: hw.Chip = hw.TPU_V5E) -> float:
        return chip.hbm_bw

    def capacity(self, accountant: PoolAccountant) -> float:
        return accountant.budget

    def account(self, accountant: PoolAccountant, nbytes: float) -> None:
        # global bytes stay resident, batch-sharded across the devices
        accountant.alloc_local(nbytes / max(accountant.plan.num_devices, 1))

    @property
    def offloads(self) -> bool:
        return False


# ---------------------------------------------------------------------------
class PooledHbmTier(MemoryTier):
    """MC-DLA: the aggregate HBM of the mesh as the backing store.

    A stashed tensor is re-sharded so every chip of the pool keeps only
    1/pool_size of it (core/pool.py BW_AWARE/LOCAL placements, paper
    Fig. 10) and all-gathered over ICI right before its backward use.
    """

    kind = "pooled_hbm"

    def stash(self, x: jax.Array, hints: TransferHints) -> Payload:
        spec = pool_spec(x.shape, self.planner, self.memory.placement,
                         hints.batch_dim, name=hints.name)
        return (self._constrain(x, spec), None)

    def fetch(self, payload: Payload, hints: TransferHints) -> jax.Array:
        x, _ = payload
        return self._constrain(x, hints.resolved_spec(x.shape))

    def bandwidth(self, plan: MeshPlan, chip: hw.Chip = hw.TPU_V5E) -> float:
        """bw_aware engages the ICI links of every mesh dimension the pool
        spans (paper Fig. 10: all N links, left+right nodes); local engages
        one dimension's links.  A 2D torus gives 2 links per dimension."""
        dims = len(PoolAxes(plan).axes_for(self.memory.placement))
        links = min(2 * dims, chip.num_links)
        return links * chip.link_bw

    def capacity(self, accountant: PoolAccountant) -> float:
        return accountant.system_capacity()

    def pool_devices(self, plan: MeshPlan) -> int:
        return PoolAxes(plan).pool_size(self.memory.placement)

    def describe(self) -> str:
        return f"{self.kind}[{self.memory.placement}]"


# ---------------------------------------------------------------------------
class HostTier(MemoryTier):
    """DC-DLA baseline: virtualize against pinned host memory over PCIe.

    Uses ``memory_kind='pinned_host'`` where the backend supports it (TPU
    does; the CPU test backend silently no-ops and the DC/HC/MC contrast is
    reproduced in ``sim/``).
    """

    kind = "host"

    _backend_has_pinned_host: Optional[bool] = None

    @classmethod
    def _supported(cls) -> bool:
        """True when the backend really exposes a pinned_host memory space.

        The CPU test backend advertises only 'unpinned_host' and its SPMD
        partitioner rejects the placement annotation under scan — so the
        host tier must genuinely no-op there (the DC/HC/MC contrast is
        reproduced in ``sim/`` instead)."""
        if cls._backend_has_pinned_host is None:
            try:
                kinds = {m.kind for m in
                         jax.devices()[0].addressable_memories()}
                cls._backend_has_pinned_host = "pinned_host" in kinds
            except Exception:
                cls._backend_has_pinned_host = False
        return cls._backend_has_pinned_host

    @classmethod
    def _transfer(cls, x: jax.Array, memory_kind: str) -> jax.Array:
        if not cls._supported():
            return x
        try:
            from jax._src.sharding_impls import TransferToMemoryKind  # noqa
            return jax.device_put(x, TransferToMemoryKind(memory_kind))
        except Exception:
            return x

    def stash(self, x: jax.Array, hints: TransferHints) -> Payload:
        return (self._transfer(x, "pinned_host"), None)

    def fetch(self, payload: Payload, hints: TransferHints) -> jax.Array:
        return self._transfer(payload[0], "device")

    def bandwidth(self, plan: MeshPlan, chip: hw.Chip = hw.TPU_V5E) -> float:
        """PCIe path, root-complex shared across the host's devices (paper
        §I: per-device host bandwidth divides by intra-node device count)."""
        local_devices = max(1, min(plan.num_devices, hw.DEVICES_PER_HOST))
        shared = 2 * hw.PCIE_ROOT_PER_SOCKET / local_devices
        return min(hw.PCIE_GEN3_BW, shared)

    def capacity(self, accountant: PoolAccountant) -> float:
        return hw.HOST_DRAM_BYTES

    def account(self, accountant: PoolAccountant, nbytes: float) -> None:
        # each device parks its own shard in host DRAM (per-device share,
        # matching the accountant's other per-device fields)
        accountant.alloc_host(nbytes / max(accountant.plan.num_devices, 1))


# ---------------------------------------------------------------------------
# codec registry — the memory-node's "optional compression ASIC" (§III-A).
# The registry itself lives in core/compress.py (codecs carry Pallas kernel
# twins there); these aliases keep the historical import path working.
Codec = comp.Codec
register_codec = comp.register_codec
get_codec = comp.get_codec
registered_codecs = comp.registered_codecs


class CompressedTier(MemoryTier):
    """Decorator: quantize-and-pack before any tier's stash collective.

    Halves (fp8) the bytes that cross the wire AND that occupy the inner
    tier — composable with pooled HBM and host alike, subsuming the old
    ``allow_compress`` flag threading.
    """

    kind = "compressed"

    def __init__(self, inner: MemoryTier, codec: str = "fp8"):
        super().__init__(inner.planner, inner.mesh, inner.memory,
                         stash_all=inner.stash_all)
        self.inner = inner
        self.codec = get_codec(codec)

    def stash(self, x: jax.Array, hints: TransferHints) -> Payload:
        if not hints.allow_compress or not self.codec.applies_to(x):
            return self.inner.stash(x, hints)
        q, scale = self.codec.compress(x)
        payload, _ = self.inner.stash(q, hints)
        return (payload, scale)

    def fetch(self, payload: Payload, hints: TransferHints) -> jax.Array:
        q, scale = payload
        if scale is None:
            return self.inner.fetch(payload, hints)
        # fetch the packed bytes through the inner tier in its own layout,
        # decompress, then restore the compute layout
        raw = self.inner.fetch(
            (q, None), dataclasses.replace(hints, compute_spec=None))
        x = self.codec.decompress(raw, scale, hints.dtype or jnp.bfloat16)
        return self._constrain(x, hints.resolved_spec(x.shape))

    def bandwidth(self, plan: MeshPlan, chip: hw.Chip = hw.TPU_V5E) -> float:
        return self.inner.bandwidth(plan, chip)

    def capacity(self, accountant: PoolAccountant) -> float:
        return self.inner.capacity(accountant)

    def account(self, accountant: PoolAccountant, nbytes: float) -> None:
        self.inner.account(accountant, nbytes * self.codec.ratio)

    @property
    def offloads(self) -> bool:
        return self.inner.offloads

    def payload_ratio(self) -> float:
        return self.codec.ratio * self.inner.payload_ratio()

    def wire_ratio(self, x: jax.Array, hints: TransferHints) -> float:
        if hints.allow_compress and self.codec.applies_to(x):
            return self.codec.ratio * self.inner.wire_ratio(x, hints)
        return self.inner.wire_ratio(x, hints)

    def describe(self) -> str:
        return f"{self.inner.describe()}+{self.codec.name}"


# ---------------------------------------------------------------------------
class SpillPayload:
    """Payload of a :class:`SpillTier` stash: the inner leg's payload plus a
    *static* record of which leg took it and how many bytes it charged.

    Registered as a pytree node with (leg, nbytes) in the treedef so the
    routing decision — made at trace time by the Python-side capacity
    counter — survives jit residuals without becoming a traced value.
    """

    __slots__ = ("leg", "nbytes", "inner")

    def __init__(self, leg: str, nbytes: float, inner: Payload):
        self.leg = leg              # "primary" | "overflow"
        self.nbytes = nbytes        # bytes charged against the primary budget
        self.inner = inner

    def __repr__(self) -> str:
        return f"SpillPayload(leg={self.leg!r}, nbytes={self.nbytes:.0f})"


jax.tree_util.register_pytree_node(
    SpillPayload,
    lambda p: ((p.inner,), (p.leg, p.nbytes)),
    lambda aux, children: SpillPayload(aux[0], aux[1], children[0]))


class SpillTier(MemoryTier):
    """Decorator: primary tier until its capacity contract is exhausted,
    then overflow to a cheaper backing store.

    The ROADMAP's host+pool composition (Buddy-Compression-style cold-page
    demotion, arXiv:1903.02596): stash to the *primary* leg (e.g. pooled
    HBM) while the boot-time capacity contract has headroom, and overflow
    to the *overflow* leg (e.g. host DRAM) once it is spent.  The routing
    decision is taken per-stash at trace time against a Python-side byte
    counter, so the same object works inside jit (static routing) and in
    the serving host loop (dynamic slot churn via :meth:`discard`).

    The planner prices both legs: each leg is itself a full
    :class:`MemoryTier`, and the blended :meth:`bandwidth` degrades from
    the primary's toward the occupancy-weighted harmonic mean as the
    primary fills.
    """

    kind = "spill"

    def __init__(self, primary: MemoryTier, overflow: MemoryTier,
                 primary_budget: Optional[float] = None):
        super().__init__(primary.planner, primary.mesh, primary.memory,
                         stash_all=primary.stash_all)
        self.primary = primary
        self.overflow = overflow
        if primary_budget is None:
            acct = PoolAccountant(primary.planner.plan, primary.memory)
            primary_budget = primary.capacity(acct)
        self.primary_budget = float(primary_budget)
        self._primary_used = 0.0
        self._overflow_used = 0.0

    # -- routing -----------------------------------------------------------
    def _charge_bytes(self, x: jax.Array) -> float:
        raw = float(x.size) * jnp.dtype(x.dtype).itemsize
        return raw * self.primary.payload_ratio()

    def primary_headroom(self) -> float:
        return self.primary_budget - self._primary_used

    def reset(self) -> None:
        self._primary_used = 0.0
        self._overflow_used = 0.0

    def stash(self, x: jax.Array, hints: TransferHints) -> Payload:
        nbytes = self._charge_bytes(x)
        if nbytes <= self.primary_headroom():
            self._primary_used += nbytes
            return (SpillPayload("primary", nbytes,
                                 self.primary.stash(x, hints)), None)
        self._overflow_used += nbytes
        return (SpillPayload("overflow", nbytes,
                             self.overflow.stash(x, hints)), None)

    def fetch(self, payload: Payload, hints: TransferHints) -> jax.Array:
        sp = payload[0]
        leg = self.primary if sp.leg == "primary" else self.overflow
        return leg.fetch(sp.inner, hints)

    def discard(self, payload: Payload) -> None:
        """Release a stashed slot's budget charge (serving slot churn)."""
        sp = payload[0]
        if sp.leg == "primary":
            self._primary_used = max(0.0, self._primary_used - sp.nbytes)
        else:
            self._overflow_used = max(0.0, self._overflow_used - sp.nbytes)

    def leg_for(self, payload: Payload) -> str:
        return payload[0].leg

    # -- cost contract: both legs priced -----------------------------------
    def bandwidth(self, plan: MeshPlan, chip: hw.Chip = hw.TPU_V5E) -> float:
        """Occupancy-blended: all-primary while nothing has overflowed,
        then the harmonic mean weighted by the routed byte fractions
        (bytes on each leg stream at that leg's rate)."""
        bw_p = self.primary.bandwidth(plan, chip)
        if self._overflow_used <= 0.0:
            return bw_p
        bw_o = self.overflow.bandwidth(plan, chip)
        total = self._primary_used + self._overflow_used
        f_over = self._overflow_used / total
        return 1.0 / ((1.0 - f_over) / bw_p + f_over / bw_o)

    def capacity(self, accountant: PoolAccountant) -> float:
        return self.primary_budget + self.overflow.capacity(accountant)

    def account(self, accountant: PoolAccountant, nbytes: float) -> None:
        if nbytes <= self.primary_headroom():
            self.primary.account(accountant, nbytes)
        else:
            self.overflow.account(accountant, nbytes)

    def payload_ratio(self) -> float:
        return self.primary.payload_ratio()

    def wire_ratio(self, x: jax.Array, hints: TransferHints) -> float:
        if self._charge_bytes(x) <= self.primary_headroom():
            return self.primary.wire_ratio(x, hints)
        return self.overflow.wire_ratio(x, hints)

    def describe(self) -> str:
        return (f"{self.kind}[{self.primary.describe()}"
                f"->{self.overflow.describe()}]")


# ---------------------------------------------------------------------------
class PipelineStageTier(MemoryTier):
    """Decorator: per-stage activation backing store for pipeline schedules.

    The training half of the tier unification (ROADMAP "pipeline-parallel
    stage tier"): a 1F1B schedule's saved stage inputs leave the stage's
    HBM for a backing store instead of staying implicitly live, so the
    KEEP/POOL/RECOMPUTE planner can trade pipeline bubbles against pool
    traffic with the same cost contract it prices every other tier with.

    * ``bandwidth`` — the DCN stage hop in *series* with the backing
      store: bytes cross the inter-stage link and then the inner tier's
      stash collective, so the harmonic composition bounds both.
    * ``capacity`` — each stage addresses its 1/n_stages share of the
      backing store (stages stash concurrently into the same pool).
    * data path — delegates to the inner tier; composes with
      :class:`CompressedTier` / :class:`SpillTier` like any other
      decorator (``build_stage_tier`` stacks the configured codec).
    """

    kind = "pipeline_stage"

    def __init__(self, inner: MemoryTier, n_stages: int = 1):
        super().__init__(inner.planner, inner.mesh, inner.memory,
                         stash_all=inner.stash_all)
        self.inner = inner
        self.n_stages = max(1, n_stages)

    def set_stages(self, n_stages: int) -> None:
        self.n_stages = max(1, n_stages)

    def stash(self, x: jax.Array, hints: TransferHints) -> Payload:
        return self.inner.stash(x, hints)

    def fetch(self, payload: Payload, hints: TransferHints) -> jax.Array:
        return self.inner.fetch(payload, hints)

    def bandwidth(self, plan: MeshPlan, chip: hw.Chip = hw.TPU_V5E) -> float:
        inner_bw = self.inner.bandwidth(plan, chip)
        if inner_bw <= 0:
            return hw.DCN_BW
        return 1.0 / (1.0 / hw.DCN_BW + 1.0 / inner_bw)

    def capacity(self, accountant: PoolAccountant) -> float:
        return self.inner.capacity(accountant) / self.n_stages

    def account(self, accountant: PoolAccountant, nbytes: float) -> None:
        self.inner.account(accountant, nbytes)

    @property
    def offloads(self) -> bool:
        return self.inner.offloads

    def payload_ratio(self) -> float:
        return self.inner.payload_ratio()

    def wire_ratio(self, x: jax.Array, hints: TransferHints) -> float:
        return self.inner.wire_ratio(x, hints)

    def describe(self) -> str:
        return f"{self.kind}[{self.n_stages}x{self.inner.describe()}]"


class CheckpointTier(MemoryTier):
    """Decorator: the durable snapshot leg of the memory hierarchy.

    A checkpoint is the coldest tensor class of all — touched once per
    cadence, read only on failure — so it belongs in the pool, not in a
    side-channel that bypasses the tier API (ISSUE 6 / ROADMAP
    "checkpoint-as-a-tier").  The decorator delegates the data path to its
    backing store (host DRAM or pooled HBM, with an optional codec stacked
    on top by :func:`build_ckpt_tier`) and prices durability:

    * ``bandwidth`` — a snapshot is only fault-tolerant once it leaves the
      failure domain, so the drain is the DCN hop in *series* with the
      backing store's stash collective (same harmonic composition as
      :class:`PipelineStageTier`'s stage hop).
    * ``capacity`` — ``keep`` live snapshots must fit concurrently: each
      addresses 1/keep of the backing store.
    """

    kind = "ckpt"

    def __init__(self, inner: MemoryTier, keep: int = 1):
        super().__init__(inner.planner, inner.mesh, inner.memory,
                         stash_all=inner.stash_all)
        self.inner = inner
        self.keep = max(1, keep)

    def stash(self, x: jax.Array, hints: TransferHints) -> Payload:
        return self.inner.stash(x, hints)

    def fetch(self, payload: Payload, hints: TransferHints) -> jax.Array:
        return self.inner.fetch(payload, hints)

    def bandwidth(self, plan: MeshPlan, chip: hw.Chip = hw.TPU_V5E) -> float:
        inner_bw = self.inner.bandwidth(plan, chip)
        if inner_bw <= 0:
            return hw.DCN_BW
        return 1.0 / (1.0 / hw.DCN_BW + 1.0 / inner_bw)

    def capacity(self, accountant: PoolAccountant) -> float:
        return self.inner.capacity(accountant) / self.keep

    def account(self, accountant: PoolAccountant, nbytes: float) -> None:
        self.inner.account(accountant, nbytes)

    @property
    def offloads(self) -> bool:
        # a checkpoint always leaves the device, even over a DeviceTier
        # backing (the drain hop is the point)
        return True

    def payload_ratio(self) -> float:
        return self.inner.payload_ratio()

    def wire_ratio(self, x: jax.Array, hints: TransferHints) -> float:
        return self.inner.wire_ratio(x, hints)

    def describe(self) -> str:
        return f"{self.kind}[{self.inner.describe()}]"


def build_ckpt_tier(memory: MemoryPlan, planner: ShardingPlanner,
                    mesh: Optional[Mesh] = None,
                    backing: str = "host", codec: str = "none",
                    keep: int = 1) -> MemoryTier:
    """The snapshot tier for a run: the requested backing store behind the
    durability drain, with the snapshot codec stacked on top.  Mirrors
    :func:`build_stage_tier` — the backing policy resolves through the
    registry, so a new store prices checkpoints without touching this."""
    if backing in ("none", "pipeline", "checkpoint"):
        backing = "host"
    binding = _TIER_REGISTRY[backing]
    inner = binding.factory(memory, planner, mesh)
    inner.stash_all = binding.stash_all
    tier: MemoryTier = CheckpointTier(inner, keep=keep)
    if codec != "none":
        tier = CompressedTier(tier, codec)
    return tier


def build_stage_tier(memory: MemoryPlan, planner: ShardingPlanner,
                     mesh: Optional[Mesh] = None,
                     n_stages: int = 1) -> MemoryTier:
    """The stage tier for a pipeline run: the memory plan's own backing
    store (pooled HBM when the policy keeps everything resident) behind the
    per-stage DCN hop, with the configured codec stacked on top."""
    backing = memory.policy if memory.policy not in ("none", "pipeline") \
        else "mcdla"
    binding = _TIER_REGISTRY[backing]
    inner = binding.factory(memory, planner, mesh)
    inner.stash_all = binding.stash_all
    tier: MemoryTier = PipelineStageTier(inner, n_stages=n_stages)
    if memory.compress != "none":
        tier = CompressedTier(tier, memory.compress)
    return tier


# ---------------------------------------------------------------------------
# tier registry: MemoryPlan.policy -> tier.  The one sanctioned policy-string
# dispatch in the codebase (everything else goes through the tier object).
TierFactory = Callable[[MemoryPlan, ShardingPlanner, Optional[Mesh]],
                       MemoryTier]


@dataclasses.dataclass(frozen=True)
class TierBinding:
    factory: TierFactory
    stash_all: bool          # stash every layer vs planner-chosen fraction


_TIER_REGISTRY: Dict[str, TierBinding] = {}


def register_tier(policy: str, factory: TierFactory,
                  stash_all: bool = True) -> None:
    _TIER_REGISTRY[policy] = TierBinding(factory, stash_all)


def registered_policies() -> Tuple[str, ...]:
    return tuple(sorted(_TIER_REGISTRY))


def build_tier(memory: MemoryPlan, planner: ShardingPlanner,
               mesh: Optional[Mesh] = None) -> MemoryTier:
    """Resolve a :class:`MemoryPlan` to its tier stack.

    Configs stay plain serializable dataclasses; this is where the policy
    string becomes an object.  ``compress != 'none'`` wraps the tier in a
    :class:`CompressedTier` (a no-op stack on the device tier, which never
    moves bytes).
    """
    if memory.policy not in _TIER_REGISTRY:
        raise KeyError(f"unknown memory policy {memory.policy!r}; "
                       f"registered: {registered_policies()}")
    binding = _TIER_REGISTRY[memory.policy]
    tier = binding.factory(memory, planner, mesh)
    tier.stash_all = binding.stash_all
    if memory.compress != "none" and tier.offloads:
        tier = CompressedTier(tier, memory.compress)
    return tier


register_tier("none",
              lambda m, p, mesh: DeviceTier(p, mesh, m), stash_all=False)
register_tier("host",
              lambda m, p, mesh: HostTier(p, mesh, m), stash_all=True)
register_tier("mcdla",
              lambda m, p, mesh: PooledHbmTier(p, mesh, m), stash_all=True)
# "auto" uses the same pooled tier; the KEEP/POOL/RECOMPUTE planner
# (core/policy.py) decides the stash fraction instead of stashing all.
register_tier("auto",
              lambda m, p, mesh: PooledHbmTier(p, mesh, m), stash_all=False)
# "spill": pooled HBM until the pool's capacity contract is spent, host
# DRAM past it (ROADMAP host+pool composition).
register_tier("spill",
              lambda m, p, mesh: SpillTier(PooledHbmTier(p, mesh, m),
                                           HostTier(p, mesh, m)),
              stash_all=True)
# "pipeline": the pipeline-stage tier over pooled HBM (stage count is
# late-bound by the run via set_stages; build_stage_tier is the usual way
# to construct it with the right backing store + codec stack).
register_tier("pipeline",
              lambda m, p, mesh: PipelineStageTier(PooledHbmTier(p, mesh, m)),
              stash_all=True)
# "checkpoint": the durable snapshot leg over host DRAM (build_ckpt_tier is
# the usual way to construct it with a pooled backing + codec stack).
register_tier("checkpoint",
              lambda m, p, mesh: CheckpointTier(HostTier(p, mesh, m)),
              stash_all=True)


# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TierSpec:
    """Hardware-model-level bandwidth/capacity contract of a backing store.

    The executable tiers above move real arrays; this spec is their analytic
    twin used by ``sim/`` to model the paper's DC/HC/MC design points as
    tier configurations (same contract, no jax arrays).
    """

    kind: str                          # device | host | pooled
    bw_per_device: float               # stash/fetch bytes/s per device
    shared_bw: float = 0.0             # host-side cap (0 = uncapped)
    uses_cpu: bool = False             # traffic counts against CPU memory BW
    capacity_bytes: float = float("inf")

    @property
    def is_oracle(self) -> bool:
        return self.kind == "device"

    def effective_bw(self, n_devices: int, n_sockets: int = 2) -> float:
        """Per-device bandwidth when ``n_devices`` stream concurrently —
        the paper's §I observation that shared host links divide."""
        if self.is_oracle:
            return float("inf")
        bw = self.bw_per_device
        if self.shared_bw > 0:
            bw = min(bw, self.shared_bw * n_sockets / max(n_devices, 1))
        return bw
