"""vdnn — legacy wrapper entry point, now a thin veneer over MemoryRuntime.

Historically this module was one of three divergent wrapper entry points
(`core.offload.maybe_offload`, `VdnnContext.wrap_layer`,
`models.layers.ModelContext.wrap`).  All three now delegate to
:class:`repro.core.runtime.MemoryRuntime` — the single facade that owns the
planner, the mesh and the :class:`~repro.core.tiers.MemoryTier` stack.
Prefer constructing a ``MemoryRuntime`` directly in new code.

Under scan-over-layers all layers share one body, so ``auto`` is realised
with a *stash fraction*: the planner returns r = pooled/(pooled+kept) and
``split_layers`` partitions the scanned stack into a kept prefix and a
pooled suffix (early layers have the largest reuse distance — they are
stashed first, exactly the planner's eviction order).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import MemoryPlan, MeshPlan
from repro.core.dag import LayerDAG
from repro.core.runtime import MemoryRuntime
from repro.parallel.sharding import ShardingPlanner


@dataclasses.dataclass
class VdnnContext:
    """Deprecated shim — use :class:`repro.core.runtime.MemoryRuntime`."""

    planner: ShardingPlanner
    mesh: Optional[Mesh]
    memory: MemoryPlan

    def __post_init__(self):
        self.runtime = MemoryRuntime(self.planner.plan, self.memory,
                                     self.mesh, planner=self.planner)

    def wrap_layer(self, layer_fn: Callable,
                   compute_spec: Optional[P] = None,
                   batch_dim: int = 0) -> Callable:
        """Offload-wrap a layer according to the memory policy."""
        if self.mesh is None:
            return layer_fn
        return self.runtime.wrap_layer(layer_fn, compute_spec=compute_spec,
                                       batch_dim=batch_dim)


def stash_fraction(dag: LayerDAG, plan: MeshPlan, memory: MemoryPlan,
                   model_state_bytes: float = 0.0) -> float:
    """Fraction of layers the policy stashes (1.0 for stash-all tiers;
    cost-model-derived for auto; 0.0 when nothing offloads)."""
    return MemoryRuntime(plan, memory).stash_fraction(
        dag, model_state_bytes=model_state_bytes)


def split_layers(num_layers: int, fraction: float) -> int:
    """Number of *stashed* layers: the first k of L (largest reuse
    distance first, matching the planner's eviction order)."""
    k = int(round(num_layers * fraction))
    return max(0, min(num_layers, k))
