"""vdnn — the policy-driven layer wrapper (the memory-overlaying runtime).

``wrap_layer`` is the single entry point the model code uses: given the
run's :class:`MemoryPlan` it returns the layer function with the right
saved-for-backward behaviour:

* ``none``   — oracle DC-DLA(O): plain layer, everything resident.
* ``mcdla``  — paper-faithful: every layer's input feature map is stashed to
               the pooled tier (core.offload), intermediates recomputed.
* ``host``   — DC-DLA baseline: stash to host memory (PCIe path on real HW).
* ``auto``   — beyond-paper: the core.policy cost model picks KEEP for as
               many layers as the HBM budget allows; the rest POOL.

Under scan-over-layers all layers share one body, so ``auto`` is realised
with a *stash fraction*: the planner returns r = pooled/(pooled+kept) and
``scan_stash_fraction`` partitions the scanned stack into a kept prefix and
a pooled suffix (early layers have the largest reuse distance — they are
stashed first, exactly the planner's eviction order).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import MemoryPlan, MeshPlan
from repro.core import policy as policy_mod
from repro.core.dag import LayerDAG
from repro.core.offload import maybe_offload
from repro.parallel.sharding import ShardingPlanner


@dataclasses.dataclass
class VdnnContext:
    planner: ShardingPlanner
    mesh: Optional[Mesh]
    memory: MemoryPlan

    def wrap_layer(self, layer_fn: Callable,
                   compute_spec: Optional[P] = None,
                   batch_dim: int = 0) -> Callable:
        """Offload-wrap a layer according to the memory policy."""
        if self.memory.policy == "none" or self.mesh is None:
            return layer_fn
        return maybe_offload(layer_fn, self.planner, self.mesh, self.memory,
                             compute_spec, batch_dim)


def stash_fraction(dag: LayerDAG, plan: MeshPlan, memory: MemoryPlan,
                   model_state_bytes: float = 0.0) -> float:
    """Fraction of layers the policy stashes (1.0 for mcdla/host;
    cost-model-derived for auto; 0.0 for none)."""
    if memory.policy == "none":
        return 0.0
    if memory.policy in ("mcdla", "host"):
        return 1.0
    report = policy_mod.plan_memory(dag, plan, memory,
                                    model_state_bytes=model_state_bytes)
    pooled = report.count("pool") + report.count("recompute")
    total = len(report.decisions)
    return pooled / max(total, 1)


def split_layers(num_layers: int, fraction: float) -> int:
    """Number of *stashed* layers: the first k of L (largest reuse
    distance first, matching the planner's eviction order)."""
    k = int(round(num_layers * fraction))
    return max(0, min(num_layers, k))
