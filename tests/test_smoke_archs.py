"""Per-arch smoke tests (assignment requirement): a REDUCED same-family twin
runs one forward/train step on CPU asserting output shapes + no NaNs, plus a
prefill + decode step."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, MemoryPlan, MeshPlan, RunConfig
from repro.configs.base import ShapeConfig
from repro.models.model import build_model

B, S = 2, 32
PLAN1 = MeshPlan((1,), ("data",))


def make_batch(cfg, m):
    batch = {
        "tokens": jnp.ones((B, S), jnp.int32),
        "labels": jnp.where(jnp.arange(S)[None] < S - 1,
                            jnp.ones((B, S), jnp.int32), -1),
        "positions": (jnp.zeros((3, B, S), jnp.int32)
                      + jnp.arange(S)[None, None, :]
                      if cfg.mrope_sections else
                      jnp.broadcast_to(jnp.arange(S)[None], (B, S))),
    }
    if cfg.frontend == "audio_stub":
        from repro.models.frontends import AUDIO_FRAME_DIM
        batch["frames"] = jnp.ones((B, cfg.frontend_tokens,
                                    AUDIO_FRAME_DIM), m.dtype)
    if cfg.frontend == "vision_stub":
        from repro.models.frontends import VISION_PATCH_DIM
        batch["patches"] = jnp.ones((B, cfg.frontend_tokens,
                                     VISION_PATCH_DIM), m.dtype)
    return batch


@pytest.fixture(scope="module")
def models():
    return {}


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_step_smoke(name):
    cfg = ARCHS[name].reduced()
    run = RunConfig(model=cfg, shape=ShapeConfig("smoke", S, B, "train"),
                    mesh=PLAN1, memory=MemoryPlan(policy="none"))
    m = build_model(run)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, m)
    (loss, metrics), grads = jax.value_and_grad(
        m.loss_fn, has_aux=True)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), name
    gsum = sum(jnp.sum(jnp.abs(l.astype(jnp.float32)))
               for l in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(gsum)) and float(gsum) > 0, name
    assert float(metrics["tokens"]) == B * (S - 1)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_serve_smoke(name):
    cfg = ARCHS[name].reduced()
    run = RunConfig(model=cfg, shape=ShapeConfig("smoke", S, B, "decode"),
                    mesh=PLAN1, memory=MemoryPlan(policy="none"))
    m = build_model(run)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, m)
    caches = m.init_cache(B, S + 4)
    logits, caches = m.prefill(params, batch, caches)
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), name
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    pos = (jnp.full((3, B, 1), S, jnp.int32) if cfg.mrope_sections
           else jnp.full((B, 1), S, jnp.int32))
    logits2, caches = m.decode_step(params, tok, pos, caches, jnp.int32(S))
    assert logits2.shape == (B, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32)))), name


def test_decode_matches_prefill_continuation():
    """Greedy decode after prefill(S) must equal a fresh prefill(S+1)'s
    last-token logits (cache correctness across the whole stack)."""
    for name in ("smollm-135m", "mamba2-370m", "zamba2-2.7b"):
        cfg = ARCHS[name].reduced()
        run = RunConfig(model=cfg,
                        shape=ShapeConfig("smoke", S, B, "decode"),
                        mesh=PLAN1, memory=MemoryPlan(policy="none"))
        m = build_model(run)
        params = m.init(jax.random.PRNGKey(0))
        key = jax.random.PRNGKey(7)
        toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
        pos_full = jnp.broadcast_to(jnp.arange(S + 1)[None], (B, S + 1))

        caches = m.init_cache(B, S + 8)
        batch = {"tokens": toks[:, :S], "positions": pos_full[:, :S]}
        _, caches = m.prefill(params, batch, caches)
        logits_dec, _ = m.decode_step(
            params, toks[:, S:S + 1], pos_full[:, S:S + 1], caches,
            jnp.int32(S))

        caches2 = m.init_cache(B, S + 8)
        batch2 = {"tokens": toks, "positions": pos_full}
        logits_pref, _ = m.prefill(params, batch2, caches2)

        a = logits_dec.astype(jnp.float32)
        b = logits_pref.astype(jnp.float32)
        err = float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(b)) + 1e-9))
        assert err < 0.05, (name, err)
        assert bool(jnp.all(jnp.argmax(a, -1) == jnp.argmax(b, -1))), name
