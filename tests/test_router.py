"""Cluster router: placement, cluster-wide quotas, graceful drain,
engine loss — plus the wire transport driven through the SAME queue
trace driver that pins the loopback TransferQueue (tests/test_disagg.py)
and the two-process TCP smoke (the CI drain scenario).

The hypothesis property suite (ISSUE 7's list) runs on a lightweight
fake pair so thousands of random schedules fit a CI budget; every
invariant also runs on seeded traces against the real engines below, so
the machinery is covered without hypothesis.
"""
import os
import random
import subprocess
import sys
import time
from collections import deque
from types import SimpleNamespace

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, MemoryPlan, RunConfig
from repro.configs.base import MeshPlan, ShapeConfig
from repro.models.model import build_model
from repro.serve.disagg import build_disagg
from repro.serve.engine import Request
from repro.serve.quota import QuotaManager, TenantQuota
from repro.serve.router import (ACTIVE, DETACHED, DRAINING, EngineView,
                                LeastLoaded, PrefixAffinity, Router,
                                RoundRobin, build_placement, build_router,
                                registered_placements, replay_trace,
                                synth_prompt)
from repro.serve.session import Session, SessionState
from repro.serve.transport import (WireReceiver, WireSender, build_wire_pair,
                                   memory_pair)

from test_disagg import run_transfer_queue_trace

CFG = ARCHS["smollm-135m"].reduced()


@pytest.fixture(scope="module")
def model_and_params():
    run = RunConfig(model=CFG, shape=ShapeConfig("t", 64, 2, "decode"),
                    mesh=MeshPlan((1,), ("data",)),
                    memory=MemoryPlan(policy="none"))
    m = build_model(run)
    return m, m.init(jax.random.PRNGKey(0))


def _prompts(n, base=4):
    return [((np.arange(base + i, dtype=np.int32) * (i + 2) + 1)
             % CFG.vocab_size) for i in range(n)]


# ---------------------------------------------------------------------------
# placement policies (pure)
def _views(*loads, window=8):
    return [EngineView(i, load, window - load)
            for i, load in enumerate(loads)]


def _sess(uid, prompt=(1, 2, 3)):
    return Session(request=Request(uid=uid, prompt=list(prompt)), seq=uid)


def test_registry():
    assert set(registered_placements()) >= {
        "least_loaded", "prefix_affinity", "round_robin"}
    assert isinstance(build_placement("round_robin"), RoundRobin)
    with pytest.raises(KeyError, match="unknown placement"):
        build_placement("darts")


def test_least_loaded_breaks_ties_low_index():
    pol = LeastLoaded()
    assert pol.choose(_views(3, 1, 1), _sess(0)) == 1
    assert pol.choose(_views(0, 0, 0), _sess(0)) == 0


def test_round_robin_rotates():
    pol = RoundRobin()
    got = [pol.choose(_views(0, 0, 0), _sess(i)) for i in range(6)]
    assert got == [0, 1, 2, 0, 1, 2]


def test_round_robin_survives_drain_mid_rotation():
    """Bugfix: the positional ``turn % len(views)`` cursor shifted when an
    engine drained mid-rotation — the rotation must continue from the
    last-placed engine *identity* over the survivors."""
    pol = RoundRobin()
    assert pol.choose(_views(0, 0, 0), _sess(0)) == 0
    assert pol.choose(_views(0, 0, 0), _sess(1)) == 1
    # engine 1 drains: views shrink to {0, 2}; a positional cursor
    # (turn=2) would pick views[0] == engine 0 — double-placing on 0
    # while engine 2 starves
    survivors = [v for v in _views(0, 0, 0) if v.index != 1]
    assert pol.choose(survivors, _sess(2)) == 2
    assert pol.choose(survivors, _sess(3)) == 0
    assert pol.choose(survivors, _sess(4)) == 2
    # engine 1 comes back: it rejoins the rotation in index order
    assert pol.choose(_views(0, 0, 0), _sess(5)) == 0
    assert pol.choose(_views(0, 0, 0), _sess(6)) == 1
    assert pol.choose(_views(0, 0, 0), _sess(7)) == 2


def test_prefix_affinity_is_sticky_and_minimally_disruptive():
    pol = PrefixAffinity(prefix_len=4)
    same = [_sess(i, prompt=[7, 7, 7, 7, i]) for i in range(10)]
    other = [_sess(100 + i, prompt=[9, 9, 9, 9, i]) for i in range(10)]
    views3 = _views(0, 0, 0)
    a = {pol.choose(views3, s) for s in same}
    b = {pol.choose(views3, s) for s in other}
    assert len(a) == 1 and len(b) == 1          # shared prefix -> one home
    # rendezvous property: removing an unrelated engine never moves a
    # prefix whose home survives
    home = a.pop()
    survivors = [v for v in views3 if v.index != (home + 1) % 3]
    assert {pol.choose(survivors, s) for s in same} == {home}


def test_prefix_affinity_spills_when_home_full():
    pol = PrefixAffinity(prefix_len=4)
    s = _sess(0, prompt=[7, 7, 7, 7])
    views = _views(0, 0, 0)
    home = pol.choose(views, s)
    full = [EngineView(v.index, 8, 0) if v.index == home else v
            for v in views]
    assert pol.choose(full, s) != home


# ---------------------------------------------------------------------------
# a lightweight pair: real Sessions, fake compute (one token per step)
class _Sched:
    def __init__(self):
        self.q = deque()

    def submit(self, s):
        self.q.append(s)

    def waiting(self):
        return tuple(self.q)

    def next_ready(self):
        return self.q.popleft() if self.q else None


class FakePair:
    """Duck-types the pair surface Router drives, with instant prefill
    and one decoded token per step — placement/drain/loss logic runs
    thousands of random schedules in milliseconds."""

    def __init__(self, slots=2, quota=None):
        self.prefill = SimpleNamespace(scheduler=_Sched(),
                                       cache=SimpleNamespace(
                                           running=lambda: []),
                                       quota=quota, sessions=[], batch=1)
        self.decode = SimpleNamespace(scheduler=_Sched(),
                                      cache=SimpleNamespace(
                                          running=lambda: list(self._res)),
                                      sessions=[], batch=slots)
        self.transfer = SimpleNamespace(depth=lambda: 0)
        self.slots = slots
        self._res = []

    def submit(self, req=None, on_token=None, session=None):
        sess = session
        self.prefill.sessions.append(sess)
        self.prefill.scheduler.submit(sess)
        return sess

    def step(self):
        self._res = [s for s in self._res if not s.done]
        while len(self._res) < self.slots:
            s = self.prefill.scheduler.next_ready()
            if s is None:
                break
            if s.done:
                continue
            s.state = SessionState.RUNNING
            self.decode.sessions.append(s)
            self._res.append(s)
        for s in list(self._res):
            s.length += 1
            s.emit(int(s.length))
            if len(s.tokens) >= s.request.max_new_tokens:
                s.finish("length")
                self._res.remove(s)
        return len(self._res)

    def has_work(self):
        return bool(self.prefill.scheduler.q) or bool(self._res)

    def traffic_report(self):
        return {}


def _fake_router(n=3, slots=2, placement="least_loaded", **kw):
    return Router([FakePair(slots=slots) for _ in range(n)],
                  placement=placement, **kw)


class SpyPolicy:
    """Wraps a policy; records every choice and asserts the router only
    ever showed it ACTIVE engines."""

    def __init__(self, inner, router_ref):
        self.inner = inner
        self.router_ref = router_ref
        self.choices = []
        self.name = f"spy({inner.name})"

    def choose(self, views, sess):
        router = self.router_ref()
        for v in views:
            assert router.engines[v.index].state == ACTIVE, \
                f"policy offered a {router.engines[v.index].state} engine"
        idx = self.inner.choose(views, sess)
        self.choices.append((sess.uid, idx))
        return idx

    def describe(self):
        return self.name


def _run_ops(ops, n_engines=3, slots=2, policy="least_loaded"):
    """Drive a router through a random submit/drain/fail/step schedule;
    returns the router.  Core invariants assert inline."""
    router = _fake_router(n=n_engines, slots=slots, placement=policy)
    router.policy = SpyPolicy(router.policy, lambda: router)
    uid = 0
    for op, arg in ops:
        if op == "submit":
            router.submit(Request(uid=uid, prompt=[1 + arg % 5] * 4,
                                  max_new_tokens=1 + arg % 4))
            uid += 1
        elif op == "drain":
            live = [e for e in router.engines if e.state == ACTIVE]
            if len(live) > 1:           # keep one engine to finish on
                router.drain(live[arg % len(live)].index)
        elif op == "fail":
            live = [e for e in router.engines if e.state == ACTIVE]
            if len(live) > 1:
                router.fail(live[arg % len(live)].index)
        router.step()
    router.run(max_steps=5000)
    return router


def _assert_invariants(router):
    dropped = [s for s in router.sessions.values() if not s.done]
    assert not dropped, f"dropped sessions: {[s.uid for s in dropped]}"
    for eng in router.engines:
        if eng.state == DRAINING:
            assert not eng.pair.has_work()
    # every drained engine stopped receiving placements after its drain
    assert not router.queue


def test_router_random_schedules_seeded():
    rng = random.Random(99)
    for _ in range(25):
        ops = [(rng.choice(["submit", "submit", "submit", "drain",
                            "fail"]), rng.randrange(32))
               for _ in range(rng.randrange(5, 40))]
        pol = rng.choice(["least_loaded", "round_robin", "prefix_affinity"])
        _assert_invariants(_run_ops(ops, policy=pol))


# ---------------------------------------------------------------------------
# the wire through the loopback queue's trace driver (seeded twin of the
# hypothesis property in tests/test_serve_properties.py)
def test_wire_queue_random_traces_seeded():
    """The byte-serialized wire driven through the SAME trace driver
    that pins the loopback TransferQueue: FIFO pages, exactly-once
    delivery, no starvation, no leaked payloads — now across frames."""
    rng = random.Random(2718)
    for _ in range(15):
        ops = [(rng.choice(["publish", "adopt", "adopt", "cancel"]),
                rng.randrange(16)) for _ in range(60)]
        q, adopted = run_transfer_queue_trace(
            ops, max_depth=rng.choice([None, 2, 4]),
            make_queue=_make_wire_queue)
        assert q.depth() == 0


class _WireLoop:
    """Sender+receiver glued into the TransferQueue surface, every
    handoff crossing a real (in-memory, fragmented) byte channel."""

    def __init__(self, max_depth):
        class _NullRuntime:
            def meter_transfer(self, *a, **k):
                pass

            def traffic_report(self):
                return {}

        tx, rx = memory_pair(max_chunk=97)
        self.sender = WireSender(tx, _NullRuntime(), max_depth=max_depth,
                                 backoff=0.0, sleep=lambda _: None)
        self.receiver = WireReceiver(rx, _NullRuntime(), backoff=0.0,
                                     sleep=lambda _: None)

    # prefill side
    def has_room(self, pending=0):
        return self.sender.has_room(pending)

    def publish(self, handoff, pages, slot_one=None):
        self.sender.publish(handoff, pages, slot_one)

    # decode side
    def next_ready(self):
        return self.receiver.next_ready()

    def requeue(self, h):
        self.receiver.requeue(h)

    def fetch_pages(self, h):
        return self.receiver.fetch_pages(h)

    def fetch_slot_leaves(self, h):
        return self.receiver.fetch_slot_leaves(h)

    def discard(self, h):
        self.receiver.discard(h)

    def parked_uids(self):
        self.receiver.pump()
        return self.receiver.parked_uids()

    def depth(self):
        return self.receiver.depth()

    @property
    def _parked(self):
        self.receiver.pump()
        return self.receiver._parked

    @property
    def adopted_pages(self):
        return self.receiver.adopted_pages

    def sweep_cancelled(self):
        swept = self.receiver.sweep_cancelled()
        return swept + self.sender.sweep_cancelled()


def _make_wire_queue(max_depth):
    loop = _WireLoop(max_depth)

    def leak_check():
        loop.receiver.pump()
        assert not loop.receiver._parked, "handoffs parked at drain"
        loop.sender.pump()          # drain the last ACKs off the channel
        assert not loop.sender._inflight, \
            "published handoffs never ACKed — sender credits leaked"
    return loop, leak_check


# ---------------------------------------------------------------------------
# real engines: cluster quota bound, drain, loss, wire engine
def test_cluster_quota_shared_across_engines(model_and_params):
    """Satellite property (real-engine twin): one tenant's pages are
    bounded by its quota ACROSS engines, because every engine charges
    the same ledger; and the ledger never exceeds the summed quotas."""
    m, params = model_and_params
    quota = QuotaManager(default_quota=TenantQuota(max_pages=4))
    router = build_router(m, params, engines=2, quota=quota,
                          batch=2, max_len=64, page_size=16,
                          transfer="host", spill="host")
    shared = router.engines[0].pair.prefill.quota
    assert shared is router.engines[1].pair.prefill.quota  # ONE ledger
    cap = 4 * 2  # two tenants in play
    seen = []
    ss = [router.submit(Request(uid=i, prompt=p, max_new_tokens=4,
                                tenant=f"t{i % 2}"))
          for i, p in enumerate(_prompts(8, base=18))]
    while router.has_work():
        router.step()
        pages = sum(u["pages"] for u in shared.usage().values())
        seen.append(pages)
        assert pages <= cap, f"cluster admitted {pages} > {cap} pages"
    assert max(seen) > 0
    # 2-page sessions under a 4-page cap: rejected sessions only when
    # genuinely over quota, and everything else finished
    for s in ss:
        assert s.done


def test_drain_zero_dropped_real_engines(model_and_params):
    m, params = model_and_params
    router = build_router(m, params, engines=2, batch=2, max_len=64,
                          page_size=16, transfer="host", spill="host")
    ss = [router.submit(Request(uid=i, prompt=p, max_new_tokens=4))
          for i, p in enumerate(_prompts(8))]
    fired = []

    def hook(r):
        if r.now == 2 and not fired:
            fired.append(True)
            r.drain(0)

    done = router.run(on_step=hook)
    assert len(done) == 8 and all(s.done for s in ss)
    assert router.engines[0].state == DETACHED
    assert all(s.finish_reason in ("eos", "length") for s in ss)


def test_engine_loss_requeues_and_streams_survive(model_and_params):
    """Losing an engine mid-run re-prefills its sessions elsewhere; at
    temperature 0 the final streams match an undisturbed router run."""
    m, params = model_and_params
    prompts = _prompts(6)

    def run(lose):
        router = build_router(m, params, engines=2, batch=2, max_len=64,
                              page_size=16, transfer="host", spill="host")
        ss = [router.submit(Request(uid=i, prompt=p, max_new_tokens=5))
              for i, p in enumerate(prompts)]
        fired = []

        def hook(r):
            if lose and r.now == 2 and not fired:
                fired.append(True)
                r.fail(1)

        router.run(on_step=hook)
        return router, [s.result() for s in ss]

    _, want = run(lose=False)
    router, got = run(lose=True)
    assert got == want
    assert router.engines[1].state == "lost"


def test_router_with_wire_engine(model_and_params):
    """A mixed cluster: engine 0 speaks the byte-framed wire, engine 1
    the loopback — streams identical to an all-loopback cluster."""
    m, params = model_and_params
    prompts = _prompts(6)

    def run(wire):
        kw = dict(batch=2, max_len=64, page_size=16, spill="host")
        if wire:
            pairs = [build_wire_pair(m, params, seed=0, **kw),
                     build_disagg(m, params, transfer="host", seed=2, **kw)]
            router = Router(pairs, placement="round_robin")
        else:
            router = build_router(m, params, engines=2,
                                  placement="round_robin",
                                  transfer="host", **kw)
        ss = [router.submit(Request(uid=i, prompt=p, max_new_tokens=4))
              for i, p in enumerate(prompts)]
        router.run()
        return [s.result() for s in ss]

    assert run(wire=True) == run(wire=False)


def test_replay_trace_through_real_router(model_and_params):
    from repro.sim.workloads import TrafficSpec, generate_traffic

    m, params = model_and_params
    trace = generate_traffic(TrafficSpec(
        sessions=10, horizon_s=100.0, prompt_mean=8.0, prompt_max=20,
        decode_mean=4.0, decode_max=8, prefix_len=6, seed=5))
    router = build_router(m, params, engines=2, batch=2, max_len=64,
                          page_size=16, transfer="host", spill="host",
                          placement="prefix_affinity")
    done = replay_trace(router, trace, CFG.vocab_size,
                        arrivals_per_step=2.0)
    assert len(done) == 10
    assert all(len(r.out_tokens) > 0 for r in done)
    # shared-prefix sessions really share their prefix tokens
    by_prefix = {}
    for s in trace:
        if s.prefix_id is not None:
            by_prefix.setdefault(s.prefix_id, []).append(
                tuple(synth_prompt(s, CFG.vocab_size)[:s.prefix_len]))
    for pid, heads in by_prefix.items():
        assert len(set(heads)) == 1


# ---------------------------------------------------------------------------
# the two-process CI smoke: prefill router and decode worker in separate
# processes over localhost TCP; drain the wire engine mid-run; all
# sessions must finish (zero dropped — the launcher asserts it too)
def test_two_process_router_drain_over_tcp(tmp_path):
    """The CI drain scenario: router with a TCP wire engine 0 in one
    process, the decode worker in another; drain the wire engine
    mid-run; both exit clean with zero dropped sessions.

    Children log to FILES, not pipes — an undrained pipe buffer would
    deadlock the pair once either side logs more than 64KB."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    # importing repro.launch.dryrun (test_dryrun_roofline) leaks a
    # 512-host-device XLA_FLAGS into this process's environ; the smoke
    # children must see a clean single-device platform
    env.pop("XLA_FLAGS", None)
    args = [sys.executable, "-m", "repro.launch.serve", "--arch",
            "smollm-135m", "--smoke", "--batch", "2", "--max-len", "64",
            "--page-size", "16"]
    rlog, wlog = tmp_path / "router.log", tmp_path / "worker.log"
    with open(rlog, "w") as rf, open(wlog, "w") as wf:
        router = subprocess.Popen(
            args + ["--router", "--engines", "2", "--listen", "0",
                    "--requests", "6", "--new-tokens", "4",
                    "--drain-after", "4", "--drain-engine", "0"],
            stdout=rf, stderr=subprocess.STDOUT, env=env)
        worker = None
        try:
            port = None
            deadline = time.time() + 240
            while time.time() < deadline and port is None:
                for line in rlog.read_text().splitlines():
                    if "listening on" in line:
                        port = int(line.rsplit(" ", 1)[-1])
                        break
                if port is None:
                    assert router.poll() is None, \
                        "router died early:\n" + rlog.read_text()
                    time.sleep(0.5)
            assert port, "router never opened its port:\n" + rlog.read_text()
            worker = subprocess.Popen(
                args + ["--role", "decode", "--connect",
                        f"127.0.0.1:{port}"],
                stdout=wf, stderr=subprocess.STDOUT, env=env)
            assert router.wait(timeout=240) == 0, rlog.read_text()
            assert worker.wait(timeout=240) == 0, wlog.read_text()
        finally:
            for proc in (router, worker):
                if proc is not None and proc.poll() is None:
                    proc.kill()
    log, wout = rlog.read_text(), wlog.read_text()
    assert "0 dropped" in log, log
    assert "drained engine 0" in log, log
    assert "decode worker done" in wout, wout
