"""core/: pool specs, policy planner, DAG, compression — incl. hypothesis
property tests on the sharding planner's divisibility invariant."""
import pytest

hp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, MemoryPlan, MeshPlan, SHAPES_BY_NAME, get_arch
from repro.core import compress as comp
from repro.core.dag import build_dag, model_flops
from repro.core.policy import fetch_bandwidth, plan_memory
from repro.core.pool import PoolAccountant, PoolAxes, pool_spec
from repro.core.vdnn import split_layers, stash_fraction
from repro.parallel.sharding import ShardingPlanner

SINGLE = MeshPlan((16, 16), ("data", "model"))
MULTI = MeshPlan((2, 16, 16), ("pod", "data", "model"))


# ---------------------------------------------------------------------------
@hp.given(
    dims=st.lists(st.integers(1, 8192), min_size=1, max_size=4),
    plan=st.sampled_from([SINGLE, MULTI, MeshPlan((4, 2), ("data", "model")),
                          MeshPlan((1,), ("data",))]),
)
@hp.settings(max_examples=200, deadline=None)
def test_planner_specs_always_divisible(dims, plan):
    """INVARIANT: every axis the planner assigns exactly divides its dim."""
    planner = ShardingPlanner(plan)
    assignment = [("data", "model")] * len(dims)
    spec = planner.spec(dims, assignment, "prop")
    for dim, part in zip(dims, tuple(spec)):
        if part is None:
            continue
        axes = (part,) if isinstance(part, str) else part
        size = 1
        for a in axes:
            size *= plan.axis_size(a)
        assert dim % size == 0


@hp.given(
    b=st.sampled_from([1, 2, 16, 32, 256, 512]),
    s=st.sampled_from([1, 128, 4096, 32768]),
    d=st.sampled_from([576, 1024, 8192]),
    placement=st.sampled_from(["bw_aware", "local"]),
    plan=st.sampled_from([SINGLE, MULTI]),
)
@hp.settings(max_examples=100, deadline=None)
def test_pool_spec_valid_and_nontrivial(b, s, d, placement, plan):
    """The stash spec is always a valid sharding; when any dim divides the
    model axis, the pool actually shards something."""
    planner = ShardingPlanner(plan)
    spec = pool_spec((b, s, d), planner, placement, batch_dim=0)
    parts = tuple(spec)
    for dim, part in zip((b, s, d), parts + (None,) * (3 - len(parts))):
        if part is None:
            continue
        axes = (part,) if isinstance(part, str) else part
        size = 1
        for a in axes:
            size *= plan.axis_size(a)
        assert dim % size == 0
    tp = plan.axis_size("model")
    if s % tp == 0 or d % tp == 0:
        assert any(p is not None for p in parts)


# ---------------------------------------------------------------------------
def test_pool_axes_and_capacity():
    acct = PoolAccountant(SINGLE, MemoryPlan(placement="bw_aware"))
    assert acct.pool_devices == 256
    # a 1 TB pooled tensor costs 4 GB/device on a 256-chip pool
    acct.alloc_pooled(1e12)
    assert abs(acct.pooled_bytes - 1e12 / 256) < 1
    assert acct.system_capacity() == pytest.approx(16e9 * 256)


def test_fetch_bandwidth_orders():
    bw_b = fetch_bandwidth(SINGLE, MemoryPlan(placement="bw_aware"))
    bw_l = fetch_bandwidth(SINGLE, MemoryPlan(placement="local"))
    assert bw_b >= bw_l > 0


# ---------------------------------------------------------------------------
def test_policy_modes():
    dag = build_dag(get_arch("mixtral-8x7b"), SHAPES_BY_NAME["train_4k"])
    state = 47e9 * 10
    r_mcdla = plan_memory(dag, SINGLE, MemoryPlan(policy="mcdla"),
                          model_state_bytes=state)
    assert r_mcdla.count("keep") == 0           # paper: stash everything
    assert r_mcdla.fits
    r_auto = plan_memory(dag, SINGLE, MemoryPlan(policy="auto"),
                         model_state_bytes=state)
    assert r_auto.count("keep") > 0             # budget allows keeping
    # tiny budget forces pooling
    r_tight = plan_memory(dag, SINGLE,
                          MemoryPlan(policy="auto", hbm_budget_gb=2.5),
                          model_state_bytes=state)
    assert r_tight.count("pool") + r_tight.count("recompute") > \
        r_auto.count("pool") + r_auto.count("recompute")


def test_stash_fraction_bounds():
    dag = build_dag(get_arch("smollm-135m"), SHAPES_BY_NAME["train_4k"])
    assert stash_fraction(dag, SINGLE, MemoryPlan(policy="mcdla")) == 1.0
    assert stash_fraction(dag, SINGLE, MemoryPlan(policy="none")) == 0.0
    f = stash_fraction(dag, SINGLE, MemoryPlan(policy="auto"),
                       model_state_bytes=135e6 * 10)
    assert 0.0 <= f <= 1.0
    assert split_layers(30, f) <= 30


# ---------------------------------------------------------------------------
def test_dag_reuse_distance_monotone():
    dag = build_dag(get_arch("starcoder2-7b"), SHAPES_BY_NAME["train_4k"])
    sched = dag.schedule()
    dists = [d for (_, _, d) in sched]
    assert dists == sorted(dists, reverse=True)   # earlier layers wait longer


def test_model_flops_moe_active():
    cfg = get_arch("llama4-maverick-400b")
    shape = SHAPES_BY_NAME["train_4k"]
    mf = model_flops(cfg, shape)
    dense_equiv = 6.0 * cfg.param_count() * shape.global_batch * shape.seq_len
    assert mf < 0.2 * dense_equiv                 # top-1 of 128 experts


# ---------------------------------------------------------------------------
@hp.given(st.integers(0, 10).flatmap(
    lambda seed: st.just(seed)))
@hp.settings(max_examples=20, deadline=None)
def test_fp8_roundtrip_bounded_error(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64, 32)) * (seed + 1)
    q, s = comp.fp8_compress(x)
    y = comp.fp8_decompress(q, s, jnp.float32)
    rel = float(jnp.linalg.norm(y - x) / (jnp.linalg.norm(x) + 1e-9))
    assert rel < 0.06
    assert q.dtype == jnp.float8_e4m3fn


@hp.given(st.integers(0, 20))
@hp.settings(max_examples=20, deadline=None)
def test_int8_error_feedback_contracts(seed):
    """EF property: quantize(g + err) keeps sum(sent + new_err) == g + err."""
    g = jax.random.normal(jax.random.PRNGKey(seed), (128,)) * 3.0
    err = jax.random.normal(jax.random.PRNGKey(seed + 1), (128,)) * 0.1
    q, scale, new_err = comp.int8_ef_quantize(g, err)
    sent = comp.int8_dequantize(q, scale)
    np.testing.assert_allclose(np.asarray(sent + new_err),
                               np.asarray(g + err), rtol=1e-5, atol=1e-5)
    assert float(jnp.max(jnp.abs(new_err))) <= float(scale) * 0.5 + 1e-6
