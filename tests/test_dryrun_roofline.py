"""Unit tests for the dry-run HLO collective parser and roofline math (the
actual 512-device lowering runs in the sweep; see EXPERIMENTS.md)."""
import pytest

from repro.launch.dryrun import parse_collectives
from repro.launch.roofline import analyze_cell

HLO = """
ENTRY %main {
  %ag = bf16[16,4096,512]{2,1,0} all-gather(bf16[1,4096,512]{2,1,0} %p0), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %p1), replica_groups=[1,256]<=[256], to_apply=%add
  %rs = bf16[8,128]{1,0} reduce-scatter(bf16[128,128]{1,0} %p2), replica_groups=[16,16]<=[256], dimensions={0}
  %cp-start = bf16[64]{0} collective-permute-start(bf16[64]{0} %p3), source_target_pairs={{0,1}}
  %tup = (f32[256]{0}, f32[256]{0}) all-reduce(%a, %b), replica_groups=[4,64]<=[256], to_apply=%add
}
"""


def test_parse_collectives_types_and_bytes():
    out = parse_collectives(HLO)
    # all-gather: result 16*4096*512*2 bytes, n=16 -> wire 15/16 * size
    ag = 16 * 4096 * 512 * 2 * 15 / 16
    assert out["all-gather"] == pytest.approx(ag)
    # all-reduce: scalar array 1024*4, n=256 -> 2*(255/256)*size ; plus the
    # tuple variant 2*256*4 with n=64
    ar = 2 * (255 / 256) * 1024 * 4 + 2 * (63 / 64) * (2 * 256 * 4)
    assert out["all-reduce"] == pytest.approx(ar)
    # reduce-scatter: result shard 8*128*2, n=16 -> (n-1)*shard
    assert out["reduce-scatter"] == pytest.approx(15 * 8 * 128 * 2)
    assert out["collective-permute"] == pytest.approx(64 * 2)


def test_analyze_cell_terms():
    r = {
        "ok": True, "arch": "smollm-135m", "shape": "train_4k",
        "mesh": "16x16", "policy": "mcdla", "placement": "bw_aware",
        "compress": "none", "opt_bits": 32, "accum": 1,
        "flops_per_dev": 197e12 * 0.5,          # 0.5 s of compute
        "bytes_accessed_per_dev": 819e9 * 0.25,  # 0.25 s of HBM
        "collective_wire_bytes_per_dev": 50e9 * 0.1,   # 0.1 s of ICI
        "arg_bytes_per_dev": 1e9, "temp_bytes_per_dev": 2e9,
    }
    a = analyze_cell(r)
    assert a["compute_s"] == pytest.approx(0.5)
    assert a["memory_s"] == pytest.approx(0.25)
    assert a["collective_s"] == pytest.approx(0.1)
    assert a["dominant"] == "compute"
    assert a["fits_hbm"]
    assert 0 < a["roofline_fraction"] <= 1.0
    assert 0 < a["useful_ratio"] < 1.0


def test_analyze_cell_skip_passthrough():
    assert analyze_cell({"ok": None, "skip": "x"}) is None
    assert analyze_cell({"ok": False, "error": "y"}) is None
