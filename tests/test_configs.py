"""Config registry + analytic param counts for the 10 assigned archs."""
import pytest

from repro.configs import ARCHS, SHAPES, SHAPES_BY_NAME, get_arch, list_archs
from repro.configs.registry import all_cells, cells_for

# published (approximate) parameter counts, tolerance 12%
EXPECTED_PARAMS = {
    # the real c4ai-command-r-v01 is MHA (64 kv heads) at ~35B; the
    # assignment pins GQA kv=8, which removes ~4.7B of K/V projections
    "command-r-35b": 30.3e9,
    "h2o-danube-1.8b": 1.8e9,
    "starcoder2-7b": 7.2e9,
    "smollm-135m": 135e6,
    "whisper-medium": 769e6,
    "llama4-maverick-400b": 400e9,
    "mixtral-8x7b": 46.7e9,
    "zamba2-2.7b": 2.7e9,
    "qwen2-vl-2b": 1.6e9,       # LM backbone only (vision tower stubbed)
    "mamba2-370m": 370e6,
}

ACTIVE_PARAMS = {
    "llama4-maverick-400b": 17e9,
    "mixtral-8x7b": 12.9e9,
}


def test_all_archs_registered():
    assert len(list_archs()) == 10
    assert set(EXPECTED_PARAMS) == set(list_archs())


@pytest.mark.parametrize("name", sorted(EXPECTED_PARAMS))
def test_param_counts(name):
    cfg = get_arch(name)
    n = cfg.param_count()
    want = EXPECTED_PARAMS[name]
    assert abs(n - want) / want < 0.12, (name, n, want)


@pytest.mark.parametrize("name", sorted(ACTIVE_PARAMS))
def test_active_params(name):
    cfg = get_arch(name)
    n = cfg.active_param_count()
    want = ACTIVE_PARAMS[name]
    assert abs(n - want) / want < 0.35, (name, n, want)
    assert n < cfg.param_count()


def test_shapes_assignment():
    assert [s.name for s in SHAPES] == [
        "train_4k", "prefill_32k", "decode_32k", "long_500k"]
    assert SHAPES_BY_NAME["train_4k"].seq_len == 4096
    assert SHAPES_BY_NAME["train_4k"].global_batch == 256
    assert SHAPES_BY_NAME["long_500k"].seq_len == 524_288
    assert SHAPES_BY_NAME["long_500k"].mode == "decode"


def test_cells_total_40():
    cells = all_cells()
    assert len(cells) == 40
    runs = [c for c in cells if c[2] == "run"]
    skips = [c for c in cells if c[2] != "run"]
    # long_500k runs only for sub-quadratic archs (4 of 10)
    assert len(skips) == 6
    assert all(s[1].name == "long_500k" for s in skips)
    assert len(runs) == 34


def test_long500k_subquadratic_only():
    for cfg, shape, status in all_cells():
        if shape.name == "long_500k":
            assert (status == "run") == cfg.sub_quadratic, cfg.name


def test_reduced_configs():
    for name in list_archs():
        cfg = get_arch(name).reduced()
        assert cfg.d_model <= 128 and cfg.num_layers <= 2 or cfg.is_hybrid
        assert cfg.family == get_arch(name).family


def test_get_arch_fuzzy():
    assert get_arch("mixtral_8x7b").name == "mixtral-8x7b"
    assert get_arch("smollm").name == "smollm-135m"
    with pytest.raises(KeyError):
        get_arch("nonexistent-model")
