"""Attention correctness: blockwise (flash-style XLA) vs dense reference,
SWA spans, decode vs full, M-RoPE, and the layers utilities."""
import pytest

hp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ref import attention_ref
from repro.models.attention import blockwise_attention, decode_attention
from repro.models.layers import (apply_rope, chunked_cross_entropy,
                                 sinusoidal_pos)


def _bhsd(x):     # (B,S,H,d) -> (B,H,S,d)
    return x.swapaxes(1, 2)


@hp.given(
    seed=st.integers(0, 50),
    S=st.sampled_from([32, 64, 96]),
    causal=st.booleans(),
    window=st.sampled_from([0, 16, 48]),
    q_chunk=st.sampled_from([16, 32]),
)
@hp.settings(max_examples=25, deadline=None)
def test_blockwise_matches_dense(seed, S, causal, window, q_chunk):
    if window and not causal:
        window = 0
    B, H, K, d = 2, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, d))
    k = jax.random.normal(ks[1], (B, S, K, d))
    v = jax.random.normal(ks[2], (B, S, K, d))
    out = blockwise_attention(q, k, v, causal=causal, window=window,
                              q_chunk=q_chunk, kv_chunk=q_chunk)
    want = _bhsd(attention_ref(_bhsd(q), _bhsd(k), _bhsd(v), causal=causal,
                               window=window))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_decode_attention_matches_last_row():
    """decode at index i == row i of the full causal attention."""
    B, S, H, K, d = 2, 24, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q_full = jax.random.normal(ks[0], (B, S, H, d))
    k = jax.random.normal(ks[1], (B, S, K, d))
    v = jax.random.normal(ks[2], (B, S, K, d))
    full = blockwise_attention(q_full, k, v, causal=True, q_chunk=8,
                               kv_chunk=8)
    i = S - 1
    dec = decode_attention(q_full[:, i:i + 1], k, v, jnp.int32(i))
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, i]),
                               rtol=2e-4, atol=2e-4)
    # sliding window variant
    full_w = blockwise_attention(q_full, k, v, causal=True, window=8,
                                 q_chunk=8, kv_chunk=8)
    dec_w = decode_attention(q_full[:, i:i + 1], k, v, jnp.int32(i), window=8)
    np.testing.assert_allclose(np.asarray(dec_w[:, 0]),
                               np.asarray(full_w[:, i]),
                               rtol=2e-4, atol=2e-4)


def test_rope_rotation_invariant():
    """RoPE preserves norms and relative-position dot products."""
    B, S, H, d = 1, 16, 2, 32
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, d))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    y = apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(y, axis=-1)),
        np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-5)
    # shifting both positions by c leaves q.k dot products unchanged
    q = apply_rope(x, pos, 10_000.0)
    k = apply_rope(x, pos, 10_000.0)
    q2 = apply_rope(x, pos + 7, 10_000.0)
    k2 = apply_rope(x, pos + 7, 10_000.0)
    dots1 = jnp.einsum("bshd,bthd->bsth", q, k)
    dots2 = jnp.einsum("bshd,bthd->bsth", q2, k2)
    np.testing.assert_allclose(np.asarray(dots1), np.asarray(dots2),
                               rtol=1e-4, atol=1e-4)


def test_mrope_sections():
    B, S, H, d = 1, 8, 2, 32
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, d))
    pos3 = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
    y = apply_rope(x, pos3, 10_000.0, mrope_sections=(8, 4, 4))
    # identical positions on all three axes == plain rope
    y_ref = apply_rope(x, pos3[0], 10_000.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_chunked_ce_matches_dense():
    B, S, D, V = 2, 24, 16, 64
    h = jax.random.normal(jax.random.PRNGKey(0), (B, S, D))
    emb = jax.random.normal(jax.random.PRNGKey(1), (V, D)) * 0.1
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, V)
    mask = (jnp.arange(S)[None] < S - 3).astype(jnp.float32) * \
        jnp.ones((B, 1))
    loss, cnt = chunked_cross_entropy(h, emb, labels, mask, chunk=8)
    logits = h @ emb.T
    lse = jax.nn.logsumexp(logits, -1)
    pick = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    want = jnp.sum((lse - pick) * mask) / jnp.sum(mask)
    np.testing.assert_allclose(float(loss), float(want), rtol=1e-5)
    assert float(cnt) == float(jnp.sum(mask))
    # gradient flows (remat'd body)
    g = jax.grad(lambda h: chunked_cross_entropy(h, emb, labels, mask,
                                                 chunk=8)[0])(h)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_sinusoidal_offset_consistency():
    a = sinusoidal_pos(10, 32)
    b = sinusoidal_pos(4, 32, offset=6)
    np.testing.assert_allclose(np.asarray(a[6:]), np.asarray(b), rtol=1e-6)
