"""Pipeline schedules over the tier API: registry + S=1 parity in-process,
S in {2,4} parity via subprocess (tests/multidev/pipeline.py), the
PipelineStageTier cost contract, and the planner's bubble-vs-stall trade."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_multidev
from repro import hw
from repro.configs import ARCHS, MemoryPlan, PipelinePlan, RunConfig, \
    SHAPES_BY_NAME, TrainConfig, get_arch
from repro.configs.base import MeshPlan, ShapeConfig
from repro.core.dag import build_dag
from repro.core.policy import micro_candidates, plan_memory, summarize
from repro.core.pool import PoolAccountant
from repro.core.runtime import MemoryRuntime
from repro.core.tiers import (CompressedTier, PipelineStageTier, build_tier,
                              build_stage_tier)
from repro.models.model import build_model
from repro.parallel.pipeline import (accumulate_microbatches, get_schedule,
                                     registered_schedules)
from repro.parallel.sharding import ShardingPlanner
from repro.sim.simulator import simulate_pipeline
from repro.sim.topology import DC_DLA, MC_DLA_B
from repro.sim.workloads import WORKLOADS

CFG = ARCHS["smollm-135m"].reduced(dtype="float32")
PLAN1 = MeshPlan((1,), ("data",))
SINGLE = MeshPlan((16, 16), ("data", "model"))
SHAPE = ShapeConfig("t", 32, 4, "train")


def _batch(B=4, S=32, seed=0):
    return {
        "tokens": jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0,
                                     CFG.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(seed + 1), (B, S),
                                     0, CFG.vocab_size),
        "positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S)),
    }


# ---------------------------------------------------------------------------
# registry + schedule contract
def test_schedule_registry():
    assert registered_schedules() == ("1f1b", "gpipe")
    with pytest.raises(KeyError):
        get_schedule("interleaved")
    g, f = get_schedule("gpipe"), get_schedule("1f1b")
    assert not g.stash_saved and f.stash_saved
    assert g.inflight(4, 16) == 16           # gpipe: all M live
    assert f.inflight(4, 16) == 4            # 1f1b: bounded by S
    assert f.inflight(4, 2) == 2             # ... and by M
    assert g.bubble_fraction(4, 16) == pytest.approx(3 / 19)
    assert g.bubble_fraction(1, 8) == 0.0


def test_micro_candidates_divide_batch():
    cands = micro_candidates(256, 4)
    assert all(256 % m == 0 for m in cands)
    assert all(m >= 4 for m in cands)        # M < S wastes the schedule
    assert micro_candidates(7, 2) == [7]
    assert micro_candidates(2, 4) == [1, 2]  # fallback below stage count


# ---------------------------------------------------------------------------
# S=1 degenerate schedule: in-process parity + stage-tier traffic
def test_single_stage_pipeline_matches_baseline():
    memory = MemoryPlan(policy="mcdla")
    base = build_model(RunConfig(model=CFG, shape=SHAPE, mesh=PLAN1,
                                 memory=memory, train=TrainConfig()))
    params = base.init(jax.random.PRNGKey(0))
    batch = _batch()
    l_base, m_base = jax.jit(base.loss_fn)(params, batch)
    for sched in ("gpipe", "1f1b"):
        m = build_model(RunConfig(
            model=CFG, shape=SHAPE, mesh=PLAN1, memory=memory,
            train=TrainConfig(),
            pipeline=PipelinePlan(enabled=True, schedule=sched, n_micro=2,
                                  n_stages=1)))
        l, _ = jax.jit(m.loss_fn)(params, batch)
        np.testing.assert_allclose(float(l), float(l_base), rtol=1e-6)
        # a grad pass exercises the 1f1b stash/fetch hooks
        jax.jit(jax.grad(lambda p: m.loss_fn(p, batch)[0]))(params)
        rep = m.stage_runtime.traffic_report()
        assert "pipeline_stage" in rep["tier"]
        if sched == "1f1b":
            assert rep["act_stash"]["calls"] > 0
            assert rep["act_fetch"]["calls"] > 0
            assert rep["act_stash"]["wire_bytes"] > 0
        else:                                # gpipe keeps activations live
            assert "act_stash" not in rep


def test_multidev_pipeline_two_stages():
    out = run_multidev("pipeline.py", devices=2, timeout=900)
    assert "schedule loss parity OK" in out
    assert "loss curve parity OK" in out
    assert "stage tier traffic OK" in out
    assert "model pipeline parity OK" in out


def test_multidev_pipeline_four_stages():
    out = run_multidev("pipeline.py", devices=4, timeout=900)
    assert "pipeline == sequential OK" in out
    assert "schedule loss parity OK" in out
    assert "model pipeline parity OK" in out


def test_pipeline_moe_aux_is_microbatch_mean():
    """An MoE load-balance aux is batch-size-invariant, so the pipelined
    forward must average it across microbatches (grad-accum semantics),
    not sum it M x."""
    cfg = ARCHS["mixtral-8x7b"].reduced(dtype="float32")
    memory = MemoryPlan(policy="none")
    shape = ShapeConfig("t", 32, 4, "train")
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(0), (4, 32), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                     cfg.vocab_size),
        "positions": jnp.broadcast_to(jnp.arange(32)[None], (4, 32)),
    }
    base = build_model(RunConfig(model=cfg, shape=shape, mesh=PLAN1,
                                 memory=memory, train=TrainConfig()))
    params = base.init(jax.random.PRNGKey(0))
    # reference: mean of per-microbatch auxes over the same split
    aux_ref = np.mean([
        float(base.loss_fn(params, jax.tree.map(
            lambda v: v[2 * m:2 * m + 2] if getattr(v, "ndim", 0) >= 1
            else v, batch))[1]["aux_loss"]) for m in range(2)])
    pipe = build_model(RunConfig(
        model=cfg, shape=shape, mesh=PLAN1, memory=memory,
        train=TrainConfig(),
        pipeline=PipelinePlan(enabled=True, schedule="1f1b", n_micro=2,
                              n_stages=1)))
    _, m2 = jax.jit(pipe.loss_fn)(params, batch)
    np.testing.assert_allclose(float(m2["aux_loss"]), aux_ref, rtol=1e-5)


# ---------------------------------------------------------------------------
# grad accumulation == the degenerate single-stage schedule
def test_accumulate_microbatches_metrics():
    def loss_fn(params, batch):
        x = batch["x"]
        l = jnp.mean((x @ params["w"]) ** 2)
        return l, {"loss": l, "aux_loss": 0.5 * l,
                   "tokens": jnp.float32(x.shape[0])}

    params = {"w": jnp.ones((4, 2))}
    batch = {"x": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
    g, l, metrics = accumulate_microbatches(loss_fn, params, batch, 4)
    l_full, m_full = loss_fn(params, batch)
    # tokens SUM to the full batch; losses are microbatch means
    assert float(metrics["tokens"]) == 8.0
    assert float(metrics["aux_loss"]) == pytest.approx(
        float(metrics["loss"]) * 0.5, rel=1e-6)
    # mean-of-microbatch-means == full-batch mean (equal microbatches)
    np.testing.assert_allclose(float(l), float(l_full), rtol=1e-6)
    g_full = jax.grad(lambda p: loss_fn(p, batch)[0])(params)
    np.testing.assert_allclose(np.asarray(g["w"]),
                               np.asarray(g_full["w"]), rtol=1e-6)


# ---------------------------------------------------------------------------
# PipelineStageTier cost contract
def test_stage_tier_contract():
    planner = ShardingPlanner(SINGLE)
    memory = MemoryPlan(policy="mcdla")
    inner = build_tier(memory, planner)
    tier = build_stage_tier(memory, planner, None, n_stages=4)
    assert isinstance(tier, PipelineStageTier)
    assert "pipeline_stage" in tier.describe()
    # DCN hop in series: strictly slower than both legs
    bw = tier.bandwidth(SINGLE)
    assert 0 < bw < inner.bandwidth(SINGLE) and bw < hw.DCN_BW
    # per-stage capacity share
    acct = PoolAccountant(SINGLE, memory)
    assert tier.capacity(acct) == pytest.approx(inner.capacity(acct) / 4)
    tier.set_stages(8)
    assert tier.capacity(acct) == pytest.approx(inner.capacity(acct) / 8)
    # registered like the others
    assert isinstance(build_tier(MemoryPlan(policy="pipeline"), planner),
                      PipelineStageTier)
    MemoryPlan(policy="pipeline").validate()


def test_stage_tier_composes_with_codec():
    planner = ShardingPlanner(SINGLE)
    memory = MemoryPlan(policy="mcdla", compress="fp8")
    tier = build_stage_tier(memory, planner, None, n_stages=2)
    assert isinstance(tier, CompressedTier)
    assert tier.payload_ratio() == pytest.approx(0.5)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8), jnp.float32)
    from repro.core.tiers import TransferHints
    y = tier.fetch(tier.stash(x, TransferHints()), TransferHints())
    assert jnp.max(jnp.abs(y.astype(jnp.float32) - x)) < 0.25


# ---------------------------------------------------------------------------
# planner: the bubble-vs-stall trade
def _plan(n_micro, schedule="1f1b", chip=hw.TPU_V5E, n_stages=4,
          recompute=False, arch="smollm-135m"):
    cfg = get_arch(arch)
    dag = build_dag(cfg, SHAPES_BY_NAME["train_4k"])
    memory = MemoryPlan(policy="mcdla", recompute_cheap=recompute)
    planner = ShardingPlanner(SINGLE)
    tier = build_stage_tier(memory, planner, None, n_stages=n_stages)
    return plan_memory(
        dag, SINGLE, memory, chip=chip,
        model_state_bytes=cfg.param_count() * 14, tier=tier,
        pipeline=PipelinePlan(enabled=True, schedule=schedule,
                              n_micro=n_micro, n_stages=n_stages),
        n_micro_candidates=micro_candidates(256, n_stages))


def test_planner_bubble_monotone_in_n_micro():
    bubbles = [_plan(m).pipeline.bubble_s for m in (2, 4, 8, 16, 32)]
    assert all(a > b for a, b in zip(bubbles, bubbles[1:]))


def test_planner_stall_monotone_in_n_micro():
    stalls = [_plan(m).pipeline.stall_s for m in (2, 4, 8, 16, 32)]
    assert all(a <= b for a, b in zip(stalls, stalls[1:]))
    assert stalls[-1] > 0                    # DCN latency term bites


def test_planner_decision_changes_with_n_micro():
    r2, r32 = _plan(2), _plan(32)
    assert r2.pipeline.n_micro != r32.pipeline.n_micro
    assert r2.pipeline.total_s != r32.pipeline.total_s
    assert "pipeline[1f1b" in summarize(r2)


def test_planner_choice_moves_with_tier_bandwidth():
    slow = dataclasses.replace(hw.TPU_V5E, link_bw=hw.TPU_V5E.link_bw / 16)
    m_fast = _plan(0, chip=hw.TPU_V5E).pipeline
    m_slow = _plan(0, chip=slow).pipeline
    # a faster stage tier affords more microbatches (smaller bubble)
    # before stash stalls dominate
    assert m_fast.n_micro >= m_slow.n_micro
    assert m_fast.stall_s <= m_slow.stall_s


def test_planner_gpipe_all_resident():
    r = _plan(0, schedule="gpipe")
    assert r.pipeline.stall_s == 0.0
    assert r.pipeline.act_wire_bytes == 0.0
    assert r.count("pool") == 0 and r.count("recompute") == 0
    # with zero stall the bubble alone decides: max candidate wins
    assert r.pipeline.n_micro == max(micro_candidates(256, 4))


def test_planner_1f1b_reports_act_traffic():
    r = _plan(8)
    assert r.pipeline.act_wire_bytes > 0
    assert r.count("pool") > 0


def test_plan_memory_without_pipeline_unchanged():
    dag = build_dag(get_arch("mixtral-8x7b"), SHAPES_BY_NAME["train_4k"])
    r = plan_memory(dag, SINGLE, MemoryPlan(policy="mcdla"),
                    model_state_bytes=47e9 * 10)
    assert r.pipeline is None
    assert r.count("keep") == 0 and r.fits


# ---------------------------------------------------------------------------
# sim: the stage tier in the DC/HC/MC vocabulary
def test_sim_pipeline_bubble_and_tier():
    dag = WORKLOADS["ResNet"]()
    r8 = simulate_pipeline(dag, MC_DLA_B, n_stages=4, n_micro=8)
    r32 = simulate_pipeline(dag, MC_DLA_B, n_stages=4, n_micro=32)
    assert r32.sync < r8.sync                # bubble shrinks with M
    assert r8.virt_bytes > 0                 # 1f1b streams the stage tier
    g = simulate_pipeline(dag, MC_DLA_B, n_stages=4, n_micro=8,
                          schedule="gpipe")
    assert g.virt_bytes == 0 and g.virt == 0.0
    # pooled backing store beats the PCIe host path on stage stash
    dc = simulate_pipeline(dag, DC_DLA, n_stages=4, n_micro=8)
    assert r8.total <= dc.total
